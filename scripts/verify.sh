#!/usr/bin/env bash
# One entry point for builders and CI:
#   tier-1:  cargo build --release && cargo test -q
#   perf:    decode-loop bench in smoke mode (needs `make artifacts` output)
#
# Integration tests that need artifacts/tiny fail with a "make artifacts"
# hint when the artifacts are missing; unit/property tests always run.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH — cannot run tier-1" >&2
    echo "verify: (tier-1 is: cargo build --release && cargo test -q)" >&2
    exit 1
fi

echo "== verify: tier-1 build =="
cargo build --release

echo "== verify: tier-1 tests =="
cargo test -q

if [ -f artifacts/tiny/manifest.json ]; then
    echo "== verify: decode bench (smoke) =="
    cargo bench --bench runtime_e2e -- --smoke
    echo "verify: wrote BENCH_decode.json"
else
    echo "verify: artifacts/tiny missing — skipping decode bench (run \`make artifacts\`)"
fi

echo "verify: OK"
