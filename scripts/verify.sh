#!/usr/bin/env bash
# One entry point for builders and CI (also reachable as `make verify`):
#   tier-1:  cargo build --release && cargo test -q
#   perf:    decode-loop + rollout + serve-loop benches in smoke mode, and
#            the serve example's --demo path (all need `make artifacts`
#            output; the rollout phase additionally needs the serving
#            entries and emits BENCH_rollout.json)
#
# Integration tests that need artifacts/tiny fail with a "make artifacts"
# hint when the artifacts are missing; unit/property tests always run.
# Serve smokes additionally need artifacts that include the serving
# entries (prefill_slot / decode_slots) — stale artifact dirs skip them
# with a re-run hint instead of failing.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH — cannot run tier-1" >&2
    echo "verify: (tier-1 is: cargo build --release && cargo test -q)" >&2
    exit 1
fi

echo "== verify: tier-1 build =="
cargo build --release

echo "== verify: tier-1 tests =="
cargo test -q

if [ -f artifacts/tiny/manifest.json ]; then
    echo "== verify: decode + rollout bench (smoke; per-backend host bytes/token) =="
    cargo bench --bench runtime_e2e -- --smoke
    test -s BENCH_decode.json \
        || { echo "verify: runtime_e2e bench did not write BENCH_decode.json" >&2; exit 1; }
    echo "verify: wrote BENCH_decode.json"
    if grep -q '"decode_step_sampled"' artifacts/tiny/manifest.json; then
        echo "verify: device-sampling artifacts present — decode bench covered host + device backends"
    else
        echo "verify: artifacts predate device-side sampling — decode bench covered host backend only (re-run \`make artifacts\`)"
    fi
    if grep -q '"device_rng": true' artifacts/tiny/manifest.json; then
        # The decode bench's chunk sweep (device counter-RNG categorical,
        # N in whatever decode_chunk_sizes the manifest carries) ran above
        # and landed in BENCH_decode.json's "chunk_sweep" section.
        echo "verify: device_rng capability present — decode bench swept fused decode chunks"
    else
        echo "verify: artifacts predate device-side RNG sampling — chunk sweep skipped (re-run \`make artifacts\`)"
    fi
    if grep -q '"prefill_slot"' artifacts/tiny/manifest.json; then
        # runtime_e2e's rollout phase (continuous vs fixed experience
        # generation) ran above and wrote BENCH_rollout.json.
        echo "verify: wrote BENCH_rollout.json (continuous rollout smoke ran in the bench)"
        if grep -q '"padded_prompts": true' artifacts/tiny/manifest.json; then
            # The serve demo mixes short TRUE prompt lengths into its
            # request list and the serve/rollout benches run their
            # mixed-length phases when this capability is present, so the
            # left-padded variable-length path is smoke-covered below.
            echo "verify: padded_prompts capability present — serve demo + benches cover mixed-length traffic"
        else
            echo "verify: artifacts predate variable-length prompts — mixed-length smokes skipped (re-run \`make artifacts\`)"
        fi
        if grep -q '"paged_kv": true' artifacts/tiny/manifest.json; then
            # serve_loop's prefix-heavy phase flips the engine to the
            # block-paged cache, admits a shared system prompt, and reports
            # admitted vs computed tokens + cache hit rate in
            # BENCH_serve.json; the integration goldens (paged ≡ arena
            # bit-match, shared-prefix reuse) ran under `cargo test` above.
            echo "verify: paged_kv capability present — serve bench covers the block-paged prefix-reuse phase"
        else
            echo "verify: artifacts predate the block-paged KV cache — paged smokes skipped (re-run \`make artifacts\`)"
        fi
        if grep -q '"lazy_kv": true' artifacts/tiny/manifest.json; then
            # serve_loop's oversubscribed phase caps the page pool below
            # the full per-slot reservation via limit_kv_pages; lazy page
            # growth + LRU prefix eviction + preempt/requeue keep the
            # greedy completions bit-identical to the uncapped run.
            echo "verify: lazy_kv capability present — serve bench covers the oversubscribed-pool phase"
        else
            echo "verify: artifacts predate lazy KV block tables — oversubscription smoke skipped (re-run \`make artifacts\`)"
        fi
        echo "== verify: serve demo (continuous batching smoke + telemetry trace) =="
        rm -f trace_serve.json
        cargo run --release --example serve -- --demo --trace-out trace_serve.json
        test -s trace_serve.json \
            || { echo "verify: serve demo did not write trace_serve.json (--trace-out)" >&2; exit 1; }
        if command -v python3 >/dev/null 2>&1; then
            # Parses as trace-event JSON with >= 1 complete request span
            # (queued -> retired with a finish code) per admitted request.
            python3 scripts/check_trace.py trace_serve.json
        fi
        echo "verify: wrote trace_serve.json (Chrome trace — load in Perfetto)"
        if grep -q '"decode_slots_sampled"' artifacts/tiny/manifest.json; then
            echo "== verify: serve demo (device sampling tail) =="
            cargo run --release --example serve -- --demo --backend device
        fi
        if grep -q '"device_rng": true' artifacts/tiny/manifest.json \
            && grep -q '"decode_chunk4"' artifacts/tiny/manifest.json; then
            echo "== verify: serve demo (fused 4-token decode, device RNG) =="
            cargo run --release --example serve -- --demo --decode-chunk 4
        else
            echo "verify: artifacts lack decode_chunk entries — fused-chunk serve demo skipped (re-run \`make artifacts\`)"
        fi
        echo "== verify: serve bench (smoke; includes the mixed-length + fused-chunk phases when supported) =="
        cargo bench --bench serve_loop -- --smoke
        test -s BENCH_serve.json \
            || { echo "verify: serve_loop bench did not write BENCH_serve.json" >&2; exit 1; }
        echo "verify: wrote BENCH_serve.json"
        if grep -q '"lazy_kv": true' artifacts/tiny/manifest.json; then
            # The oversubscribed phase must have run and reported its
            # pool-pressure fields (the bench itself asserts the capped
            # run's tokens match the uncapped prefix phase).
            for field in continuous_oversub oversub_pool_pages oversub_peak_occupancy \
                oversub_preemptions oversub_pages_stolen oversub_steal_rate_per_admission; do
                grep -q "\"$field\"" BENCH_serve.json \
                    || { echo "verify: BENCH_serve.json lacks \"$field\" despite lazy_kv artifacts" >&2; exit 1; }
            done
            echo "verify: BENCH_serve.json carries the oversubscribed-phase occupancy/steal/preemption fields"
        fi
        echo "== verify: serve bench under chaos (fault injection smoke) =="
        # Re-runs the continuous phase with transient prefill/decode faults
        # and slow ticks injected; the bench asserts goodput survives and
        # reports the recovery counters in BENCH_serve.json's chaos phase.
        cargo bench --bench serve_loop -- --smoke --chaos
        echo "verify: wrote BENCH_serve.json (with chaos phase)"
        echo "== verify: anomaly-guard rollback drill + resume =="
        # A short PPO run with iteration 1's loss poisoned to NaN: the
        # guard must trip, roll back, and finish; then --resume continues
        # from the durable checkpoint the first run wrote.
        rm -rf runs/verify_guard
        cargo run --release -- train --run tiny \
            --sft-steps 20 --rm-steps 20 --ppo-iters 3 \
            --fault-iter 1 --ckpt-interval 1 --out runs/verify_guard
        test -f runs/verify_guard/ppo_ckpt.bin \
            || { echo "verify: rollback drill left no durable checkpoint" >&2; exit 1; }
        # Resume against a longer horizon so the restored run actually
        # trains more iterations (the checkpoint holds iteration 3 of 3;
        # resuming at --ppo-iters 3 would be refused as already complete).
        cargo run --release -- train --run tiny --ppo-iters 5 \
            --resume --out runs/verify_guard
        echo "verify: rollback drill + resume OK (runs/verify_guard)"
    else
        echo "verify: artifacts predate continuous batching — skipping rollout/serve smokes (re-run \`make artifacts\`)"
    fi
else
    echo "verify: artifacts/tiny missing — skipping benches (run \`make artifacts\`)"
fi

echo "verify: OK"
