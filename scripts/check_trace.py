#!/usr/bin/env python3
"""Validate a Chrome trace written by `--trace-out` (serve / e2e_rlhf /
`dschat train`): the file must parse as trace-event JSON (array form) and
every request admitted to a slot must show a COMPLETE lifecycle span — a
`request` Begin paired with a `request` End carrying a decoded finish
code. Used by scripts/verify.sh and the CI telemetry job.

The recorder's event buffer is bounded: on overflow it keeps the earliest
events and stamps the drop count into the trace as a `telemetry_dropped`
instant. Such a trace is TRUNCATED — the missing tail makes unclosed
spans expected, so that check downgrades to a warning (the parse,
ordering, and finish-code checks still apply to what was kept).

Usage: check_trace.py TRACE.json [--min-requests N]
"""
import json
import sys


def fail(msg):
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    if len(sys.argv) < 2:
        fail("usage: check_trace.py TRACE.json [--min-requests N]")
    path = sys.argv[1]
    min_requests = 1
    if "--min-requests" in sys.argv:
        min_requests = int(sys.argv[sys.argv.index("--min-requests") + 1])

    with open(path) as f:
        events = json.load(f)
    if not isinstance(events, list) or not events:
        fail(f"{path}: expected a non-empty trace-event array")

    dropped = sum(
        e.get("args", {}).get("value", 0)
        for e in events
        if e.get("name") == "telemetry_dropped"
    )

    open_spans = {}
    finishes = {}
    complete = 0
    for e in events:
        ph = e.get("ph")
        if ph not in ("B", "E") or e.get("name") != "request":
            continue
        key = (e.get("tid"), e.get("args", {}).get("id"))
        if ph == "B":
            open_spans[key] = e
        else:
            begin = open_spans.pop(key, None)
            if begin is None:
                fail(f"{path}: request End without a Begin: {e}")
            if e["ts"] < begin["ts"]:
                fail(f"{path}: request span ends before it begins: {e}")
            fin = e.get("args", {}).get("finish")
            if fin not in ("eos", "length", "failed", "deadline", "aborted"):
                fail(f"{path}: request End without a finish code: {e}")
            finishes[fin] = finishes.get(fin, 0) + 1
            complete += 1

    if open_spans:
        if dropped > 0:
            # Truncated trace: the recorder dropped the timeline tail, so
            # the missing End events are expected, not a scheduler bug.
            print(
                f"check_trace: WARN: {path}: {len(open_spans)} request span(s) "
                f"unclosed, but the trace is truncated ({dropped} event(s) "
                f"dropped at capacity) — raise the event buffer capacity for "
                f"a complete timeline",
                file=sys.stderr,
            )
        else:
            fail(
                f"{path}: {len(open_spans)} request span(s) never closed: "
                f"{sorted(open_spans)}"
            )
    if complete < min_requests:
        fail(f"{path}: {complete} complete request span(s), wanted >= {min_requests}")
    truncated = f", TRUNCATED ({dropped} dropped)" if dropped > 0 else ""
    print(
        f"check_trace: OK: {path}: {len(events)} events, "
        f"{complete} complete request span(s) {finishes}{truncated}"
    )


if __name__ == "__main__":
    main()
