//! The required end-to-end driver (DESIGN.md §5): full 3-step RLHF on the
//! `small` deployment — SFT, reward model, then a few hundred PPO
//! iterations — logging every curve to `runs/e2e/` and printing a Table 4-6
//! style breakdown plus before/after evaluation.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_rlhf -- \
//!     [--run small] [--sft-steps 800] [--rm-steps 400] [--ppo-iters 200] \
//!     [--rollout fixed|continuous] [--rollout-batch N] [--min-prompt-len L] \
//!     [--decode-chunk N] [--trace-out trace.json]
//! ```
//!
//! `--rollout continuous` streams Step-3 experience generation through the
//! continuous-batching scheduler (`dschat::rollout`): `--rollout-batch N`
//! prompts per PPO iteration (default 2x the artifact batch, must be a
//! multiple of it) share the KV slots, EOS-retired rows admit the next
//! prompt immediately, and each group of `b` completions trains as its own
//! PPO batch. `--min-prompt-len L` additionally draws each rollout
//! prompt's TRUE length uniformly from `[L, prompt_len]` (left-padded
//! variable-length admission; needs artifacts with the `padded_prompts`
//! capability). `--rollout fixed` (default) keeps the lockstep
//! `HybridEngine::generate` path with exactly `b` prompts.
//!
//! `--decode-chunk N` (default 1) fuses N decode steps per scheduler
//! dispatch during the continuous rollout: sampling moves fully on-device
//! (counter-RNG categorical draw) and each artifact call returns N tokens
//! per live slot, cutting host round-trips per generated token by ~N×.
//! Needs `--rollout continuous` and artifacts built with the `decode_chunkN`
//! capability (re-run `make artifacts` on older artifact sets).
//!
//! Recorded in EXPERIMENTS.md (§Real end-to-end run).

use std::path::PathBuf;
use std::rc::Rc;

use dschat::config::{PpoConfig, TrainRecipe};
use dschat::data::synthetic::{Mode, TaskGen};
use dschat::data::{Blend, DataSplit};
use dschat::examples_support::eval_true_reward;
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::Engine;
use dschat::util::argparse::Args;
use dschat::util::csv::Table;
use dschat::util::fmt_duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let run = args.str("run", "small");
    let dir = args.str("artifacts", &format!("artifacts/{run}"));
    let out = PathBuf::from(args.str("out", "runs/e2e"));
    std::fs::create_dir_all(&out)?;

    println!("== e2e RLHF ({run}) ==");
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, args.usize("seed", 0) as i32, true)?;
    // Pipeline-phase tracing: rollout / score / train-step / checkpoint /
    // guard-rollback spans on their own Perfetto tracks, plus per-slot
    // request lifecycles when the continuous rollout runs.
    let trace_out = args.get("trace-out").map(|s| s.to_string());
    if trace_out.is_some() {
        he.set_telemetry(dschat::telemetry::Telemetry::enabled_default());
    }
    let (vocab, sp, sg, batch, seq_len, actor_name, critic_name, actor_np, critic_np) = {
        let m = he.manifest();
        (m.actor.vocab, m.prompt_len, m.gen_len, m.batch, m.seq_len,
         m.actor.name.clone(), m.critic.name.clone(),
         m.actor.n_params(), m.critic.n_params())
    };
    println!(
        "actor {} ({} params) | critic {} ({} params) | batch {} seq {}",
        actor_name,
        dschat::util::fmt_count(actor_np as f64),
        critic_name,
        dschat::util::fmt_count(critic_np as f64),
        batch,
        seq_len
    );

    // Blended data sources (the paper's data abstraction): general 4-mode
    // instructions + a counting-heavy source, split 2/4/4 across stages.
    let all_modes = TaskGen::new(vocab, sp, sg);
    let counting = TaskGen::new(vocab, sp, sg).with_modes(vec![Mode::Count]);
    let mut blend =
        Blend::new(vec![(all_modes, 3.0), (counting, 1.0)], DataSplit::new(2.0, 4.0, 4.0));

    // Experience-generation path: fixed lockstep batches, or the prompt
    // queue streamed through the continuous-batching scheduler.
    let rollout_batch = match args.str("rollout", "fixed").as_str() {
        "fixed" => {
            anyhow::ensure!(
                args.get("rollout-batch").is_none(),
                "--rollout-batch only applies to --rollout continuous (the fixed path \
                 always generates exactly the artifact batch)"
            );
            0
        }
        "continuous" => {
            let n = args.usize("rollout-batch", 2 * batch);
            anyhow::ensure!(
                n > 0 && n % batch == 0,
                "--rollout-batch must be a positive multiple of the artifact batch {batch}, got {n}"
            );
            n
        }
        other => anyhow::bail!("unknown --rollout {other:?} (fixed|continuous)"),
    };
    let min_prompt_len = args.usize("min-prompt-len", 0);
    if min_prompt_len > 0 {
        anyhow::ensure!(
            rollout_batch > 0,
            "--min-prompt-len needs --rollout continuous (the fixed path generates \
             exact-length prompts only)"
        );
        anyhow::ensure!(
            min_prompt_len <= sp,
            "--min-prompt-len {min_prompt_len} exceeds the artifact prompt window {sp}"
        );
    }
    let decode_chunk = args.usize("decode-chunk", 1);
    anyhow::ensure!(decode_chunk > 0, "--decode-chunk must be at least 1");
    if decode_chunk > 1 {
        anyhow::ensure!(
            rollout_batch > 0,
            "--decode-chunk needs --rollout continuous (the fixed path dispatches one \
             decode step at a time by design)"
        );
    }
    if rollout_batch > 0 {
        println!(
            "rollout: continuous ({} prompts/iter through the slot scheduler, {} PPO batches{}{})",
            rollout_batch,
            rollout_batch / batch,
            if min_prompt_len > 0 {
                format!(", prompt lengths {}..={sp}", min_prompt_len.max(TaskGen::MIN_PROMPT_LEN))
            } else {
                String::new()
            },
            if decode_chunk > 1 {
                format!(", fused decode chunks of {decode_chunk} (device RNG)")
            } else {
                String::new()
            }
        );
    }

    let recipe = TrainRecipe {
        run: run.clone(),
        seed: args.usize("seed", 0) as u64,
        sft_steps: args.usize("sft-steps", 800),
        sft_lr: args.f64("sft-lr", 6e-3) as f32,
        rm_steps: args.usize("rm-steps", 400),
        rm_lr: args.f64("rm-lr", 2e-3) as f32,
        ppo_iters: args.usize("ppo-iters", 200),
        actor_lr: args.f64("actor-lr", 2e-4) as f32,
        critic_lr: args.f64("critic-lr", 8e-4) as f32,
        ppo: PpoConfig {
            ptx_coef: args.f64("ptx-coef", 0.2) as f32,
            kl_coef: args.f64("kl-coef", 0.05) as f32,
            ppo_epochs: 1,
            rollout_batch,
            min_prompt_len,
            decode_chunk,
            ..Default::default()
        },
        ..Default::default()
    };

    // Baseline quality before any training.
    let r_init = eval_true_reward(&mut he, 4, 99)?;
    println!("eval true reward before training: {r_init:.3}");

    // Run the three steps separately so quality is measured at each stage
    // boundary (greedy decoding, fresh prompts).
    let mut rng = dschat::util::rng::Rng::new(recipe.seed);
    let mut sft_log = dschat::util::csv::CsvWriter::create(out.join("sft.csv"), &["step", "loss", "lr"])?;
    let sft = pipeline::run_sft(&mut he, &mut blend, &recipe, &mut rng, Some(&mut sft_log))?;
    let r_sft = eval_true_reward(&mut he, 4, 99)?;
    println!("eval true reward after SFT: {r_sft:.3}");

    let mut rm_log =
        dschat::util::csv::CsvWriter::create(out.join("rm.csv"), &["step", "loss", "acc", "lr"])?;
    let rm = pipeline::run_rm(&mut he, &mut blend, &recipe, &mut rng, Some(&mut rm_log))?;

    let mut ppo_log = dschat::util::csv::CsvWriter::create(
        out.join("ppo.csv"),
        &["iter", "true_reward", "rm_score", "kl", "actor_loss", "critic_loss", "clipfrac",
          "gen_secs", "train_secs"],
    )?;
    let (ppo, ppo_history) = pipeline::run_ppo(&mut he, &mut blend, &recipe, &mut rng, Some(&mut ppo_log))?;
    let report = pipeline::PipelineReport { sft, rm, ppo, ppo_history };
    let r_sft_rl = eval_true_reward(&mut he, 4, 99)?;
    he.promote_ema()?;
    let r_ema = eval_true_reward(&mut he, 4, 99)?;

    // Table 4/5/6 analogue: measured per-step wall time at this scale.
    let mut t = Table::new(
        "Measured e2e breakdown (Table 4-6 analogue, CPU PJRT testbed)",
        &["Model", "Step 1", "Step 2", "Step 3", "Total"],
    );
    t.row(vec![
        format!("Actor {actor_name}, RM {critic_name}"),
        fmt_duration(report.sft.wall_secs),
        fmt_duration(report.rm.wall_secs),
        fmt_duration(report.ppo.wall_secs),
        fmt_duration(report.sft.wall_secs + report.rm.wall_secs + report.ppo.wall_secs),
    ]);
    t.print();

    println!("step 1 SFT loss    : {:.3} -> {:.3}", report.sft.first_metric, report.sft.last_metric);
    println!(
        "step 2 RM          : loss {:.3} -> {:.3} | held-out pairwise acc {:.1}%",
        report.rm.first_metric,
        report.rm.last_metric,
        100.0 * report.rm.extra
    );
    println!(
        "step 3 PPO         : true reward {:.3} -> {:.3} (RM score {:.3})",
        report.ppo.first_metric, report.ppo.last_metric, report.ppo.extra
    );
    println!("eval true reward   : init {r_init:.3} | after SFT {r_sft:.3} | after PPO {r_sft_rl:.3} | EMA ckpt {r_ema:.3}");
    println!(
        "phase stats        : gen {} ({} tok, {:.0} tok/s) | train {} ({:.0} tok/s) | {} flips",
        fmt_duration(he.stats.gen_secs),
        he.stats.gen_tokens,
        he.stats.gen_tok_per_sec(),
        fmt_duration(he.stats.train_secs),
        he.stats.train_tok_per_sec(),
        he.stats.mode_flips
    );
    if rollout_batch > 0 {
        let mean_bubble: f64 = report.ppo_history.iter().map(|s| s.rollout_bubble).sum::<f64>()
            / report.ppo_history.len().max(1) as f64;
        println!(
            "rollout            : {} prompts/iter via scheduler, mean slot-bubble {:.1}%",
            rollout_batch,
            100.0 * mean_bubble
        );
    }
    println!(
        "memory (tracked)   : live {} peak {}",
        dschat::util::fmt_bytes(he.memory.live_bytes() as f64),
        dschat::util::fmt_bytes(he.memory.peak_bytes() as f64)
    );

    let ckpt = out.join("actor_ema.bin");
    pipeline::save_actor(&he, &ckpt)?;
    println!("saved EMA actor to {}", ckpt.display());
    println!("curves: {}/sft.csv rm.csv ppo.csv", out.display());
    if let Some(path) = &trace_out {
        std::fs::write(path, he.telemetry.chrome_trace_json())?;
        println!(
            "wrote Chrome trace ({} events, {} dropped) to {path}",
            he.telemetry.event_count(),
            he.telemetry.dropped()
        );
    }
    Ok(())
}
