//! Chat: the paper's §2.1 inference-API demo — load a trained actor
//! checkpoint and hold a scripted conversation on the synthetic task,
//! showing the ground-truth score per exchange.
//!
//! ```text
//! cargo run --release --example chat -- [--run tiny] [--ckpt runs/tiny/actor.bin] [--turns 4]
//! ```

use std::rc::Rc;

use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::Engine;
use dschat::util::argparse::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let run = args.str("run", "tiny");
    let dir = args.str("artifacts", &format!("artifacts/{run}"));
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, false)?;
    match args.get("ckpt") {
        Some(ckpt) => {
            pipeline::load_actor(&mut he, ckpt)?;
            println!("loaded checkpoint {ckpt}");
        }
        None => println!("(no --ckpt: chatting with an untrained actor — try training first)"),
    }
    dschat::examples_support::chat_loop(&mut he, args.usize("turns", 4), args.usize("seed", 1) as u64)
}
