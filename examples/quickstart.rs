//! Quickstart: the paper's §2.2 "coffee-break" experience at `tiny` scale —
//! all three RLHF steps on one CPU in a couple of minutes.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use dschat::config::{PpoConfig, TrainRecipe};
use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::Engine;
use dschat::util::fmt_duration;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts/tiny".into());
    println!("== dschat quickstart ({dir}) ==");
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, true)?;
    let m = he.manifest();
    println!(
        "actor {} ({} params) + critic {} ({} params), batch {}, seq {}",
        m.actor.name,
        dschat::util::fmt_count(m.actor.n_params() as f64),
        m.critic.name,
        dschat::util::fmt_count(m.critic.n_params() as f64),
        m.batch,
        m.seq_len
    );

    let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let mut blend = Blend::new(vec![(task, 1.0)], DataSplit::new(2.0, 4.0, 4.0));
    let recipe = TrainRecipe {
        sft_steps: 300,
        sft_lr: 1e-2,
        rm_steps: 150,
        rm_lr: 3e-3,
        ppo_iters: 15,
        actor_lr: 2e-4,
        critic_lr: 8e-4,
        ppo: PpoConfig { ptx_coef: 0.2, ..Default::default() },
        ..Default::default()
    };

    let t0 = std::time::Instant::now();
    let report = pipeline::run_all(&mut he, &mut blend, &recipe, None)?;
    println!(
        "step 1 SFT : loss {:.3} -> {:.3}   ({})",
        report.sft.first_metric,
        report.sft.last_metric,
        fmt_duration(report.sft.wall_secs)
    );
    println!(
        "step 2 RM  : loss {:.3} -> {:.3}, held-out pairwise acc {:.1}%   ({})",
        report.rm.first_metric,
        report.rm.last_metric,
        100.0 * report.rm.extra,
        fmt_duration(report.rm.wall_secs)
    );
    println!(
        "step 3 PPO : true reward {:.3} -> {:.3}   ({})",
        report.ppo.first_metric,
        report.ppo.last_metric,
        fmt_duration(report.ppo.wall_secs)
    );
    println!(
        "hybrid engine: {} mode flips | gen {} ({:.0} tok/s) | train {}",
        he.stats.mode_flips,
        fmt_duration(he.stats.gen_secs),
        he.stats.gen_tok_per_sec(),
        fmt_duration(he.stats.train_secs)
    );

    println!("\n-- inference API demo (greedy) --");
    dschat::examples_support::chat_loop(&mut he, 2, 7)?;
    println!("total: {}", fmt_duration(t0.elapsed().as_secs_f64()));
    Ok(())
}
