//! Serve: a minimal line-oriented inference server over the trained actor —
//! the "favorite front-end GUI" hook of the paper's §2.2, with dynamic
//! request batching done by the L3 coordinator (std-thread edition; tokio is
//! not available offline).
//!
//! Protocol (newline-delimited over TCP): a request is `mode a b` (e.g.
//! `count 10 12`); the response line is the detokenized generation plus the
//! ground-truth score.
//!
//! ```text
//! cargo run --release --example serve -- [--run tiny] [--ckpt runs/tiny/actor.bin] \
//!     [--port 7878] [--demo]        # --demo: run 3 in-process requests and exit
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::rc::Rc;
use std::sync::mpsc;

use dschat::data::synthetic::{Mode, Prompt, TaskGen, Vocab};
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::Engine;
use dschat::sampling::{Sampler, SamplerConfig};
use dschat::util::argparse::Args;

struct Request {
    prompt: Prompt,
    reply: mpsc::Sender<String>,
}

fn parse_request(task: &TaskGen, line: &str) -> Option<Prompt> {
    let mut it = line.split_whitespace();
    let mode = match it.next()?.to_lowercase().as_str() {
        "repeat" => Mode::Repeat,
        "constant" => Mode::Constant,
        "count" => Mode::Count,
        "mirror" => Mode::Mirror,
        _ => return None,
    };
    let (lo, hi) = task.vocab.content_range();
    let a = it.next()?.parse::<i32>().ok()?.clamp(lo, hi - 1);
    let b = it.next().and_then(|s| s.parse::<i32>().ok()).unwrap_or(a).clamp(lo, hi - 1);
    // Re-synthesize the canonical prompt encoding.
    let mut tokens = vec![Vocab::BOS, mode.token(), a, b];
    while tokens.len() < task.prompt_len - 1 {
        let i = tokens.len();
        tokens.push(if i % 2 == 0 { a } else { b });
    }
    tokens.push(Vocab::SEP);
    Some(Prompt { mode, a, b, tokens })
}

/// The batching loop: drain up to `batch` queued requests (padding the
/// artifact batch with repeats), run one generation, reply to each.
/// Per-batch latency and host↔device traffic are logged from the engine's
/// byte ledger — with the device-resident decode path, bytes/token stay
/// O(b·vocab) no matter how large the KV cache is.
fn serve_batch(he: &mut HybridEngine, task: &TaskGen, reqs: Vec<Request>, sampler: &mut Sampler) {
    let m = he.manifest();
    let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
    let mut flat = Vec::with_capacity(b * sp);
    for i in 0..b {
        let p = &reqs[i.min(reqs.len() - 1)].prompt;
        flat.extend_from_slice(&p.tokens);
    }
    let secs0 = he.stats.gen_secs;
    let toks0 = he.stats.gen_tokens;
    let (up0, down0) = he.engine.bytes_moved();
    match he.generate(&flat, sampler) {
        Ok(seqs) => {
            let secs = he.stats.gen_secs - secs0;
            let toks = he.stats.gen_tokens - toks0;
            let (up, down) = he.engine.bytes_moved();
            eprintln!(
                "[batch] {} req ({} rows), {} tok in {:.0}ms ({:.1} tok/s), host {}/tok down {}/tok up",
                reqs.len(),
                b,
                toks,
                secs * 1e3,
                toks as f64 / secs.max(1e-9),
                dschat::util::fmt_bytes((down - down0) as f64 / toks.max(1) as f64),
                dschat::util::fmt_bytes((up - up0) as f64 / toks.max(1) as f64),
            );
            for (i, r) in reqs.iter().enumerate() {
                let resp = &seqs[i * s + sp..(i + 1) * s];
                let score = task.reward(&r.prompt, resp);
                let _ = r.reply.send(format!(
                    "{}  [ground-truth {:.2}]",
                    task.detokenize(resp),
                    score
                ));
            }
        }
        Err(e) => {
            for r in &reqs {
                let _ = r.reply.send(format!("error: {e:#}"));
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let run = args.str("run", "tiny");
    let dir = args.str("artifacts", &format!("artifacts/{run}"));
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, false)?;
    if let Some(ckpt) = args.get("ckpt") {
        pipeline::load_actor(&mut he, ckpt)?;
        eprintln!("loaded checkpoint {ckpt}");
    }
    let m = he.manifest();
    let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let mut sampler = Sampler::new(SamplerConfig { greedy: true, ..Default::default() }, 0);

    if args.bool("demo", false) {
        // In-process demo: exercise the batching path without a socket.
        let demo = ["repeat 10 11", "count 20", "mirror 30 31"];
        let (tx, rx) = mpsc::channel();
        let reqs: Vec<Request> = demo
            .iter()
            .filter_map(|l| parse_request(&task, l))
            .map(|prompt| Request { prompt, reply: tx.clone() })
            .collect();
        let n = reqs.len();
        serve_batch(&mut he, &task, reqs, &mut sampler);
        for (line, req) in rx.iter().take(n).zip(demo.iter()) {
            println!("{req:<16} -> {line}");
        }
        return Ok(());
    }

    let port = args.usize("port", 7878);
    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    eprintln!("serving on 127.0.0.1:{port} (one line per request: `mode a [b]`)");

    // Accept loop on worker threads; generation on this (engine-owning)
    // thread — PJRT types are not Send, so requests flow over a channel and
    // the main thread is the single executor (the vLLM-router shape).
    let (tx, rx) = mpsc::channel::<RequestLine>();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let mut line = String::new();
                while reader.read_line(&mut line).unwrap_or(0) > 0 {
                    let (rtx, rrx) = mpsc::channel();
                    let text = line.trim().to_string();
                    line.clear();
                    let _ = tx.send(RequestLine { text, reply: rtx });
                    if let Ok(resp) = rrx.recv() {
                        let _ = writeln!(stream, "{resp}");
                    }
                }
            });
        }
    });

    // Batch scheduler: block for one request, then drain whatever else is
    // queued up to the artifact batch size (dynamic batching).
    let b = m.batch;
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => break,
        };
        let mut lines = vec![first];
        while lines.len() < b {
            match rx.try_recv() {
                Ok(r) => lines.push(r),
                Err(_) => break,
            }
        }
        let reqs: Vec<Request> = lines
            .into_iter()
            .filter_map(|rl| {
                let reply = rl.reply.clone();
                match parse_request(&task, &rl.text) {
                    Some(prompt) => Some(Request { prompt, reply }),
                    None => {
                        let _ = rl
                            .reply
                            .send("parse error: expected `repeat|constant|count|mirror a [b]`".into());
                        None
                    }
                }
            })
            .collect();
        if !reqs.is_empty() {
            serve_batch(&mut he, &task, reqs, &mut sampler);
        }
    }
    Ok(())
}

struct RequestLine {
    text: String,
    reply: mpsc::Sender<String>,
}
