//! Serve: a line-oriented inference server over the trained actor — the
//! "favorite front-end GUI" hook of the paper's §2.2, scheduled with
//! **iteration-level continuous batching** (`dschat::serving`).
//!
//! # Protocol
//!
//! Newline-delimited over TCP: a request line is `mode a [b [len]]` (e.g.
//! `count 10 12`, `repeat 10 11 9`; modes `repeat|constant|count|mirror`);
//! the response line is the detokenized generation plus the ground-truth
//! score. The optional `len` is the prompt's TRUE length — shorter
//! prompts ride the left-padded variable-length admission path when the
//! artifacts carry the `padded_prompts` capability (clamped to the
//! structural floor and the artifact window). One in-flight request per
//! connection; malformed lines get a parse error reply and cost no model
//! time. The line `stats` replies with the unified one-line JSON metrics
//! snapshot (runtime byte ledger + scheduler counters + KV occupancy +
//! TTFT/inter-token/queue-wait histograms) instead of a generation.
//!
//! # Scheduling
//!
//! Reader threads feed an mpsc queue; the engine-owning thread (PJRT types
//! are not Send, so generation is single-threaded — the vLLM-router shape)
//! drains the queue into a [`dschat::serving::Scheduler`] and calls
//! `step()` in a loop. Each step admits queued requests into free batch
//! slots (per-slot prefill into a retired slot's K/V rows), samples one
//! token per live slot, retires finished sequences immediately (EOS or
//! length), and advances all live slots in ONE fused decode call with
//! per-slot positions. A request arriving mid-flight therefore waits one
//! decode step for a free slot instead of a whole fixed-batch generation,
//! and early-EOS slots are refilled instead of burning decode steps on
//! dead rows.
//!
//! # Sampling backend
//!
//! `--backend auto|device|host|rng` picks the [`dschat::sampling`]
//! backend: `device` runs the fused sampling tail inside the `_sampled`
//! artifacts (per-tick fetch is the `[b]` token ids — O(b) instead of the
//! `[b, vocab]` logits matrix), `rng` the `_rng` artifacts whose
//! counter-based Threefry draw also runs ON device (O(b) ids even for
//! stochastic sampling), `host` is the full-row path, and `auto` (default)
//! uses the best tail the artifact set carries. `--decode-chunk N` fuses N
//! decode steps into one `decode_chunk{N}` artifact dispatch (requires the
//! `rng` backend and paged serving; admission/retirement boundaries move
//! to every N steps, dispatches/token drop ~N×).
//!
//! Per-request latency, queue depth, live-slot count, slot utilization /
//! bubble fraction (the scheduler's occupancy counters — the same
//! instrumentation the rollout bench tracks), and host bytes/token (from
//! the engine's byte ledger) are logged to stderr at completion.
//!
//! ```text
//! cargo run --release --example serve -- [--run tiny] [--ckpt runs/tiny/actor.bin] \
//!     [--port 7878] [--backend auto|device|host|rng] [--decode-chunk N] \
//!     [--trace-out trace.json]      # Chrome trace-event JSON, written at exit \
//!     [--demo]                      # --demo: run 6 in-process requests and exit
//! ```

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::rc::Rc;
use std::sync::mpsc;
use std::time::Instant;

use dschat::data::synthetic::{Mode, Prompt, TaskGen, Vocab};
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::Engine;
use dschat::sampling::{DeviceCategorical, DeviceTopK, HostFullRow, SamplerConfig, SamplingBackend};
use dschat::serving::{FinishReason, Request, Scheduler};
use dschat::telemetry::{metrics_snapshot_json, Telemetry};
use dschat::util::argparse::Args;
use dschat::util::fmt_bytes;

struct RequestLine {
    text: String,
    reply: mpsc::Sender<String>,
}

/// A submitted request awaiting completion on the scheduler.
struct Pending {
    prompt: Prompt,
    reply: mpsc::Sender<String>,
    arrived: Instant,
}

fn parse_request(task: &TaskGen, line: &str) -> Option<Prompt> {
    let mut it = line.split_whitespace();
    let mode = match it.next()?.to_lowercase().as_str() {
        "repeat" => Mode::Repeat,
        "constant" => Mode::Constant,
        "count" => Mode::Count,
        "mirror" => Mode::Mirror,
        _ => return None,
    };
    let (lo, hi) = task.vocab.content_range();
    let a = it.next()?.parse::<i32>().ok()?.clamp(lo, hi - 1);
    let b = it.next().and_then(|s| s.parse::<i32>().ok()).unwrap_or(a).clamp(lo, hi - 1);
    // Optional TRUE prompt length: shorter prompts exercise the
    // left-padded variable-length admission path (the scheduler pads them
    // into the fixed artifact window and masks).
    let len = it
        .next()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(task.prompt_len)
        .clamp(TaskGen::MIN_PROMPT_LEN, task.prompt_len);
    // Re-synthesize the canonical prompt encoding at that length.
    let mut tokens = vec![Vocab::BOS, mode.token(), a, b];
    while tokens.len() < len - 1 {
        let i = tokens.len();
        tokens.push(if i % 2 == 0 { a } else { b });
    }
    tokens.push(Vocab::SEP);
    Some(Prompt { mode, a, b, tokens })
}

/// One-line unified metrics snapshot (the `stats` protocol command):
/// runtime byte ledger + scheduler counters + KV occupancy + latency
/// histograms, flattened for the newline-delimited protocol.
fn stats_line(sched: &Scheduler<HybridEngine>) -> String {
    let exec = sched.engine.engine.stats();
    let kv = sched.engine.kv_occupancy();
    metrics_snapshot_json(&exec, Some(&sched.stats), &[], kv.as_ref(), sched.telemetry())
        .replace('\n', " ")
}

/// Loud one-time warning when the runtime fell off the zero-copy
/// fused-tuple output path (previously visible only by reading
/// `ExecStats::fallback_untuples`).
fn warn_fallbacks(sched: &Scheduler<HybridEngine>, warned: &mut bool) {
    if *warned {
        return;
    }
    let n = sched.engine.engine.fallback_untuples();
    if n > 0 {
        *warned = true;
        eprintln!(
            "[serve] WARNING: {n} fused-tuple fallback(s) — artifact outputs are being \
             copied through host literals instead of donated device tuples; throughput \
             is degraded (stale artifacts? re-run `make artifacts`)"
        );
    }
}

/// Parse one queued line and hand it to the scheduler (or reply with a
/// parse error immediately, costing no model time).
fn enqueue(
    rl: RequestLine,
    task: &TaskGen,
    sched: &mut Scheduler<HybridEngine>,
    pending: &mut HashMap<u64, Pending>,
    next_id: &mut u64,
    max_new: usize,
) {
    if rl.text.trim().eq_ignore_ascii_case("stats") {
        let _ = rl.reply.send(stats_line(sched));
        return;
    }
    let Some(prompt) = parse_request(task, &rl.text) else {
        let _ = rl
            .reply
            .send("parse error: expected `repeat|constant|count|mirror a [b [len]]`".into());
        return;
    };
    let id = *next_id;
    *next_id += 1;
    let req = Request { id, prompt: prompt.tokens.clone(), max_new, seed: None, prefix_len: 0 };
    match sched.submit(req) {
        Ok(()) => {
            pending.insert(id, Pending { prompt, reply: rl.reply, arrived: Instant::now() });
        }
        Err(e) => {
            let _ = rl.reply.send(format!("error: {e:#}"));
        }
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let run = args.str("run", "tiny");
    let dir = args.str("artifacts", &format!("artifacts/{run}"));
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, false)?;
    if let Some(ckpt) = args.get("ckpt") {
        pipeline::load_actor(&mut he, ckpt)?;
        eprintln!("loaded checkpoint {ckpt}");
    }
    let m = he.manifest();
    let (sp, sg) = (m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    // Pick the sampling backend: the device tail (O(b) ids fetched per
    // tick) whenever the artifacts carry it, unless overridden.
    let device_ready = m.artifacts.contains_key("decode_slots_sampled")
        && m.artifacts.contains_key("prefill_slot_sampled")
        && m.sample_k > 0;
    let rng_ready = m.has_device_rng() && m.sample_k > 0;
    let padded_prompts = m.padded_prompts;
    let greedy_cfg = SamplerConfig { greedy: true, ..Default::default() };
    // Fused N-token decode: one artifact dispatch advances every live slot
    // by up to N tokens (needs the device-RNG backend + paged serving).
    let chunk = args.usize("decode-chunk", 1);
    enum Backend {
        Host,
        Device,
        Rng,
    }
    let backend = match args.str("backend", "auto").as_str() {
        "device" => Backend::Device,
        "host" => Backend::Host,
        "rng" => Backend::Rng,
        "auto" => {
            if chunk > 1 && rng_ready {
                Backend::Rng
            } else if device_ready {
                Backend::Device
            } else {
                Backend::Host
            }
        }
        other => anyhow::bail!("unknown --backend {other:?} (auto|device|host|rng)"),
    };
    if chunk > 1 && !matches!(backend, Backend::Rng) {
        anyhow::bail!(
            "--decode-chunk {chunk} needs the device-RNG backend (`--backend rng`, or \
             `auto` with `_rng` artifacts present — re-run `make artifacts` if missing)"
        );
    }
    let (mut sampler, backend_desc): (Box<dyn SamplingBackend>, &str) = match backend {
        Backend::Rng => (
            Box::new(DeviceCategorical::new(greedy_cfg, m.sample_k, m.actor.vocab)?),
            "device-RNG (fused categorical draw; per-tick fetch [b] token ids)",
        ),
        Backend::Device => (
            Box::new(DeviceTopK::for_manifest(greedy_cfg, 0, m)?),
            "device (fused sampling tail; per-tick fetch [b] token ids)",
        ),
        Backend::Host => (
            Box::new(HostFullRow::new(greedy_cfg, 0)),
            "host (full logits rows; per-tick fetch [b, vocab] logits)",
        ),
    };
    eprintln!("sampling backend: {backend_desc}");
    if chunk > 1 {
        // Chunked decode serves from the block-paged pool (the
        // `decode_chunk{N}` artifacts take block tables).
        he.use_paged_serving(true)?;
        eprintln!("fused decode chunks: {chunk} tokens per dispatch (paged serving)");
    }

    // Request-lifecycle tracing: enable telemetry on the engine BEFORE the
    // scheduler is built so it adopts the handle; the Chrome trace-event
    // JSON (Perfetto / chrome://tracing) is written at exit.
    let trace_out = args.get("trace-out").map(|s| s.to_string());
    if trace_out.is_some() {
        he.set_telemetry(Telemetry::enabled_default());
    }

    // From here on the scheduler owns the engine (per-slot serving mode).
    let mut sched = Scheduler::new(he)?;
    if chunk > 1 {
        sched.set_decode_chunk(chunk)?;
    }
    let tok0 = sched.engine.stats.gen_tokens;
    let (up0, down0) = sched.engine.engine.bytes_moved();

    if args.bool("demo", false) {
        // In-process demo: more requests than batch slots, so admission,
        // backpressure, and slot reuse are all exercised without a socket.
        // With the `padded_prompts` capability, half the demo requests use
        // short TRUE lengths (4th field) so mixed-length admission,
        // left-padding, and the pad-overhead accounting run too.
        let demo: &[&str] = if padded_prompts {
            &[
                "repeat 10 11",
                "count 20 20 7",
                "mirror 30 31 9",
                "constant 12",
                "count 9 9 5",
                "repeat 40 8 6",
            ]
        } else {
            &["repeat 10 11", "count 20", "mirror 30 31", "constant 12", "count 9", "repeat 40 8"]
        };
        let mut prompts: HashMap<u64, Prompt> = HashMap::new();
        for (i, line) in demo.iter().enumerate() {
            let prompt = parse_request(&task, line).expect("demo lines parse");
            sched.submit(Request {
                id: i as u64,
                prompt: prompt.tokens.clone(),
                max_new: sg,
                seed: None,
                prefix_len: 0,
            })?;
            prompts.insert(i as u64, prompt);
        }
        let mut done = sched.run_until_idle(sampler.as_mut())?;
        done.sort_by_key(|c| c.id);
        for c in &done {
            let p = &prompts[&c.id];
            let resp = c.response();
            println!(
                "{:<16} -> {}  [ground-truth {:.2}; plen {}, {} tok, {:?}, slot {}, waited {} steps]",
                demo[c.id as usize],
                task.detokenize(resp),
                task.reward(p, resp),
                c.prompt_len,
                c.generated,
                c.finish,
                c.slot,
                c.queued_steps,
            );
        }
        let st = &sched.stats;
        let toks = (sched.engine.stats.gen_tokens - tok0).max(1);
        let (up, down) = sched.engine.engine.bytes_moved();
        eprintln!(
            "[demo] {} reqs in {} steps ({} decode calls, slot utilization {:.0}% / \
             bubble {:.0}%, pad overhead {:.0}%, {} eos + {} length retirements), \
             host/tok: {} down {} up",
            st.completed,
            st.steps,
            st.decode_calls,
            100.0 * st.utilization(),
            100.0 * st.bubble_fraction(),
            100.0 * st.pad_fraction(),
            st.retired_eos,
            st.retired_length,
            fmt_bytes((down - down0) as f64 / toks as f64),
            fmt_bytes((up - up0) as f64 / toks as f64),
        );
        warn_fallbacks(&sched, &mut false);
        if let Some(path) = &trace_out {
            std::fs::write(path, sched.telemetry().chrome_trace_json())?;
            eprintln!("[demo] wrote Chrome trace ({} events) to {path}", sched.telemetry().event_count());
        }
        return Ok(());
    }

    let port = args.usize("port", 7878);
    let listener = TcpListener::bind(("127.0.0.1", port as u16))?;
    eprintln!("serving on 127.0.0.1:{port} (one line per request: `mode a [b [len]]`)");

    // Accept loop on worker threads; generation on this (engine-owning)
    // thread. A dropped or broken client connection must never panic a
    // worker — clone/read/write failures just end that connection.
    let (tx, rx) = mpsc::channel::<RequestLine>();
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let Ok(peer) = stream.try_clone() else { return };
                let mut reader = BufReader::new(peer);
                let mut stream = stream;
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => return, // EOF or abrupt disconnect
                        Ok(_) => {}
                    }
                    let (rtx, rrx) = mpsc::channel();
                    let text = line.trim().to_string();
                    if tx.send(RequestLine { text, reply: rtx }).is_err() {
                        return; // server shut down
                    }
                    match rrx.recv() {
                        Ok(resp) => {
                            if writeln!(stream, "{resp}").is_err() {
                                return; // client went away mid-reply
                            }
                        }
                        Err(_) => return,
                    }
                }
            });
        }
    });

    // The continuous-batching loop: block only while fully idle, otherwise
    // drain whatever is queued and run one scheduler step per iteration.
    let mut pending: HashMap<u64, Pending> = HashMap::new();
    let mut next_id = 0u64;
    let mut warned_fallback = false;
    loop {
        if sched.is_idle() {
            match rx.recv() {
                Ok(rl) => enqueue(rl, &task, &mut sched, &mut pending, &mut next_id, sg),
                Err(_) => break, // listener thread gone: drain and exit
            }
        }
        while let Ok(rl) = rx.try_recv() {
            enqueue(rl, &task, &mut sched, &mut pending, &mut next_id, sg);
        }
        let done = match sched.step(sampler.as_mut()) {
            Ok(done) => done,
            Err(e) => {
                // A failed step leaves slot state suspect: fail the
                // affected requests, reset to a fresh serving cache, and
                // keep the listener alive for new traffic.
                eprintln!("[serve] scheduler step failed: {e:#} — resetting serving state");
                for (_, p) in pending.drain() {
                    let _ = p.reply.send(format!("error: {e:#}"));
                }
                if let Err(reset_err) = sched.reset() {
                    eprintln!("[serve] reset failed, shutting down: {reset_err:#}");
                    return Err(reset_err);
                }
                continue;
            }
        };
        if done.is_empty() {
            continue;
        }
        warn_fallbacks(&sched, &mut warned_fallback);
        let toks = (sched.engine.stats.gen_tokens - tok0).max(1);
        let (up, down) = sched.engine.engine.bytes_moved();
        for c in &done {
            let Some(p) = pending.remove(&c.id) else { continue };
            // Per-request failure semantics: the scheduler retires (rather
            // than silently drops) requests whose engine calls kept failing
            // or whose decode-step deadline expired — tell the client which.
            match c.finish {
                FinishReason::Failed { retries } => {
                    let _ = p.reply.send(format!(
                        "error: request failed after {retries} engine retr{} — try again",
                        if retries == 1 { "y" } else { "ies" }
                    ));
                    continue;
                }
                FinishReason::Deadline => {
                    let _ = p.reply.send(
                        "error: request exceeded its decode-step deadline".to_string(),
                    );
                    continue;
                }
                FinishReason::Eos | FinishReason::Length => {}
            }
            let resp = c.response();
            let score = task.reward(&p.prompt, resp);
            let _ = p
                .reply
                .send(format!("{}  [ground-truth {:.2}]", task.detokenize(resp), score));
            eprintln!(
                "[req {}] {:.0}ms  {} tok ({:?})  slot {}  waited {} steps  \
                 queue {}  active {}  util {:.0}% bubble {:.0}%  host/tok: {} down {} up",
                c.id,
                p.arrived.elapsed().as_secs_f64() * 1e3,
                c.generated,
                c.finish,
                c.slot,
                c.queued_steps,
                sched.queue_depth(),
                sched.n_active(),
                100.0 * sched.stats.utilization(),
                100.0 * sched.stats.bubble_fraction(),
                fmt_bytes((down - down0) as f64 / toks as f64),
                fmt_bytes((up - up0) as f64 / toks as f64),
            );
        }
    }
    if let Some(path) = &trace_out {
        std::fs::write(path, sched.telemetry().chrome_trace_json())?;
        eprintln!("[serve] wrote Chrome trace ({} events) to {path}", sched.telemetry().event_count());
    }
    Ok(())
}
