//! Ablations for the design choices DESIGN.md §8 calls out, measured on the
//! real CPU-PJRT stack at `tiny` scale:
//!
//!  1. KV-cache decode vs naive full-recompute generation (the Hybrid
//!     Engine's inference-kernel claim — the real analogue of Figure 5).
//!  2. Device-resident params (`execute_b`) vs host literals per call.
//!  3. EMA on/off and mixture training on/off (the paper's two Step-3
//!     quality features) on the synthetic task.
//!  5. Experience-rollout discipline: fixed lockstep batches vs the
//!     continuous-batching scheduler rollout (`dschat::rollout`) on a
//!     heterogeneous-budget prompt queue — tok/s and slot-bubble fraction
//!     (`--rollout fixed|continuous|both` selects which paths run).
//!  6. Prompt-length traffic mix on the continuous scheduler: all prompts
//!     at the artifact window vs heterogeneous TRUE lengths through the
//!     left-padded variable-length admission path — tok/s, slot-bubble,
//!     and the padded-token overhead fraction (needs artifacts with the
//!     `padded_prompts` capability).
//!
//! ```text
//! cargo run --release --example ablations -- [--run tiny] [--quality] \
//!     [--rollout fixed|continuous|both]
//! ```

use std::rc::Rc;
use std::time::Instant;

use dschat::config::PpoConfig;
use dschat::config::TrainRecipe;
use dschat::data::synthetic::TaskGen;
use dschat::data::{Blend, DataSplit};
use dschat::examples_support::{naive_generate, ppo_probe};
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::{ArtifactSet, Engine, HostTensor};
use dschat::sampling::{HostFullRow, SamplerConfig};
use dschat::util::argparse::Args;
use dschat::util::csv::Table;
use dschat::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let run = args.str("run", "tiny");
    let dir = args.str("artifacts", &format!("artifacts/{run}"));

    ablation_generation(&dir)?;
    ablation_buffers(&dir)?;
    ablation_tp_vs_zero_generation();
    ablation_rollout(&dir, &args.str("rollout", "both"))?;
    ablation_mixed_lengths(&dir)?;
    if args.bool("quality", false) {
        ablation_quality(&dir)?;
    } else {
        println!("(run with --quality for the EMA / mixture-training ablation — slower)");
    }
    Ok(())
}

/// Ablation 5: experience-rollout discipline on a heterogeneous workload —
/// the fixed-batch `generate` loop (every slot held until the slowest row
/// finishes, budgets only honored by truncation) vs the continuous-batching
/// scheduler rollout (EOS/budget-retired slots admit the next prompt
/// immediately). Reports useful tokens/sec and the slot-bubble fraction
/// each discipline pays, through the same accounting the `runtime_e2e`
/// rollout bench uses (`dschat::examples_support`). `which` = `fixed` |
/// `continuous` | `both`.
fn ablation_rollout(dir: &str, which: &str) -> anyhow::Result<()> {
    use dschat::examples_support::{rollout_continuous, rollout_fixed_baseline};

    if !matches!(which, "fixed" | "continuous" | "both") {
        anyhow::bail!("unknown --rollout {which:?} (fixed|continuous|both)");
    }
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, dir, 0, false)?;
    let m = he.manifest();
    if !m.has_serving() {
        println!(
            "(artifacts predate continuous batching — rollout ablation skipped; \
             re-run `make artifacts`)"
        );
        return Ok(());
    }
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(19);
    let n = 4 * b;
    let prompts: Vec<Vec<i32>> = (0..n).map(|_| task.sample_prompt(&mut rng).tokens).collect();
    // Heterogeneous per-request budgets: the straggler variance that makes
    // lockstep batching pay for its barrier.
    let budgets: Vec<usize> =
        (0..n).map(|_| rng.range((sg / 4).max(1) as i64, sg as i64 + 1) as usize).collect();
    let greedy = || HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);

    let mut t = Table::new(
        "Ablation 5 — experience-rollout discipline (real, CPU PJRT)",
        &["Path", "secs", "useful tok/s", "slot bubble"],
    );

    if which != "continuous" {
        let mut sampler = greedy();
        he.generate(&prompts[..b].concat(), &mut sampler)?; // warmup
        let fixed = rollout_fixed_baseline(&mut he, &prompts, &budgets, &mut sampler)?;
        t.row(vec![
            "fixed batch (lockstep generate)".into(),
            format!("{:.3}", fixed.secs),
            format!("{:.1}", fixed.tok_per_sec()),
            format!("{:.0}%", 100.0 * fixed.bubble),
        ]);
    }

    if which != "fixed" {
        let mut sampler = greedy();
        // Warm the per-slot artifacts before timing.
        rollout_continuous(&mut he, &prompts[..b], &budgets[..b], 0, &mut sampler)?;
        let cont = rollout_continuous(&mut he, &prompts, &budgets, 0, &mut sampler)?;
        t.row(vec![
            "continuous (scheduler rollout)".into(),
            format!("{:.3}", cont.secs),
            format!("{:.1}", cont.tok_per_sec()),
            format!("{:.0}%", 100.0 * cont.bubble),
        ]);
    }
    t.print();
    Ok(())
}

/// Ablation 6: prompt-length traffic mix on the continuous scheduler —
/// every prompt at the artifact's fixed window vs heterogeneous TRUE
/// lengths (uniform in [prompt_len/2, prompt_len], left-padded at
/// admission). Reports useful tok/s, slot-bubble, and the padded-token
/// overhead fraction through the SAME `dschat::examples_support`
/// accounting the serve/rollout benches use, so the ablation table and
/// the BENCH JSONs cannot diverge.
fn ablation_mixed_lengths(dir: &str) -> anyhow::Result<()> {
    use dschat::examples_support::{mixed_prompts, rollout_continuous};

    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, dir, 0, false)?;
    let m = he.manifest();
    if !m.has_serving() || !m.padded_prompts {
        println!(
            "(artifacts predate variable-length prompts — mixed-length ablation skipped; \
             re-run `make artifacts`)"
        );
        return Ok(());
    }
    let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
    let task = TaskGen::new(m.actor.vocab, sp, sg);
    let mut rng = Rng::new(29);
    let n = 4 * b;
    let budgets: Vec<usize> =
        (0..n).map(|_| rng.range((sg / 4).max(1) as i64, sg as i64 + 1) as usize).collect();
    let fixed_prompts: Vec<Vec<i32>> =
        (0..n).map(|_| task.sample_prompt(&mut rng).tokens).collect();
    let mixed = mixed_prompts(&task, &mut rng, n, sp / 2);
    let greedy = || HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);

    // Warm the serving artifacts before timing either traffic mix.
    rollout_continuous(&mut he, &fixed_prompts[..b], &budgets[..b], 0, &mut greedy())?;

    let mut t = Table::new(
        "Ablation 6 — prompt-length traffic mix (continuous scheduler, real CPU PJRT)",
        &["Traffic", "secs", "useful tok/s", "slot bubble", "pad overhead"],
    );
    for (label, prompts) in
        [("fixed length (all = prompt_len)", &fixed_prompts), ("mixed length (left-padded)", &mixed)]
    {
        let r = rollout_continuous(&mut he, prompts, &budgets, 0, &mut greedy())?;
        t.row(vec![
            label.into(),
            format!("{:.3}", r.secs),
            format!("{:.1}", r.tok_per_sec()),
            format!("{:.0}%", 100.0 * r.bubble),
            format!("{:.0}%", 100.0 * r.pad_overhead),
        ]);
    }
    t.print();
    Ok(())
}

/// Ablation 4 (simulator): TP vs ZeRO-3 for the *generation* phase — the
/// paper's §5.3 design claim ("using TP in the generation phase instead of
/// ZeRO ... reduces the inter-GPU communication and maintains high GPU
/// memory bandwidth utilization").
fn ablation_tp_vs_zero_generation() {
    use dschat::baselines::ds_he;
    use dschat::config::model;
    use dschat::sim::{a100_80g, simulate_step3, Cluster, Recipe};

    let mut t = Table::new(
        "Ablation 4 — generation-phase sharding (simulator, DS-HE on 8x A100-80G)",
        &["Actor", "gen sharding", "gen secs/iter", "pairs/sec", "slowdown"],
    );
    let critic = model("opt-350m");
    let r = Recipe::default();
    let cluster = Cluster::dgx(a100_80g(), 1);
    for m in ["opt-13b", "opt-30b", "opt-66b"] {
        let a = model(m);
        let tp = simulate_step3(&ds_he(), &a, &critic, &cluster, &r);
        let mut zero_gen = ds_he();
        zero_gen.gen_tp = false; // fall back to ZeRO-3 per-token gathers
        let z = simulate_step3(&zero_gen, &a, &critic, &cluster, &r);
        if let (Some(tp), Some(z)) = (tp, z) {
            t.row(vec![
                m.replace("opt-", "OPT-"),
                "TP (paper)".into(),
                format!("{:.1}", tp.gen_secs),
                format!("{:.3}", tp.pairs_per_sec),
                "1.0x".into(),
            ]);
            t.row(vec![
                String::new(),
                "ZeRO-3 gathers".into(),
                format!("{:.1}", z.gen_secs),
                format!("{:.3}", z.pairs_per_sec),
                format!("{:.1}x slower", tp.pairs_per_sec / z.pairs_per_sec),
            ]);
        }
    }
    t.print();
}

/// Ablation 1: hybrid-engine generation (prefill + decode-attention kernel
/// over a KV cache) vs the naive baseline (full forward per token). This is
/// the real measured counterpart of Figure 5's generation-phase gap.
fn ablation_generation(dir: &str) -> anyhow::Result<()> {
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, dir, 0, false)?;
    let (b, sp, gen_len, vocab) = {
        let m = he.manifest();
        (m.batch, m.prompt_len, m.gen_len, m.actor.vocab)
    };
    let task = TaskGen::new(vocab, sp, gen_len);
    let mut rng = Rng::new(3);
    let reps = 5usize;

    // Same prompts for both paths.
    let mut flat = Vec::with_capacity(b * sp);
    for _ in 0..b {
        flat.extend_from_slice(&task.sample_prompt(&mut rng).tokens);
    }

    let mut sampler = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
    // warmup (compile/caches)
    let warm_kv = he.generate(&flat, &mut sampler)?;
    let t0 = Instant::now();
    for _ in 0..reps {
        he.generate(&flat, &mut sampler)?;
    }
    let kv_secs = t0.elapsed().as_secs_f64() / reps as f64;

    let warm_naive = naive_generate(&mut he, &flat, &mut sampler)?;
    let t1 = Instant::now();
    for _ in 0..reps {
        naive_generate(&mut he, &flat, &mut sampler)?;
    }
    let naive_secs = t1.elapsed().as_secs_f64() / reps as f64;

    assert_eq!(warm_kv, warm_naive, "both paths must produce identical greedy sequences");

    let toks = (b * gen_len) as f64;
    let mut t = Table::new(
        "Ablation 1 — generation path (real, CPU PJRT; Figure 5 analogue)",
        &["Path", "secs/batch", "tokens/sec", "speedup"],
    );
    t.row(vec![
        "naive (full recompute / no KV cache)".into(),
        format!("{naive_secs:.3}"),
        format!("{:.1}", toks / naive_secs),
        "1.0x".into(),
    ]);
    t.row(vec![
        "hybrid engine (KV cache + decode kernel)".into(),
        format!("{kv_secs:.3}"),
        format!("{:.1}", toks / kv_secs),
        format!("{:.1}x", naive_secs / kv_secs),
    ]);
    t.print();
    Ok(())
}

/// Ablation 2: device-resident param buffers (`execute_b`) vs re-uploading
/// host literals on every call, measured on `logprobs_forward`.
fn ablation_buffers(dir: &str) -> anyhow::Result<()> {
    let engine = Rc::new(Engine::cpu()?);
    let arts = ArtifactSet::load(&engine, dir, &["init_actor", "logprobs_forward"])?;
    let m = &arts.manifest;
    let (b, s) = (m.batch, m.seq_len);
    let params = arts.get("init_actor")?.call(&[HostTensor::scalar_i32(0)])?;
    let tokens = HostTensor::I32(
        (0..b * s).map(|i| (i % m.actor.vocab) as i32).collect(),
        vec![b, s],
    );
    let art = arts.get("logprobs_forward")?;
    let reps = 20usize;

    // Host-literal path: params converted + re-uploaded every call.
    {
        // warmup
        let mut inputs: Vec<xla::Literal> =
            params.iter().map(|p| p.to_literal().unwrap()).collect();
        inputs.push(tokens.to_literal()?);
        art.call_literals(&inputs)?;
    }
    let t0 = Instant::now();
    for _ in 0..reps {
        let fresh: Vec<xla::Literal> =
            params.iter().map(|p| p.to_literal().unwrap()).collect();
        let mut inputs = fresh;
        inputs.push(tokens.to_literal()?);
        art.call_literals(&inputs)?;
    }
    let lit_secs = t0.elapsed().as_secs_f64() / reps as f64;

    // Device-buffer path: params uploaded once.
    let bufs: Vec<xla::PjRtBuffer> =
        params.iter().map(|p| engine.upload(p).unwrap()).collect();
    let tok_buf = engine.upload(&tokens)?;
    let mut inputs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
    inputs.push(&tok_buf);
    art.call_buffers(&inputs)?;
    let t1 = Instant::now();
    for _ in 0..reps {
        art.call_buffers(&inputs)?;
    }
    let buf_secs = t1.elapsed().as_secs_f64() / reps as f64;

    let mut t = Table::new(
        "Ablation 2 — parameter residency on the forward hot path",
        &["Path", "secs/call", "speedup"],
    );
    t.row(vec!["host literals re-uploaded per call".into(), format!("{lit_secs:.4}"), "1.0x".into()]);
    t.row(vec![
        "device-resident buffers (execute_b)".into(),
        format!("{buf_secs:.4}"),
        format!("{:.2}x", lit_secs / buf_secs),
    ]);
    t.print();
    Ok(())
}

/// Ablation 3: the paper's optional Step-3 quality features (EMA, mixture
/// training) on the synthetic task, from a shared SFT+RM start.
fn ablation_quality(dir: &str) -> anyhow::Result<()> {
    let mut t = Table::new(
        "Ablation 3 — Step-3 quality features (true reward after 20 PPO iters)",
        &["Variant", "reward first", "reward last"],
    );
    for (label, ptx, ema) in [
        ("PPO only", 0.0f32, None),
        ("+ mixture (ptx=0.2)", 0.2, None),
        ("+ EMA", 0.0, Some(0.992f32)),
        ("+ both", 0.2, Some(0.992)),
    ] {
        let engine = Rc::new(Engine::cpu()?);
        let mut he = HybridEngine::init(engine, dir, 0, ema.is_some())?;
        let m = he.manifest();
        let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
        let mut blend = Blend::new(vec![(task, 1.0)], DataSplit::new(2.0, 4.0, 4.0));
        let mut rng = Rng::new(11);
        let recipe = TrainRecipe { sft_steps: 250, sft_lr: 1e-2, rm_steps: 120, ..Default::default() };
        pipeline::run_sft(&mut he, &mut blend, &recipe, &mut rng, None)?;
        pipeline::run_rm(&mut he, &mut blend, &recipe, &mut rng, None)?;
        let cfg = PpoConfig { ptx_coef: ptx, ema_decay: ema, ..Default::default() };
        let (first, last) = ppo_probe(&mut he, &mut blend, cfg, 20, (2e-4, 8e-4), 5)?;
        t.row(vec![label.into(), format!("{first:.3}"), format!("{last:.3}")]);
    }
    t.print();
    Ok(())
}
