# dschat build plumbing.
#
#   make artifacts   — AOT-lower every RLHF entry point to HLO text +
#                      manifest.json via python/compile/aot.py (the only
#                      step that needs Python/jax; rust is self-contained
#                      afterwards). Referenced by ROADMAP, the integration
#                      tests' failure hints, and scripts/verify.sh.
#   make verify      — tier-1 build/tests plus the smoke benches
#                      (scripts/verify.sh, the one entry point for CI).
#   make test-python — the kernel/model/AOT contract tests that pin what
#                      the rust runtime compiles against.
#   make clean-artifacts — drop generated artifacts (they are not
#                      checked in; see .gitignore).
#
# RUNS selects which deployment shapes to lower (comma-separated, see
# python/compile/configs.py): `make artifacts RUNS=tiny` is enough for
# tier-1 integration tests and the smoke benches.

PYTHON ?= python3
RUNS   ?= tiny,small

.PHONY: artifacts verify test-python clean-artifacts

artifacts:
	cd python && $(PYTHON) -m compile.aot --runs $(RUNS) --out ../artifacts

verify:
	bash scripts/verify.sh

test-python:
	cd python && $(PYTHON) -m pytest tests -q

clean-artifacts:
	rm -rf artifacts
