"""Model-size zoo shared between the L2 model, the AOT lowering, and pytest.

The *real* configs (tiny..medium) are trained from scratch on the synthetic
corpus by the rust pipeline; the paper-scale OPT configs (1.3B..175B) live in
the rust simulator (`rust/src/sim/`), which only needs architecture shapes.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def n_params(self, lm_head_tied: bool = True) -> int:
        """Parameter count (embeddings + blocks + final LN [+ scalar head])."""
        d, v, s = self.d_model, self.vocab, self.max_seq
        per_layer = (
            4 * d * d  # wq wk wv wo
            + 2 * d * self.d_ff  # w1 w2
            + self.d_ff
            + d  # b1 b2
            + 4 * d  # two LayerNorms (g, b)
        )
        return v * d + s * d + self.n_layers * per_layer + 2 * d


@dataclass(frozen=True)
class RunConfig:
    """Shapes baked into the AOT artifacts for one deployment."""

    actor: ModelConfig
    critic: ModelConfig
    batch: int
    prompt_len: int
    gen_len: int
    # Candidate count of the device-side sampling tail: the `_sampled`
    # artifacts return [batch, sample_k] top-k logits+ids instead of the
    # full [batch, vocab] row. Must satisfy 0 < sample_k <= actor.vocab.
    sample_k: int = 32
    # Tokens per KV-cache page of the block-paged serving path (the `_paged`
    # artifacts). Must divide seq_len AND the decode kernel's effective tile
    # `min(DEFAULT_BLOCK_K, seq_len)` — the paged kernel reassembles arena
    # tiles from whole pages so its accumulation order (and therefore its
    # bits) match the contiguous-cache kernel. Shared prefixes are reused at
    # page granularity, so smaller pages share more but table/scatter
    # overhead grows.
    page_size: int = 8

    @property
    def seq_len(self) -> int:
        return self.prompt_len + self.gen_len

    @property
    def kv_blocks_per_slot(self) -> int:
        """Logical pages spanning one slot's full [0, seq_len) window."""
        assert self.seq_len % self.page_size == 0, (self.seq_len, self.page_size)
        return self.seq_len // self.page_size

    @property
    def kv_pages(self) -> int:
        """Physical pool size: every slot's full window plus one spare
        slot's worth (so a retired request's shared prefix can stay
        registered under full admission load) plus page 0, which is
        reserved as the garbage page that dead slots' block tables point
        at — its contents are written by inactive rows and never read."""
        return (self.batch + 1) * self.kv_blocks_per_slot + 1


_MODELS: Dict[str, ModelConfig] = {
    # name                 vocab d_mod layers heads d_ff max_seq
    "nano": ModelConfig("nano", 256, 32, 1, 2, 64, 64),
    "tiny": ModelConfig("tiny", 256, 64, 2, 2, 256, 64),
    "small": ModelConfig("small", 512, 128, 4, 4, 512, 128),
    "base": ModelConfig("base", 512, 256, 6, 8, 1024, 128),
    "medium": ModelConfig("medium", 512, 512, 8, 8, 2048, 256),
}

# Deployment presets mirroring the paper's actor/reward pairing (actor large,
# reward/critic small — e.g. OPT-13B actor + OPT-350M reward).
_RUNS: Dict[str, RunConfig] = {
    "nano": RunConfig(_MODELS["nano"], _MODELS["nano"], batch=2, prompt_len=8, gen_len=8),
    "tiny": RunConfig(_MODELS["tiny"], _MODELS["tiny"], batch=4, prompt_len=16, gen_len=16),
    "small": RunConfig(_MODELS["small"], _MODELS["tiny"], batch=8, prompt_len=32, gen_len=32),
    "base": RunConfig(_MODELS["base"], _MODELS["small"], batch=8, prompt_len=32, gen_len=32),
    "medium": RunConfig(_MODELS["medium"], _MODELS["small"], batch=8, prompt_len=64, gen_len=64),
}


def model_config(name: str) -> ModelConfig:
    return _MODELS[name]


def run_config(name: str) -> RunConfig:
    return _RUNS[name]


def run_config_names():
    return list(_RUNS)


def to_dict(rc: RunConfig) -> dict:
    d = asdict(rc)
    d["seq_len"] = rc.seq_len
    d["kv_pages"] = rc.kv_pages
    d["actor"]["d_head"] = rc.actor.d_head
    d["critic"]["d_head"] = rc.critic.d_head
    d["actor"]["n_params"] = rc.actor.n_params()
    d["critic"]["n_params"] = rc.critic.n_params()
    return d
