"""Hand-rolled Adam(W) + EMA over the explicit flat-param layout.

The optimizer state layout is part of the rust manifest contract:
  opt_state = [t (scalar f32)] + [m_i for every param] + [v_i for every param]
Every train-step artifact takes/returns this flat list; the update itself runs
through the fused L1 Pallas kernel (`kernels/adam_kernel.py`).
"""

import jax.numpy as jnp

from .kernels.adam_kernel import adam_update
from .model import param_spec

BETA1 = 0.9
BETA2 = 0.95
EPS = 1e-8
WEIGHT_DECAY = 0.0


def opt_spec(cfg, kind):
    """Flat (name, shape) list for the optimizer state."""
    pspec = param_spec(cfg, kind)
    return (
        [("t", (1,))]
        + [("m." + n, s) for n, s in pspec]
        + [("v." + n, s) for n, s in pspec]
    )


def init_opt(cfg, kind):
    return [jnp.zeros(s, jnp.float32) for _, s in opt_spec(cfg, kind)]


def split_opt(flat):
    """[t, m..., v...] -> (t, m_list, v_list)."""
    n = (len(flat) - 1) // 2
    return flat[0], flat[1 : 1 + n], flat[1 + n :]


def join_opt(t, ms, vs):
    return [t] + list(ms) + list(vs)


def apply_adam(params_flat, opt_flat, grads_flat, lr):
    """One fused-Adam step over every tensor. lr: traced f32 scalar.

    §Perf note: a multi-tensor variant (concatenate all params -> ONE Pallas
    call, DeepSpeed's multi-tensor-apply) was tried and REVERTED: at these
    model sizes the concat/split copies XLA cannot alias cost ~25% on the
    measured train step (see EXPERIMENTS.md §Perf, change 1). Per-tensor
    kernel calls win on the CPU backend.
    """
    t, ms, vs = split_opt(opt_flat)
    t_new = t + 1.0
    hyper = jnp.stack(
        [
            lr,
            jnp.float32(BETA1),
            jnp.float32(BETA2),
            jnp.float32(EPS),
            jnp.float32(WEIGHT_DECAY),
            t_new[0],
            jnp.float32(0.0),
            jnp.float32(0.0),
        ]
    )
    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(params_flat, ms, vs, grads_flat):
        shape = p.shape
        pn, mn, vn = adam_update(p.ravel(), m.ravel(), v.ravel(), g.ravel(), hyper)
        new_p.append(pn.reshape(shape))
        new_m.append(mn.reshape(shape))
        new_v.append(vn.reshape(shape))
    return new_p, join_opt(t_new, new_m, new_v)
