"""AOT lowering: every RLHF entry point → HLO text + a JSON manifest.

This is the only place Python touches the model after development: `make
artifacts` runs it once per deployment config, and the rust coordinator is
self-contained afterwards.

Interchange is HLO **text**, not `.serialize()`: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact calling convention (the manifest contract with rust):
  * all tensors are flat lists, f32 except token/len/seed tensors (int32);
  * actor params:  P  (len = len(actor_params) in the manifest)
  * critic params: C
  * opt states:    O_P / O_C = [t] + [m...] + [v...]
  * every train step returns (new params..., new opt..., scalar metrics...).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import adam, model
from .configs import run_config, run_config_names, to_dict

# Chunk sizes baked as `decode_chunk{N}` artifacts (N = 1 is the stepwise
# `decode_slots*` path). The scan length is compile-time, so each N is its
# own artifact; the rust scheduler picks one via `--decode-chunk N`.
DECODE_CHUNK_SIZES = (2, 4, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _pspecs(cfg, kind):
    return [_spec(s) for _, s in model.param_spec(cfg, kind)]


def _ospecs(cfg, kind):
    return [_spec(s) for _, s in adam.opt_spec(cfg, kind)]


def build_entries(rc):
    """Returns {name: (fn, [arg_specs], [output names], donate_argnums)}.

    fn takes flat positional args (matching arg_specs) and returns a flat
    tuple. Output names are recorded in the manifest for rust-side parsing.
    `donate_argnums` marks inputs whose buffers XLA may update in place
    (the K/V caches of the decode entry points): the lowered HLO carries the
    `input_output_alias` and the rust runtime must treat those inputs as
    consumed by the call (it does — decode outputs replace the live cache
    handles every step; see rust/src/runtime/mod.rs).
    """
    a, c = rc.actor, rc.critic
    B, S, SP = rc.batch, rc.seq_len, rc.prompt_len
    na = len(model.param_spec(a, "lm"))
    nc = len(model.param_spec(c, "scalar"))
    noa = len(adam.opt_spec(a, "lm"))
    noc = len(adam.opt_spec(c, "scalar"))
    bh_a = B * a.n_heads

    tok = _spec((B, S), jnp.int32)
    mask = _spec((B, S - 1))
    scalar_f = _spec((), jnp.float32)

    entries = {}

    # ---- init -----------------------------------------------------------
    def init_actor(seed):
        return tuple(model.flatten_params(a, "lm", model.init_params(a, "lm", seed)))

    entries["init_actor"] = (init_actor, [_spec((), jnp.int32)], ["actor_params"])

    def init_critic(seed):
        return tuple(model.flatten_params(c, "scalar", model.init_params(c, "scalar", seed)))

    entries["init_critic"] = (init_critic, [_spec((), jnp.int32)], ["critic_params"])

    # ---- step 1: SFT ----------------------------------------------------
    def sft_step(*args):
        P = list(args[:na])
        O = list(args[na : na + noa])
        tokens, msk, lr = args[na + noa :]

        def loss_fn(flat):
            return model.sft_loss(a, model.unflatten_params(a, "lm", flat), tokens, msk)

        loss, grads = jax.value_and_grad(loss_fn)(P)
        P2, O2 = adam.apply_adam(P, O, grads, lr)
        return tuple(P2) + tuple(O2) + (loss,)

    entries["sft_step"] = (
        sft_step,
        _pspecs(a, "lm") + _ospecs(a, "lm") + [tok, mask, scalar_f],
        ["actor_params", "actor_opt", "loss"],
    )

    def sft_eval(*args):
        P = list(args[:na])
        tokens, msk = args[na:]
        return (model.sft_loss(a, model.unflatten_params(a, "lm", P), tokens, msk),)

    entries["sft_eval"] = (sft_eval, _pspecs(a, "lm") + [tok, mask], ["loss"])

    # ---- step 2: reward model -------------------------------------------
    def rm_step(*args):
        C = list(args[:nc])
        O = list(args[nc : nc + noc])
        chosen, rejected, lens_c, lens_r, lr = args[nc + noc :]

        def loss_fn(flat):
            loss, acc = model.rm_pair_loss(
                c, model.unflatten_params(c, "scalar", flat), chosen, rejected, lens_c, lens_r
            )
            return loss, acc

        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(C)
        C2, O2 = adam.apply_adam(C, O, grads, lr)
        return tuple(C2) + tuple(O2) + (loss, acc)

    lens = _spec((B,), jnp.int32)
    entries["rm_step"] = (
        rm_step,
        _pspecs(c, "scalar") + _ospecs(c, "scalar") + [tok, tok, lens, lens, scalar_f],
        ["critic_params", "critic_opt", "loss", "acc"],
    )

    def rm_forward(*args):
        C = list(args[:nc])
        tokens, lens_ = args[nc:]
        return (
            model.rewards_fn(c, model.unflatten_params(c, "scalar", C), tokens, lens_),
        )

    entries["rm_forward"] = (rm_forward, _pspecs(c, "scalar") + [tok, lens], ["rewards"])

    def rm_eval(*args):
        C = list(args[:nc])
        chosen, rejected, lens_c, lens_r = args[nc:]
        loss, acc = model.rm_pair_loss(
            c, model.unflatten_params(c, "scalar", C), chosen, rejected, lens_c, lens_r
        )
        return (loss, acc)

    entries["rm_eval"] = (
        rm_eval,
        _pspecs(c, "scalar") + [tok, tok, lens, lens],
        ["loss", "acc"],
    )

    # ---- step 3: experience forwards -------------------------------------
    def logprobs_forward(*args):
        P = list(args[:na])
        tokens = args[na]
        return (model.token_logprobs(a, model.unflatten_params(a, "lm", P), tokens),)

    entries["logprobs_forward"] = (logprobs_forward, _pspecs(a, "lm") + [tok], ["logprobs"])

    # Full per-position logits — used only by the naive-generation baseline
    # (no KV cache) that the Figure-5 ablation measures against.
    def logits_forward(*args):
        P = list(args[:na])
        tokens = args[na]
        return (model.logits_fn(a, model.unflatten_params(a, "lm", P), tokens),)

    entries["logits_forward"] = (logits_forward, _pspecs(a, "lm") + [tok], ["logits"])

    def critic_forward(*args):
        C = list(args[:nc])
        tokens = args[nc]
        return (model.values_fn(c, model.unflatten_params(c, "scalar", C), tokens),)

    entries["critic_forward"] = (critic_forward, _pspecs(c, "scalar") + [tok], ["values"])

    # ---- step 3: generation ----------------------------------------------
    # Every prompt-taking entry also takes a per-row `start` (valid-start)
    # vector: prompts of true length L <= SP arrive LEFT-PADDED into the
    # fixed [*, SP] shape with start = SP - L, attention masks keys before
    # start, and position embeddings are shifted so the computation is
    # bit-identical to the unpadded exact-length prompt. start == 0 is the
    # full-length case (bit-compatible with the pre-padding artifacts).
    # The capability is recorded as `padded_prompts` in the manifest config.
    start_b = _spec((B,), jnp.int32)

    def gen_prefill(*args):
        P = list(args[:na])
        prompt, start = args[na:]
        return model.prefill(a, model.unflatten_params(a, "lm", P), prompt, S, start)

    entries["prefill"] = (
        gen_prefill,
        _pspecs(a, "lm") + [_spec((B, SP), jnp.int32), start_b],
        ["logits", "k_cache", "v_cache"],
    )

    kv = _spec((a.n_layers, bh_a, S, a.d_head))
    # The K/V cache inputs sit right after the params in every decode-family
    # entry; donating them lets XLA scatter the new K/V rows into the live
    # cache buffers instead of allocating a fresh pair each step.
    kv_donate = (na, na + 1)

    def gen_decode(*args):
        P = list(args[:na])
        kc, vc, token, pos = args[na:]
        return model.decode_step(a, model.unflatten_params(a, "lm", P), kc, vc, token, pos)

    entries["decode_step"] = (
        gen_decode,
        _pspecs(a, "lm") + [kv, kv, _spec((B,), jnp.int32), _spec((1,), jnp.int32)],
        ["logits", "k_cache", "v_cache"],
        kv_donate,
    )

    # ---- serving: iteration-level continuous batching ---------------------
    # `prefill_slot` admits one request into one batch slot of a LIVE cache
    # (other slots' rows untouched); `decode_slots` advances every slot with
    # its own per-row position. Together they let the rust scheduler retire
    # and admit sequences at decode-step boundaries instead of padding fixed
    # batches (OpenRLHF/vLLM-style scheduling in front of the hybrid engine).
    def gen_prefill_slot(*args):
        P = list(args[:na])
        kc, vc, prompt, slot, start = args[na:]
        return model.prefill_slot(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, slot, start
        )

    entries["prefill_slot"] = (
        gen_prefill_slot,
        _pspecs(a, "lm")
        + [kv, kv, _spec((1, SP), jnp.int32), _spec((1,), jnp.int32), _spec((1,), jnp.int32)],
        ["logits", "k_cache", "v_cache"],
    )

    def gen_decode_slots(*args):
        P = list(args[:na])
        kc, vc, token, pos, start = args[na:]
        return model.decode_slots(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, start
        )

    entries["decode_slots"] = (
        gen_decode_slots,
        _pspecs(a, "lm") + [kv, kv, _spec((B,), jnp.int32), _spec((B,), jnp.int32), start_b],
        ["logits", "k_cache", "v_cache"],
        kv_donate,
    )

    # ---- serving: block-paged KV cache ------------------------------------
    # The `_paged` entries replace the per-slot arena rows with a physical
    # page pool [L, h, kv_pages * page_size, dh] indexed through per-slot
    # block tables ([*, max_blocks] int32 page ids): retired pages return to
    # the rust allocator's free list and pages holding a shared system-prompt
    # prefix are mapped into several tables at once (refcounted,
    # copy-on-write). Prompts are FRONT-ALIGNED here (no left-padding;
    # `last` = true length - 1 picks the logits row), which the causal mask
    # keeps bit-identical to the exact-length computation — and therefore to
    # the arena path. The capability is recorded as `paged_kv` (+
    # `page_size` / `kv_pages` geometry) in the manifest config.
    PS = rc.page_size
    MB = rc.kv_blocks_per_slot
    # Bit-match precondition: the paged kernel rebuilds the contiguous
    # kernel's block_k tiles from whole pages, so the page size must divide
    # the effective tile min(DEFAULT_BLOCK_K, seq_len) (configs.py already
    # guarantees PS | seq_len via kv_blocks_per_slot above).
    from .kernels.decode import DEFAULT_BLOCK_K

    assert min(DEFAULT_BLOCK_K, S) % PS == 0, (DEFAULT_BLOCK_K, S, PS)
    kv_paged = _spec((a.n_layers, a.n_heads, rc.kv_pages * PS, a.d_head))
    bt_one = _spec((1, MB), jnp.int32)
    bt_all = _spec((B, MB), jnp.int32)

    def gen_prefill_slot_paged(*args):
        P = list(args[:na])
        kc, vc, prompt, bt, last = args[na:]
        return model.prefill_slot_paged(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, bt, last, PS
        )

    entries["prefill_slot_paged"] = (
        gen_prefill_slot_paged,
        _pspecs(a, "lm")
        + [kv_paged, kv_paged, _spec((1, SP), jnp.int32), bt_one, _spec((1,), jnp.int32)],
        ["logits", "k_cache", "v_cache"],
    )

    def gen_decode_slots_paged(*args):
        P = list(args[:na])
        kc, vc, token, pos, bt = args[na:]
        return model.decode_slots_paged(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, bt, PS
        )

    entries["decode_slots_paged"] = (
        gen_decode_slots_paged,
        _pspecs(a, "lm")
        + [kv_paged, kv_paged, _spec((B,), jnp.int32), _spec((B,), jnp.int32), bt_all],
        ["logits", "k_cache", "v_cache"],
        kv_donate,
    )

    # ---- device-side sampling: the `_sampled` artifact family ---------------
    # Same compute as the entries above plus the fused Pallas sampling tail
    # (kernels/sampling.py): outputs are (ids [B], topk_logits [B, K],
    # topk_ids [B, K], caches) instead of the full [B, vocab] logits row.
    # The rust `SamplingBackend` fetches ids only (greedy, O(B)) or the
    # top-k pair (stochastic, O(B·K)) and finishes the draw host-side.
    K = rc.sample_k
    assert 0 < K <= a.vocab, (K, a.vocab)
    sampled_outputs = ["ids", "topk_logits", "topk_ids", "k_cache", "v_cache"]

    def gen_prefill_sampled(*args):
        P = list(args[:na])
        prompt, start = args[na:]
        return model.prefill_sampled(
            a, model.unflatten_params(a, "lm", P), prompt, S, K, start
        )

    entries["prefill_sampled"] = (
        gen_prefill_sampled,
        _pspecs(a, "lm") + [_spec((B, SP), jnp.int32), start_b],
        sampled_outputs,
    )

    def gen_decode_sampled(*args):
        P = list(args[:na])
        kc, vc, token, pos = args[na:]
        return model.decode_step_sampled(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, K
        )

    entries["decode_step_sampled"] = (
        gen_decode_sampled,
        _pspecs(a, "lm") + [kv, kv, _spec((B,), jnp.int32), _spec((1,), jnp.int32)],
        sampled_outputs,
        kv_donate,
    )

    def gen_prefill_slot_sampled(*args):
        P = list(args[:na])
        kc, vc, prompt, slot, start = args[na:]
        return model.prefill_slot_sampled(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, slot, K, start
        )

    entries["prefill_slot_sampled"] = (
        gen_prefill_slot_sampled,
        _pspecs(a, "lm")
        + [kv, kv, _spec((1, SP), jnp.int32), _spec((1,), jnp.int32), _spec((1,), jnp.int32)],
        sampled_outputs,
    )

    def gen_decode_slots_sampled(*args):
        P = list(args[:na])
        kc, vc, token, pos, start = args[na:]
        return model.decode_slots_sampled(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, K, start
        )

    entries["decode_slots_sampled"] = (
        gen_decode_slots_sampled,
        _pspecs(a, "lm") + [kv, kv, _spec((B,), jnp.int32), _spec((B,), jnp.int32), start_b],
        sampled_outputs,
        kv_donate,
    )

    def gen_prefill_slot_paged_sampled(*args):
        P = list(args[:na])
        kc, vc, prompt, bt, last = args[na:]
        return model.prefill_slot_paged_sampled(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, bt, last, PS, K
        )

    entries["prefill_slot_paged_sampled"] = (
        gen_prefill_slot_paged_sampled,
        _pspecs(a, "lm")
        + [kv_paged, kv_paged, _spec((1, SP), jnp.int32), bt_one, _spec((1,), jnp.int32)],
        sampled_outputs,
    )

    def gen_decode_slots_paged_sampled(*args):
        P = list(args[:na])
        kc, vc, token, pos, bt = args[na:]
        return model.decode_slots_paged_sampled(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, bt, PS, K
        )

    entries["decode_slots_paged_sampled"] = (
        gen_decode_slots_paged_sampled,
        _pspecs(a, "lm")
        + [kv_paged, kv_paged, _spec((B,), jnp.int32), _spec((B,), jnp.int32), bt_all],
        sampled_outputs,
        kv_donate,
    )

    # ---- device RNG: the `_rng` artifact family -----------------------------
    # Same compute as the `_sampled` entries plus the device-side categorical
    # draw (kernels/sampling.py `sample_draw_rows`): a counter-based
    # Threefry-2x32 hash of each row's (request seed, generation step) feeds
    # temperature/top-k/top-p over the top-k candidates ON DEVICE, so
    # stochastic decode fetches O(B) sampled ids instead of O(B·K) candidate
    # rows. Outputs gain `sampled_ids` at index 3; the greedy ids and top-k
    # pair remain so one artifact serves every backend. Per-request stream
    # determinism: the draw is a pure function of (seed, step), independent
    # of slot index, admission order, and chunking.
    seeds_b = _spec((B, 2), jnp.int32)
    steps_b = _spec((B,), jnp.int32)
    seeds_1 = _spec((1, 2), jnp.int32)
    steps_1 = _spec((1,), jnp.int32)
    sparams = _spec((3,))
    rng_outputs = ["ids", "topk_logits", "topk_ids", "sampled_ids", "k_cache", "v_cache"]

    def gen_prefill_rng(*args):
        P = list(args[:na])
        prompt, start, seeds, steps, sp = args[na:]
        return model.prefill_rng(
            a, model.unflatten_params(a, "lm", P), prompt, S, K, seeds, steps, sp, start
        )

    entries["prefill_rng"] = (
        gen_prefill_rng,
        _pspecs(a, "lm") + [_spec((B, SP), jnp.int32), start_b, seeds_b, steps_b, sparams],
        rng_outputs,
    )

    def gen_decode_step_rng(*args):
        P = list(args[:na])
        kc, vc, token, pos, seeds, steps, sp = args[na:]
        return model.decode_step_rng(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, K, seeds, steps, sp
        )

    entries["decode_step_rng"] = (
        gen_decode_step_rng,
        _pspecs(a, "lm")
        + [kv, kv, _spec((B,), jnp.int32), _spec((1,), jnp.int32), seeds_b, steps_b, sparams],
        rng_outputs,
        kv_donate,
    )

    def gen_prefill_slot_rng(*args):
        P = list(args[:na])
        kc, vc, prompt, slot, start, seeds, steps, sp = args[na:]
        return model.prefill_slot_rng(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, slot, K, seeds, steps, sp, start
        )

    entries["prefill_slot_rng"] = (
        gen_prefill_slot_rng,
        _pspecs(a, "lm")
        + [
            kv,
            kv,
            _spec((1, SP), jnp.int32),
            _spec((1,), jnp.int32),
            _spec((1,), jnp.int32),
            seeds_1,
            steps_1,
            sparams,
        ],
        rng_outputs,
    )

    def gen_decode_slots_rng(*args):
        P = list(args[:na])
        kc, vc, token, pos, start, seeds, steps, sp = args[na:]
        return model.decode_slots_rng(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, K, seeds, steps, sp, start
        )

    entries["decode_slots_rng"] = (
        gen_decode_slots_rng,
        _pspecs(a, "lm")
        + [kv, kv, _spec((B,), jnp.int32), _spec((B,), jnp.int32), start_b, seeds_b, steps_b, sparams],
        rng_outputs,
        kv_donate,
    )

    def gen_prefill_slot_paged_rng(*args):
        P = list(args[:na])
        kc, vc, prompt, bt, last, seeds, steps, sp = args[na:]
        return model.prefill_slot_paged_rng(
            a, model.unflatten_params(a, "lm", P), kc, vc, prompt, bt, last, PS, K, seeds, steps, sp
        )

    entries["prefill_slot_paged_rng"] = (
        gen_prefill_slot_paged_rng,
        _pspecs(a, "lm")
        + [
            kv_paged,
            kv_paged,
            _spec((1, SP), jnp.int32),
            bt_one,
            _spec((1,), jnp.int32),
            seeds_1,
            steps_1,
            sparams,
        ],
        rng_outputs,
    )

    def gen_decode_slots_paged_rng(*args):
        P = list(args[:na])
        kc, vc, token, pos, bt, seeds, steps, sp = args[na:]
        return model.decode_slots_paged_rng(
            a, model.unflatten_params(a, "lm", P), kc, vc, token, pos, bt, PS, K, seeds, steps, sp
        )

    entries["decode_slots_paged_rng"] = (
        gen_decode_slots_paged_rng,
        _pspecs(a, "lm")
        + [kv_paged, kv_paged, _spec((B,), jnp.int32), _spec((B,), jnp.int32), bt_all, seeds_b, steps_b, sparams],
        rng_outputs,
        kv_donate,
    )

    # ---- fused N-step decode: the `decode_chunk{N}` artifacts ---------------
    # `jax.lax.scan` over decode_slots_paged + the device-RNG sampling tail:
    # one dispatch advances every live slot by up to N tokens and returns the
    # [N, B] emitted ids — dispatches/token drop ~N× on top of the _rng
    # family's O(B) bytes/token. A per-row latch freezes rows that emit EOS
    # or exhaust `quota` mid-chunk (idempotent re-writes of their last live
    # K/V row, no further RNG consumption), so chunked greedy decode is
    # bit-identical to N stepwise ticks including mid-chunk retirement.
    chunk_outputs = ["chunk_ids", "k_cache", "v_cache"]
    for N in DECODE_CHUNK_SIZES:

        def gen_decode_chunk(*args, _n=N):
            P = list(args[:na])
            kc, vc, token, pos, bt, seeds, steps, quota, frozen, eos, sp = args[na:]
            return model.decode_chunk_paged(
                a,
                model.unflatten_params(a, "lm", P),
                kc,
                vc,
                token,
                pos,
                bt,
                PS,
                _n,
                K,
                seeds,
                steps,
                quota,
                frozen,
                eos,
                sp,
            )

        entries[f"decode_chunk{N}"] = (
            gen_decode_chunk,
            _pspecs(a, "lm")
            + [
                kv_paged,
                kv_paged,
                _spec((B,), jnp.int32),
                _spec((B,), jnp.int32),
                bt_all,
                seeds_b,
                steps_b,
                _spec((B,), jnp.int32),
                _spec((B,), jnp.int32),
                _spec((1,), jnp.int32),
                sparams,
            ],
            chunk_outputs,
            kv_donate,
        )

    # ---- step 3: PPO updates ----------------------------------------------
    arr = _spec((B, S - 1))

    def ppo_actor_step(*args):
        P = list(args[:na])
        O = list(args[na : na + noa])
        tokens, old_logp, adv, msk, ptx_tokens, hyper, lr = args[na + noa :]

        def loss_fn(flat):
            loss, kl, clipfrac = model.ppo_actor_loss(
                a,
                model.unflatten_params(a, "lm", flat),
                tokens,
                old_logp,
                adv,
                msk,
                ptx_tokens,
                hyper,
            )
            return loss, (kl, clipfrac)

        (loss, (kl, clipfrac)), grads = jax.value_and_grad(loss_fn, has_aux=True)(P)
        P2, O2 = adam.apply_adam(P, O, grads, lr)
        return tuple(P2) + tuple(O2) + (loss, kl, clipfrac)

    entries["ppo_actor_step"] = (
        ppo_actor_step,
        _pspecs(a, "lm")
        + _ospecs(a, "lm")
        + [tok, arr, arr, mask, tok, _spec((4,)), scalar_f],
        ["actor_params", "actor_opt", "loss", "approx_kl", "clipfrac"],
    )

    def ppo_critic_step(*args):
        C = list(args[:nc])
        O = list(args[nc : nc + noc])
        tokens, returns, old_values, msk, hyper, lr = args[nc + noc :]

        def loss_fn(flat):
            return model.ppo_critic_loss(
                c,
                model.unflatten_params(c, "scalar", flat),
                tokens,
                returns,
                old_values,
                msk,
                hyper,
            )

        loss, grads = jax.value_and_grad(loss_fn)(C)
        C2, O2 = adam.apply_adam(C, O, grads, lr)
        return tuple(C2) + tuple(O2) + (loss,)

    entries["ppo_critic_step"] = (
        ppo_critic_step,
        _pspecs(c, "scalar") + _ospecs(c, "scalar") + [tok, arr, arr, mask, _spec((4,)), scalar_f],
        ["critic_params", "critic_opt", "loss"],
    )

    # ---- EMA ---------------------------------------------------------------
    def ema_step(*args):
        E = list(args[:na])
        P = list(args[na : 2 * na])
        decay = args[2 * na]
        return tuple(model.ema_update(E, P, decay))

    entries["ema_update"] = (
        ema_step,
        _pspecs(a, "lm") + _pspecs(a, "lm") + [scalar_f],
        ["ema_params"],
    )

    return entries


def lower_entry(fn, specs, donate=()):
    return jax.jit(fn, donate_argnums=tuple(donate)).lower(*specs)


def build(run_name: str, out_dir: str, only=None):
    rc = run_config(run_name)
    os.makedirs(out_dir, exist_ok=True)
    entries = build_entries(rc)
    cfg_dict = to_dict(rc)
    # Capability flag: the prompt-taking generation entries accept per-row
    # valid-start vectors (left-padded variable-length prompts). The rust
    # runtime refuses to admit short prompts against artifact sets that
    # lack it (pre-padding builds parse with the flag absent -> false).
    cfg_dict["padded_prompts"] = True
    # Capability flag: the `_paged` serving entries exist — the KV cache is
    # addressable as a block-paged pool through per-slot block tables, with
    # `page_size` / `kv_pages` (already in cfg_dict via to_dict) giving the
    # pool geometry. Pre-paging builds parse with the flag absent -> false
    # and the rust runtime refuses paged serving against them.
    cfg_dict["paged_kv"] = True
    # Capability flag: the paged entries honor the LAZY block-table
    # contract — every gathered/scattered row is masked by the live length
    # (`idx <= pos` / the causal mask), so table entries past
    # `ceil((pos+1)/page_size)` blocks are never read and may point at
    # garbage page 0. The rust allocator relies on this to grow tables
    # on demand (one page per boundary crossing) and to run the pool
    # OVERSUBSCRIBED (`limit_kv_pages`); it refuses oversubscription
    # against artifact sets that predate the stamp.
    cfg_dict["lazy_kv"] = True
    # Capability flag: the `_rng` entries exist — the categorical draw runs
    # ON DEVICE from a counter-based Threefry hash of (request seed, step),
    # so stochastic decode fetches O(B) sampled ids. The rust runtime
    # refuses the DeviceCategorical backend against artifact sets that lack
    # it (older builds parse with the flag absent -> false).
    cfg_dict["device_rng"] = True
    # Capability list: fused N-step decode artifacts (`decode_chunk{N}`,
    # scan over decode_slots_paged + the device-RNG tail). The rust
    # scheduler refuses `--decode-chunk N` for N not in this list.
    cfg_dict["decode_chunk_sizes"] = list(DECODE_CHUNK_SIZES)
    manifest = {
        "run": run_name,
        "config": cfg_dict,
        "actor_params": [
            {"name": n, "shape": list(s)} for n, s in model.param_spec(rc.actor, "lm")
        ],
        "critic_params": [
            {"name": n, "shape": list(s)} for n, s in model.param_spec(rc.critic, "scalar")
        ],
        "actor_opt": [{"name": n, "shape": list(s)} for n, s in adam.opt_spec(rc.actor, "lm")],
        "critic_opt": [
            {"name": n, "shape": list(s)} for n, s in adam.opt_spec(rc.critic, "scalar")
        ],
        "artifacts": {},
    }
    for name, entry in entries.items():
        if only and name not in only:
            continue
        fn, specs, outputs = entry[:3]
        donate = entry[3] if len(entry) > 3 else ()
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        print(f"[aot:{run_name}] lowering {name} ({len(specs)} inputs) ...", flush=True)
        text = to_hlo_text(lower_entry(fn, specs, donate))
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "outputs": outputs,
            "donates": list(donate),
            "hlo_bytes": len(text),
        }
        if donate and "input_output_alias" not in text.split("\n", 1)[0]:
            raise RuntimeError(
                f"{name}: donate_argnums={donate} did not survive to the HLO "
                "text (input_output_alias missing) — the in-place KV update "
                "contract with the rust runtime would silently degrade"
            )
        with open(path, "w") as f:
            f.write(text)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot:{run_name}] wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="tiny,small", help="comma-separated run configs")
    ap.add_argument("--out", default="../artifacts", help="artifacts root dir")
    ap.add_argument("--only", default=None, help="comma-separated entry subset")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    for run_name in args.runs.split(","):
        if run_name not in run_config_names():
            raise SystemExit(f"unknown run config {run_name!r}; have {run_config_names()}")
        build(run_name, os.path.join(args.out, run_name), only)


if __name__ == "__main__":
    main()
