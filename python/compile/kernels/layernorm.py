"""L1: fused LayerNorm (mean/var/normalize/affine in one VMEM pass).

Mirrors DeepSpeed-Inference's fused LN: one read of x per row instead of the
four separate HLO reductions/broadcasts an unfused graph performs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 32


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (block_rows, d)
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def layernorm(x, g, b, eps=1e-5, block_rows=DEFAULT_BLOCK_ROWS):
    """x: [n, d]; g,b: [d] -> [n, d]."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    assert n % block_rows == 0, (n, block_rows)
    kernel = functools.partial(_layernorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, g, b)
