"""L1: fused causal flash attention (Pallas, interpret mode).

TPU adaptation of the paper's fused CUDA transformer kernels: Q is tiled into
VMEM-sized blocks via BlockSpec (the scratchpad analogue of CUDA shared-memory
tiling); the kernel streams K/V blocks through an online-softmax loop so the
full [s, s] score matrix is never materialized, and the inner `q_blk @ k_blkᵀ`
/ `p @ v_blk` products are MXU-shaped matmuls.

`interpret=True` is mandatory on this image: the CPU PJRT plugin cannot run
Mosaic custom-calls (see /opt/xla-example/README.md). Correctness is pinned to
`ref.attention_ref` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len, scale):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (block_q, dh)
    d_head = q.shape[-1]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    # Causal structure: K blocks strictly after this Q block's last row are
    # fully masked — skip them entirely (dynamic fori_loop upper bound).
    n_kv_blocks = jax.lax.div(qi * block_q + block_q + block_k - 1, block_k)
    n_kv_blocks = jnp.minimum(n_kv_blocks, seq_len // block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_k) — MXU-shaped
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(q_pos[:, None] >= k_pos[None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d_head), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Causal attention forward. q,k,v: [bh, s, dh] -> [bh, s, dh]."""
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (dh**0.5)
    grid = (bh, s // block_q)
    kernel = functools.partial(
        _flash_fwd_kernel, block_q=block_q, block_k=block_k, seq_len=s, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v)


def _flash_fwd_padded_kernel(
    start_ref, q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, seq_len, scale
):
    """`_flash_fwd_kernel` plus a per-row valid-start mask (left padding).

    Keys at positions < start are left-padding and masked alongside the
    causal mask. The online softmax makes the padding contribute exact
    zeros once a real key is seen (alpha = exp(-inf) = 0 rescales any
    leading fully-masked block away), so real positions' outputs are
    bit-identical to the unpadded computation; pad query rows (positions
    < start) produce finite don't-care values (never NaN: a fully masked
    block yields a uniform p, not 0/0).
    """
    qi = pl.program_id(1)
    start = start_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (block_q, dh)
    d_head = q.shape[-1]
    q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    n_kv_blocks = jax.lax.div(qi * block_q + block_q + block_k - 1, block_k)
    n_kv_blocks = jnp.minimum(n_kv_blocks, seq_len // block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # (block_q, block_k)
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        ok = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] >= start)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d_head), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kv_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_padded_fwd(
    q, k, v, start, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K
):
    """Causal attention over left-padded rows (padded-prefill kernel).

    q,k,v: [bh, s, dh]; start: [bh] int32 — row r's real tokens occupy
    positions [start[r], s), keys before start[r] are masked. start == 0
    everywhere reproduces `flash_attention_fwd` bit for bit (the extra
    mask term is vacuously true). Correctness is pinned to
    `ref.attention_padded_ref` by pytest.
    """
    bh, s, dh = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (dh**0.5)
    grid = (bh, s // block_q)
    kernel = functools.partial(
        _flash_fwd_padded_kernel, block_q=block_q, block_k=block_k, seq_len=s, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, i: (b,)),
            pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(start, q, k, v)


def _attention_bwd_ref(q, k, v, g):
    """Recompute-based backward (standard softmax-attention VJP, f32)."""
    s = q.shape[1]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    qf, kf, vf, gf = (a.astype(jnp.float32) for a in (q, k, v, g))
    logits = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    dv = jnp.einsum("bqk,bqd->bkd", p, gf)
    dp = jnp.einsum("bqd,bkd->bqk", gf, vf)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    ds = jnp.where(mask[None], ds, 0.0) * scale
    dq = jnp.einsum("bqk,bkd->bqd", ds, kf)
    dk = jnp.einsum("bqk,bqd->bkd", ds, qf)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@jax.custom_vjp
def flash_attention(q, k, v):
    """Differentiable causal flash attention: Pallas forward, recompute VJP."""
    return flash_attention_fwd(q, k, v)


def _fwd(q, k, v):
    return flash_attention_fwd(q, k, v), (q, k, v)


def _bwd(res, g):
    q, k, v = res
    return _attention_bwd_ref(q, k, v, g)


flash_attention.defvjp(_fwd, _bwd)
