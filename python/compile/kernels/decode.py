"""L1: fused single-token decode attention over the KV cache.

This is the paper's generation hot spot: the experience-generation phase of
RLHF runs the actor once per generated token and is memory-bandwidth-bound
(§5.3). The DeepSpeed-Inference answer is a fused kernel that reads each KV
byte exactly once; this kernel has the same single-pass property, streaming
the cache in blocks through an online softmax so q·Kᵀ → softmax → ·V never
round-trips to HBM.

Cache layout is [bh, smax, dh] (sequence-major) so a cache block is a
contiguous VMEM tile. `pos` arrives as a [1] int32 array (runtime value —
the rust coordinator advances it every token without recompiling).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_K = 32


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, smax, scale):
    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (dh,)
    d_head = q.shape[-1]

    # Only cache blocks containing positions <= pos participate.
    n_blocks = jax.lax.div(pos + block_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = k.astype(jnp.float32) @ q  # (block_k,)
        idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d_head,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def _decode_call(q, k, v, pos, pos_spec, block_k):
    """Shared pallas_call wiring; `pos_spec` is the only thing that differs
    between the shared-position and per-row-position entry points."""
    bh, smax, dh = k.shape
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(_decode_kernel, block_k=block_k, smax=smax, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pos_spec,
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dh), q.dtype),
        interpret=True,
    )(pos, q, k, v)


def decode_attention(q, k, v, pos, block_k=DEFAULT_BLOCK_K):
    """q: [bh, dh]; k,v: [bh, smax, dh]; pos: [1] int32 -> [bh, dh]."""
    return _decode_call(q, k, v, pos, pl.BlockSpec((1,), lambda b: (0,)), block_k)


def decode_attention_pb(q, k, v, pos, block_k=DEFAULT_BLOCK_K):
    """Per-row-position decode attention (continuous batching).

    The same single-pass online-softmax kernel, but every cache row carries
    its own sequence position — the iteration-level scheduler decodes slots
    that sit at different depths in one fused call.

    q: [bh, dh]; k,v: [bh, smax, dh]; pos: [bh] int32 -> [bh, dh].
    """
    return _decode_call(q, k, v, pos, pl.BlockSpec((1,), lambda b: (b,)), block_k)


def _decode_pbs_kernel(pos_ref, start_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, smax, scale):
    """`_decode_kernel` plus a per-row valid-start mask (left-padded cache).

    Cache entries in [start, pos] are the row's real tokens; entries before
    `start` were written by a padded prefill and are masked. A leading
    fully-masked block gives a uniform-p garbage partial that the online
    softmax rescales away (alpha = exp(-inf) = 0) at the first real key, so
    the output is bit-identical to attending the unpadded window alone.
    """
    pos = pos_ref[0]
    start = start_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (dh,)
    d_head = q.shape[-1]

    n_blocks = jax.lax.div(pos + block_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = k.astype(jnp.float32) @ q  # (block_k,)
        idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((idx <= pos) & (idx >= start), s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d_head,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def decode_attention_pbs(q, k, v, pos, start, block_k=DEFAULT_BLOCK_K):
    """Per-row-position decode attention over a LEFT-PADDED cache.

    `decode_attention_pb` with a second per-row vector `start`: row r
    attends cache entries `start[r] ..= pos[r]` only, skipping the
    left-padding a variable-length prefill wrote before its prompt. With
    start == 0 everywhere this is exactly the unpadded kernel's window.

    q: [bh, dh]; k,v: [bh, smax, dh]; pos, start: [bh] int32 -> [bh, dh].
    """
    bh, smax, dh = k.shape
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(_decode_pbs_kernel, block_k=block_k, smax=smax, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dh), q.dtype),
        interpret=True,
    )(pos, start, q, k, v)
