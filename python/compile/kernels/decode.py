"""L1: fused single-token decode attention over the KV cache.

This is the paper's generation hot spot: the experience-generation phase of
RLHF runs the actor once per generated token and is memory-bandwidth-bound
(§5.3). The DeepSpeed-Inference answer is a fused kernel that reads each KV
byte exactly once; this kernel has the same single-pass property, streaming
the cache in blocks through an online softmax so q·Kᵀ → softmax → ·V never
round-trips to HBM.

Cache layout is [bh, smax, dh] (sequence-major) so a cache block is a
contiguous VMEM tile. `pos` arrives as a [1] int32 array (runtime value —
the rust coordinator advances it every token without recompiling).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_K = 32


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, smax, scale):
    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (dh,)
    d_head = q.shape[-1]

    # Only cache blocks containing positions <= pos participate.
    n_blocks = jax.lax.div(pos + block_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = k.astype(jnp.float32) @ q  # (block_k,)
        idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d_head,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def _decode_call(q, k, v, pos, pos_spec, block_k):
    """Shared pallas_call wiring; `pos_spec` is the only thing that differs
    between the shared-position and per-row-position entry points."""
    bh, smax, dh = k.shape
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(_decode_kernel, block_k=block_k, smax=smax, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pos_spec,
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dh), q.dtype),
        interpret=True,
    )(pos, q, k, v)


def decode_attention(q, k, v, pos, block_k=DEFAULT_BLOCK_K):
    """q: [bh, dh]; k,v: [bh, smax, dh]; pos: [1] int32 -> [bh, dh]."""
    return _decode_call(q, k, v, pos, pl.BlockSpec((1,), lambda b: (0,)), block_k)


def decode_attention_pb(q, k, v, pos, block_k=DEFAULT_BLOCK_K):
    """Per-row-position decode attention (continuous batching).

    The same single-pass online-softmax kernel, but every cache row carries
    its own sequence position — the iteration-level scheduler decodes slots
    that sit at different depths in one fused call.

    q: [bh, dh]; k,v: [bh, smax, dh]; pos: [bh] int32 -> [bh, dh].
    """
    return _decode_call(q, k, v, pos, pl.BlockSpec((1,), lambda b: (b,)), block_k)


def _decode_pbs_kernel(pos_ref, start_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, smax, scale):
    """`_decode_kernel` plus a per-row valid-start mask (left-padded cache).

    Cache entries in [start, pos] are the row's real tokens; entries before
    `start` were written by a padded prefill and are masked. A leading
    fully-masked block gives a uniform-p garbage partial that the online
    softmax rescales away (alpha = exp(-inf) = 0) at the first real key, so
    the output is bit-identical to attending the unpadded window alone.
    """
    pos = pos_ref[0]
    start = start_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (dh,)
    d_head = q.shape[-1]

    n_blocks = jax.lax.div(pos + block_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(kb * block_k, block_k), slice(None)))
        s = k.astype(jnp.float32) @ q  # (block_k,)
        idx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((idx <= pos) & (idx >= start), s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d_head,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def _decode_paged_kernel(
    pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, *, block_k, page_size, smax, scale
):
    """`_decode_kernel` over a BLOCK-PAGED cache (per-slot block tables).

    The grid row's K/V live scattered across a physical page pool; the
    row's block table maps logical block kb -> physical page id. Each
    `block_k` tile is reassembled from its `block_k / page_size` whole
    pages (the config layer guarantees divisibility), so the online-softmax
    update sequence — one max/exp/rescale per block_k tile over the logical
    window [0, smax) — is IDENTICAL to the contiguous-cache kernel's, and
    the output is bit-identical to `decode_attention_pb` over the gathered
    logical cache. `smax` here is the LOGICAL window (max_blocks *
    page_size), not the pool length.
    """
    pos = pos_ref[0]
    q = q_ref[0].astype(jnp.float32) * scale  # (dh,)
    d_head = q.shape[-1]
    pages_per_block = block_k // page_size

    n_blocks = jax.lax.div(pos + block_k, block_k)

    def load_tile(ref, tb):
        # Reassemble logical tile tb from its whole pages, in logical order.
        parts = []
        for r in range(pages_per_block):  # static unroll
            page = pl.load(bt_ref, (0, tb * pages_per_block + r))
            parts.append(pl.load(ref, (0, pl.dslice(page * page_size, page_size), slice(None))))
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)

    def body(tb, carry):
        m, l, acc = carry
        k = load_tile(k_ref, tb)
        v = load_tile(v_ref, tb)
        s = k.astype(jnp.float32) @ q  # (block_k,)
        idx = tb * block_k + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(idx <= pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max())
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum()
        acc_new = acc * alpha + p @ v.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.float32(NEG_INF)
    l0 = jnp.float32(0.0)
    acc0 = jnp.zeros((d_head,), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def decode_attention_paged(q, k_pool, v_pool, pos, block_tables, page_size, block_k=DEFAULT_BLOCK_K):
    """Block-paged per-row-position decode attention (paged serving path).

    K/V live in a physical page pool shared by every slot; each slot's
    block table maps its logical blocks onto pool pages (pages holding a
    shared prompt prefix may appear in several tables). All heads of a
    slot share the slot's table. The tile math matches the contiguous
    kernel's exactly (see `_decode_paged_kernel`), so paged serving is
    bit-identical to the arena path for the same logical cache contents.

    LAZY-TABLE CONTRACT (`lazy_kv` manifest capability): only the first
    `ceil((pos+1) / page_size)` entries of a row's block table need to
    name real pages. The kernel walks `ceil((pos+1) / block_k)` tiles and
    masks every score at `idx > pos` to -inf, so a dead entry's K feeds a
    zeroed softmax weight and its V is multiplied by 0 — dead tail entries
    may therefore alias any valid pool page (the allocator points them at
    garbage page 0, which is kept finite and never handed out). This is
    what lets the rust `PageLedger` grow tables one page per boundary
    crossing and run the pool oversubscribed instead of reserving
    `max_blocks` pages up front.

    q: [b*h, dh] (row = slot * h + head);
    k_pool, v_pool: [h, n_pages * page_size, dh];
    pos: [b*h] int32 (logical token index per row);
    block_tables: [b, max_blocks] int32 -> [b*h, dh].
    """
    h, pool_len, dh = k_pool.shape
    bh = q.shape[0]
    b, max_blocks = block_tables.shape
    assert bh == b * h, (bh, b, h)
    assert pool_len % page_size == 0, (pool_len, page_size)
    smax = max_blocks * page_size  # logical window
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    assert block_k % page_size == 0, (block_k, page_size)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(
        _decode_paged_kernel, block_k=block_k, page_size=page_size, smax=smax, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, max_blocks), lambda i: (i // h, 0)),
            pl.BlockSpec((1, dh), lambda i: (i, 0)),
            pl.BlockSpec((1, pool_len, dh), lambda i: (i % h, 0, 0)),
            pl.BlockSpec((1, pool_len, dh), lambda i: (i % h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dh), q.dtype),
        interpret=True,
    )(pos, block_tables, q, k_pool, v_pool)


def decode_attention_pbs(q, k, v, pos, start, block_k=DEFAULT_BLOCK_K):
    """Per-row-position decode attention over a LEFT-PADDED cache.

    `decode_attention_pb` with a second per-row vector `start`: row r
    attends cache entries `start[r] ..= pos[r]` only, skipping the
    left-padding a variable-length prefill wrote before its prompt. With
    start == 0 everywhere this is exactly the unpadded kernel's window.

    q: [bh, dh]; k,v: [bh, smax, dh]; pos, start: [bh] int32 -> [bh, dh].
    """
    bh, smax, dh = k.shape
    block_k = min(block_k, smax)
    assert smax % block_k == 0, (smax, block_k)
    scale = 1.0 / (dh**0.5)
    kernel = functools.partial(_decode_pbs_kernel, block_k=block_k, smax=smax, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1,), lambda b: (b,)),
            pl.BlockSpec((1, dh), lambda b: (b, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, smax, dh), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, dh), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, dh), q.dtype),
        interpret=True,
    )(pos, start, q, k, v)
