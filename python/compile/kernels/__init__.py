"""L1 Pallas kernels (build-time only; lowered into the AOT HLO artifacts)."""

from .attention import flash_attention, flash_attention_fwd, flash_attention_padded_fwd
from .decode import decode_attention, decode_attention_pb, decode_attention_pbs
from .layernorm import layernorm
from .adam_kernel import adam_update
from .sampling import argmax_rows, top_k_rows

__all__ = [
    "flash_attention",
    "flash_attention_fwd",
    "flash_attention_padded_fwd",
    "decode_attention",
    "decode_attention_pb",
    "decode_attention_pbs",
    "layernorm",
    "adam_update",
    "argmax_rows",
    "top_k_rows",
]
