"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest (and the custom-VJP gradient
checks) compare each kernel against the function here under hypothesis-driven
shape/dtype sweeps.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, scale=None):
    """Causal softmax attention. q,k,v: [bh, s, dh] -> [bh, s, dh]."""
    s = q.shape[1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, pos):
    """Single-position attention over a KV cache.

    q: [bh, dh]; k,v: [bh, smax, dh]; pos: scalar int32 (index of the current
    token; cache entries 0..pos inclusive are valid) -> [bh, dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(k.shape[1])
    logits = jnp.where(idx[None, :] <= pos, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bk,bkd->bd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_pb_ref(q, k, v, pos):
    """Per-row-position decode attention (continuous-batching oracle).

    q: [bh, dh]; k,v: [bh, smax, dh]; pos: [bh] int32 (each row's current
    token index; entries 0..pos[r] inclusive are valid) -> [bh, dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(k.shape[1])
    logits = jnp.where(idx[None, :] <= pos[:, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bk,bkd->bd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_pbs_ref(q, k, v, pos, start):
    """Per-row-position decode attention over a LEFT-PADDED cache (oracle).

    Like `decode_attention_pb_ref` but each row additionally carries a
    `start` (its valid-start: the first cache entry holding a real token —
    entries before it are left-padding written by a padded prefill and must
    never be attended). Valid window per row: start[r] <= idx <= pos[r].

    q: [bh, dh]; k,v: [bh, smax, dh]; pos, start: [bh] int32 -> [bh, dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    idx = jnp.arange(k.shape[1])
    valid = (idx[None, :] <= pos[:, None]) & (idx[None, :] >= start[:, None])
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bk,bkd->bd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def gather_paged_kv(pool, block_tables, page_size, n_heads):
    """Assemble the logical per-slot cache from a block-paged pool.

    pool: [h, n_pages * page_size, dh] (physical page p occupies rows
    [p * page_size, (p+1) * page_size)); block_tables: [b, max_blocks]
    int32 mapping each slot's logical block kb to its physical page id.
    Returns the logically-contiguous [b*h, max_blocks * page_size, dh]
    cache (row r = slot * h + head) — pure data movement, bit-exact.
    """
    b, mb = block_tables.shape
    # [b, mb, page_size] physical row index of every logical position.
    rows = block_tables[:, :, None] * page_size + jnp.arange(page_size)[None, None, :]
    rows = rows.reshape(b, mb * page_size)
    gathered = pool[:, rows]  # [h, b, smax, dh]
    h, _, smax, dh = gathered.shape
    assert h == n_heads, (h, n_heads)
    return gathered.transpose(1, 0, 2, 3).reshape(b * h, smax, dh)


def decode_attention_paged_ref(q, k_pool, v_pool, pos, block_tables, page_size):
    """Block-paged decode attention (oracle): per-slot block tables map
    logical positions onto a shared physical page pool.

    The gather is pure data movement, so this is BIT-IDENTICAL to
    `decode_attention_pb_ref` over the logically-contiguous cache — the
    paged serving path's numerics equal the contiguous (arena) path's by
    construction. Every head of a slot shares the slot's table.

    q: [b*h, dh]; k_pool, v_pool: [h, n_pages * page_size, dh];
    pos: [b*h] int32 (logical token index per row);
    block_tables: [b, max_blocks] int32 -> [b*h, dh].
    """
    b = block_tables.shape[0]
    h = q.shape[0] // b
    k = gather_paged_kv(k_pool, block_tables, page_size, h)
    v = gather_paged_kv(v_pool, block_tables, page_size, h)
    return decode_attention_pb_ref(q, k, v, pos)


def attention_padded_ref(q, k, v, start):
    """Causal attention over LEFT-PADDED rows (padded-prefill oracle).

    Each row's real tokens occupy positions [start[r], s); positions before
    start[r] are padding whose keys must never be attended (their query
    rows produce don't-care output). The valid window for query position i
    is therefore start[r] <= j <= i — which makes the real positions'
    outputs bit-identical to running the unpadded length-(s - start) rows
    through `attention_ref` (padding contributes exact zeros to the
    softmax-weighted sums). start == 0 reproduces `attention_ref` exactly.

    q,k,v: [bh, s, dh]; start: [bh] int32 -> [bh, s, dh].
    """
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qi = jnp.arange(s)
    causal = qi[:, None] >= qi[None, :]
    valid = qi[None, None, :] >= start[:, None, None]
    logits = jnp.where(causal[None] & valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def argmax_ref(x):
    """Row-wise greedy token ids. x: [b, vocab] -> [b] int32 (first max wins)."""
    return jnp.argmax(x, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Counter-based device RNG (Threefry-2x32) + the categorical draw it feeds.
#
# The device sampling tail draws its own uniform from a keyed counter hash
# instead of consuming a host RNG stream: the draw for generation step `s` of
# a request is a pure function of (request_seed, s), so per-request stream
# determinism survives admission reordering, slot reassignment, and N-step
# fused dispatch — the same replayability contract rollout::request_seed
# gives the host sampler, moved on device. The rust runtime mirrors the hash
# bit-for-bit (rust/src/sampling/device.rs); both sides pin the Random123
# known-answer vectors.
# ---------------------------------------------------------------------------

_THREEFRY_ROT = (13, 15, 26, 6, 17, 29, 16, 24)


def _rotl32(x, r):
    x = x.astype(jnp.uint32)
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def threefry2x32_ref(k0, k1, x0, x1):
    """Threefry-2x32, 20 rounds (Random123). Inputs broadcastable int/uint32
    arrays (int32 reinterpreted as uint32); returns (uint32, uint32)."""
    k0, k1, x0, x1 = (jnp.asarray(v).astype(jnp.uint32) for v in (k0, k1, x0, x1))
    ks = (k0, k1, k0 ^ k1 ^ jnp.uint32(0x1BD11BDA))
    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for j in range(5):
        for r in _THREEFRY_ROT[(j % 2) * 4 : (j % 2) * 4 + 4]:
            x0 = x0 + x1
            x1 = _rotl32(x1, r) ^ x0
        x0 = x0 + ks[(j + 1) % 3]
        x1 = x1 + ks[(j + 2) % 3] + jnp.uint32(j + 1)
    return x0, x1


def counter_uniform_ref(seeds, steps):
    """Keyed uniform in [0, 1): one draw per row, no carried state.

    seeds: [b, 2] int32 — the request seed's (hi, lo) words (the rust side
    splits its u64 `request_seed`); steps: [b] int32 — the row's generation
    step counter. Returns [b] f32. The u32 -> f32 mapping is the host RNG's
    `(u >> 8) * 2^-24` so both samplers draw from the same 24-bit grid.
    """
    x0, _ = threefry2x32_ref(seeds[..., 0], seeds[..., 1], steps, jnp.zeros_like(steps))
    return (x0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def draw_index_ref(vals, u, temp, top_k, top_p):
    """Categorical draw over ONE row of descending top-k candidate logits.

    vals: [k] f32 (sorted descending); u: scalar f32 in [0, 1); temp <= 0
    selects argmax (index 0); top_k <= 0 disables the count cutoff; top_p
    keeps the smallest prefix whose mass reaches top_p (the first candidate
    is always kept). Returns the scalar int32 index into the candidate row.
    Shared verbatim by the Pallas kernel and the vectorized oracle so the
    two are bit-identical by construction.
    """
    k = vals.shape[0]
    j = jnp.arange(k, dtype=jnp.float32)
    kk = jnp.where(top_k > 0, top_k, jnp.float32(k))
    scaled = jnp.where(j < kk, vals.astype(jnp.float32) / jnp.maximum(temp, 1e-6), NEG_INF)
    scaled = scaled - scaled[0]  # stabilize: top candidate pins exp at 1
    p = jnp.exp(scaled)
    p = p / p.sum()
    csum = jnp.cumsum(p)
    w = jnp.where((csum - p) < top_p, p, 0.0)
    cw = jnp.cumsum(w)
    idx = jnp.argmax(cw > u * cw[-1]).astype(jnp.int32)
    return jnp.where(temp > 0, idx, 0).astype(jnp.int32)


def device_draw_ref(tv, ti, seeds, steps, sparams):
    """Device-side categorical draw (sampling-tail oracle).

    tv, ti: [b, k] top-k candidate logits/ids (descending); seeds: [b, 2]
    int32; steps: [b] int32; sparams: [3] f32 = (temperature, top_k, top_p).
    Returns [b] int32 sampled token ids; temperature <= 0 is greedy (ti[:, 0],
    bit-equal to argmax by the shared first-index tie-break).
    """
    u = counter_uniform_ref(seeds, steps)
    idx = jax.vmap(lambda v, uu: draw_index_ref(v, uu, sparams[0], sparams[1], sparams[2]))(
        tv, u
    )
    return jnp.take_along_axis(ti, idx[:, None], axis=1)[:, 0].astype(jnp.int32)


def top_k_ref(x, k):
    """Row-wise top-k candidates (sampling-tail oracle).

    x: [b, vocab] -> (values [b, k] f32, indices [b, k] int32), sorted by
    descending value, ties toward the lower index — `lax.top_k` semantics,
    which the iterative-selection kernel reproduces exactly.
    """
    v, i = jax.lax.top_k(x.astype(jnp.float32), k)
    return v, i.astype(jnp.int32)


def layernorm_ref(x, g, b, eps=1e-5):
    """LayerNorm over the last axis. x: [n, d]; g,b: [d]."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * g.astype(jnp.float32) + b.astype(jnp.float32)
    return y.astype(x.dtype)


def adam_ref(p, m, v, g, lr, b1, b2, eps, wd, t):
    """One Adam(W) step with bias correction. All arrays 1-D, same length."""
    pf, mf, vf, gf = (a.astype(jnp.float32) for a in (p, m, v, g))
    m_new = b1 * mf + (1.0 - b1) * gf
    v_new = b2 * vf + (1.0 - b2) * gf * gf
    mhat = m_new / (1.0 - b1**t)
    vhat = v_new / (1.0 - b2**t)
    p_new = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * pf)
    return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)
