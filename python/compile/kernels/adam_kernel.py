"""L1: fused Adam(W) update kernel.

DeepSpeed ships fused CUDA optimizers so the p/m/v/g streams are read once and
written once per step; this is the Pallas equivalent. Hyper-parameters arrive
as a [8] f32 array (lr, b1, b2, eps, wd, t, _, _) so the learning-rate schedule
is a runtime input — the rust coordinator changes lr without recompiling.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _adam_kernel(h_ref, p_ref, m_ref, v_ref, g_ref, po_ref, mo_ref, vo_ref):
    lr, b1, b2, eps, wd, t = (h_ref[i] for i in range(6))
    p = p_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    # b^t via exp(t*log(b)) — t is a runtime value.
    bc1 = 1.0 - jnp.exp(t * jnp.log(b1))
    bc2 = 1.0 - jnp.exp(t * jnp.log(b2))
    update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps) + wd * p
    po_ref[...] = (p - lr * update).astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


def adam_update(p, m, v, g, hyper, block=DEFAULT_BLOCK):
    """One fused Adam(W) step over 1-D tensors.

    p,m,v,g: [n] (n need not divide `block`; the tail is padded internally).
    hyper: [8] f32 = (lr, b1, b2, eps, wd, t, _, _). Returns (p', m', v').
    """
    n = p.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        p, m, v, g = (jnp.pad(a, (0, pad)) for a in (p, m, v, g))
    npad = n + pad
    shapes = [jax.ShapeDtypeStruct((npad,), a.dtype) for a in (p, m, v)]
    specs = [pl.BlockSpec((block,), lambda i: (i,)) for _ in range(4)]
    out = pl.pallas_call(
        _adam_kernel,
        grid=(npad // block,),
        in_specs=[pl.BlockSpec((8,), lambda i: (0,))] + specs,
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in range(3)],
        out_shape=shapes,
        interpret=True,
    )(hyper, p, m, v, g)
    if pad:
        out = tuple(a[:n] for a in out)
    return tuple(out)
