"""L1: fused sampling tail — the device half of token sampling.

Until now every decode artifact ended at the logits matmul and the full
`[b, vocab]` row crossed to the host for sampling, the dominant remaining
host↔device traffic of the generation loop (the inference-side bottleneck
DeepSpeed-Chat's hybrid engine targets; OpenRLHF makes the same point about
the RLHF sampling tail). These kernels run the heavy half of sampling on
device so the host sees only what it needs:

  * `argmax_rows` — greedy decoding: `[b]` token ids, O(b) bytes/step.
  * `top_k_rows`  — stochastic decoding: the `[b, k]` largest candidate
    logits + their vocabulary indices, O(b·k) bytes/step. The host finishes
    temperature / top-p / the categorical draw over the k candidates so the
    seeded rust RNG stays the single source of randomness (generation
    remains bit-deterministic and EOS/length retirement stays host-side).

Tie-breaking is first-index-wins in both kernels (matching `jax.lax.top_k`
and the rust host sampler's argmax), which is what makes device-greedy
generation bit-identical to the host full-row path.

Selection is iterative (k passes of max+mask over the row held in VMEM):
k ≪ vocab and the row is already resident from the logits matmul, so the
tail adds O(k·vocab) flops to a step that just did O(d·vocab) — noise — in
exchange for shrinking the per-step fetch by vocab/k.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF, draw_index_ref, threefry2x32_ref


def _argmax_kernel(x_ref, o_ref):
    x = pl.load(x_ref, (pl.dslice(0, 1), slice(None)))[0].astype(jnp.float32)
    o_ref[...] = jnp.argmax(x).astype(jnp.int32)[None]


def argmax_rows(x):
    """Row-wise argmax. x: [b, vocab] -> [b] int32 (first max wins)."""
    b, vocab = x.shape
    return pl.pallas_call(
        _argmax_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, vocab), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(x)


def _topk_kernel(x_ref, vals_ref, idx_ref, *, k):
    x = pl.load(x_ref, (pl.dslice(0, 1), slice(None)))[0].astype(jnp.float32)

    def body(j, carry):
        x, vals, idx = carry
        m = x.max()
        i = jnp.argmax(x).astype(jnp.int32)
        vals = vals.at[j].set(m)
        idx = idx.at[j].set(i)
        x = x.at[i].set(NEG_INF)
        return x, vals, idx

    _, vals, idx = jax.lax.fori_loop(
        0,
        k,
        body,
        (x, jnp.zeros((k,), jnp.float32), jnp.zeros((k,), jnp.int32)),
    )
    vals_ref[...] = vals[None]
    idx_ref[...] = idx[None]


def top_k_rows(x, k):
    """Row-wise top-k by iterative selection.

    x: [b, vocab] -> (values [b, k] f32, indices [b, k] int32), both sorted
    by descending value, ties broken toward the lower vocabulary index.
    """
    b, vocab = x.shape
    assert 0 < k <= vocab, (k, vocab)
    kernel = functools.partial(_topk_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, vocab), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, k), jnp.int32),
        ],
        interpret=True,
    )(x)


def _draw_kernel(tv_ref, ti_ref, seeds_ref, steps_ref, sp_ref, o_ref):
    vals = pl.load(tv_ref, (pl.dslice(0, 1), slice(None)))[0].astype(jnp.float32)
    ids = pl.load(ti_ref, (pl.dslice(0, 1), slice(None)))[0]
    seed = pl.load(seeds_ref, (pl.dslice(0, 1), slice(None)))[0]
    step = pl.load(steps_ref, (pl.dslice(0, 1),))[0]
    sp = pl.load(sp_ref, (pl.dslice(0, 3),))
    x0, _ = threefry2x32_ref(seed[0], seed[1], step, jnp.int32(0))
    u = (x0 >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    idx = draw_index_ref(vals, u, sp[0], sp[1], sp[2])
    o_ref[...] = ids[idx].astype(jnp.int32)[None]


def sample_draw_rows(tv, ti, seeds, steps, sparams):
    """Device-side categorical draw over top-k candidate rows.

    The per-row uniform comes from the counter-based Threefry-2x32 hash of
    `(seeds[r], steps[r])` — a pure function of the request key and its
    generation step, so the draw stream is reproducible regardless of which
    slot the request occupies, when it was admitted, or whether the step ran
    alone or inside a fused N-step chunk. The draw itself is
    temperature -> top-k cutoff -> top-p prefix -> categorical over the
    descending candidates; temperature <= 0 degrades to argmax (index 0).

    tv, ti: [b, k] (descending, from `top_k_rows`); seeds: [b, 2] int32;
    steps: [b] int32; sparams: [3] f32 (temperature, top_k, top_p).
    Returns sampled token ids [b] int32.
    """
    b, k = tv.shape
    assert ti.shape == (b, k) and seeds.shape == (b, 2) and steps.shape == (b,)
    return pl.pallas_call(
        _draw_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(tv, ti, seeds, steps, sparams)
