"""L2: the OPT-style decoder-only transformer and every RLHF loss/graph.

Everything here is traced once by `aot.py` and lowered to HLO text; the rust
coordinator (L3) only ever sees the lowered artifacts. The compute hot spots —
causal attention (training/prefill), decode attention over the KV cache
(generation), LayerNorm — call the L1 Pallas kernels in `kernels/`.

Architecture (OPT-flavoured): learned positional embeddings, pre-LN blocks
with ReLU MLPs, tied LM head for the actor, scalar value head for the
reward/critic model (one "scalar" model serves both: per-position outputs are
the critic values, the value at the last real token is the RM reward — the
same weight-sharing InstructGPT uses when initializing the critic from the
RM).
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.attention import (
    flash_attention,
    flash_attention_fwd,
    flash_attention_padded_fwd,
)
from .kernels.decode import (
    decode_attention,
    decode_attention_paged,
    decode_attention_pb,
    decode_attention_pbs,
)
from .kernels.layernorm import layernorm as layernorm_pallas
from .kernels.sampling import argmax_rows, sample_draw_rows, top_k_rows

# ---------------------------------------------------------------------------
# LayerNorm: Pallas forward + analytic VJP (pallas_call has no autodiff rule).
# ---------------------------------------------------------------------------


@jax.custom_vjp
def layernorm(x, g, b):
    """x: [n, d]; g,b: [d]."""
    return layernorm_pallas(x, g, b)


def _ln_fwd(x, g, b):
    return layernorm_pallas(x, g, b), (x, g)


def _ln_bwd(res, dy):
    x, g = res
    eps = 1e-5
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = (xf - mu) * inv
    dg = (dyf * xhat).sum(0)
    db = dyf.sum(0)
    dxhat = dyf * g.astype(jnp.float32)
    dx = inv * (
        dxhat - dxhat.mean(-1, keepdims=True) - xhat * (dxhat * xhat).mean(-1, keepdims=True)
    )
    return dx.astype(x.dtype), dg.astype(g.dtype), db.astype(x.dtype)


layernorm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# Parameters: explicit, deterministic flat order (the manifest contract with
# the rust runtime — rust addresses params purely by position).
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig, kind: str):
    """kind: 'lm' (actor, tied head) or 'scalar' (reward/critic, value head)."""
    d, v, s, ff = cfg.d_model, cfg.vocab, cfg.max_seq, cfg.d_ff
    spec = [("embed", (v, d)), ("pos_embed", (s, d))]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        spec += [
            (p + "ln1_g", (d,)),
            (p + "ln1_b", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ln2_g", (d,)),
            (p + "ln2_b", (d,)),
            (p + "w1", (d, ff)),
            (p + "b1", (ff,)),
            (p + "w2", (ff, d)),
            (p + "b2", (d,)),
        ]
    spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    if kind == "scalar":
        spec += [("vhead", (d,)), ("vbias", (1,))]
    return spec


def init_params(cfg: ModelConfig, kind: str, seed):
    """seed: traced int32 scalar — init is itself an AOT artifact."""
    key = jax.random.PRNGKey(seed)
    params = {}
    scale = 0.02
    resid_scale = scale / jnp.sqrt(jnp.float32(2 * cfg.n_layers))
    for i, (name, shape) in enumerate(param_spec(cfg, kind)):
        sub = jax.random.fold_in(key, i)
        leaf = name.split(".")[-1]
        if leaf in ("ln1_g", "ln2_g", "lnf_g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif leaf in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2", "vbias"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif leaf in ("wo", "w2"):  # residual-path projections: scaled init
            params[name] = resid_scale * jax.random.normal(sub, shape, jnp.float32)
        else:
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def flatten_params(cfg, kind, params):
    return [params[n] for n, _ in param_spec(cfg, kind)]


def unflatten_params(cfg, kind, flat):
    spec = param_spec(cfg, kind)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {n: a for (n, _), a in zip(spec, flat)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _attn_train(cfg, params, i, x):
    """Full-sequence causal attention (flash kernel). x: [b, s, d]."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    p = f"l{i}."
    q = x @ params[p + "wq"]
    k = x @ params[p + "wk"]
    v = x @ params[p + "wv"]

    def split(t):  # [b, s, d] -> [b*h, s, dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    o = flash_attention(split(q), split(k), split(v))
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ params[p + "wo"]


def _mlp(cfg, params, i, x):
    p = f"l{i}."
    return (
        jax.nn.relu(x @ params[p + "w1"] + params[p + "b1"]) @ params[p + "w2"]
        + params[p + "b2"]
    )


def _ln(params, name, x):
    b, s, d = x.shape
    return layernorm(x.reshape(b * s, d), params[name + "_g"], params[name + "_b"]).reshape(
        b, s, d
    )


def forward_hidden(cfg: ModelConfig, params, tokens):
    """tokens: [b, s] int32 -> hidden [b, s, d] (post final-LN)."""
    b, s = tokens.shape
    x = params["embed"][tokens] + params["pos_embed"][:s][None]
    for i in range(cfg.n_layers):
        x = x + _attn_train(cfg, params, i, _ln(params, f"l{i}.ln1", x))
        x = x + _mlp(cfg, params, i, _ln(params, f"l{i}.ln2", x))
    return _ln(params, "lnf", x)


def logits_fn(cfg, params, tokens):
    """LM logits via the tied embedding: [b, s, vocab]."""
    return forward_hidden(cfg, params, tokens) @ params["embed"].T


def token_logprobs(cfg, params, tokens):
    """Log-probs of each realized next token: [b, s-1]."""
    logits = logits_fn(cfg, params, tokens)[:, :-1]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)[..., 0]


def values_fn(cfg, params, tokens):
    """Per-position scalar head output: [b, s]."""
    h = forward_hidden(cfg, params, tokens)
    return h @ params["vhead"] + params["vbias"]


def rewards_fn(cfg, params, tokens, lens):
    """RM reward = value at the last real token. lens: [b] int32 -> [b]."""
    v = values_fn(cfg, params, tokens)
    return jnp.take_along_axis(v, lens[:, None], axis=1)[:, 0]


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def sft_loss(cfg, params, tokens, mask):
    """Masked next-token CE. tokens: [b,s]; mask: [b,s-1] f32."""
    logp = token_logprobs(cfg, params, tokens)
    return -(logp * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def rm_pair_loss(cfg, params, chosen, rejected, lens_c, lens_r):
    """-log sigmoid(r_chosen - r_rejected); also returns pairwise accuracy."""
    rc = rewards_fn(cfg, params, chosen, lens_c)
    rr = rewards_fn(cfg, params, rejected, lens_r)
    loss = -jax.nn.log_sigmoid(rc - rr).mean()
    acc = (rc > rr).astype(jnp.float32).mean()
    return loss, acc


def ppo_actor_loss(cfg, params, tokens, old_logp, adv, mask, ptx_tokens, hyper):
    """PPO clipped surrogate + optional mixture (pretraining) objective.

    hyper: [4] f32 = (clip_eps, ptx_coef, _, _). Returns (loss, approx_kl,
    clipfrac). Mixture training is the paper's Step-3 option that blends the
    next-word-prediction objective into PPO to avoid benchmark regression.
    """
    clip_eps, ptx_coef = hyper[0], hyper[1]
    logp = token_logprobs(cfg, params, tokens)
    denom = jnp.maximum(mask.sum(), 1.0)
    ratio = jnp.exp(logp - old_logp)
    s1 = ratio * adv
    s2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg_loss = -(jnp.minimum(s1, s2) * mask).sum() / denom
    approx_kl = ((old_logp - logp) * mask).sum() / denom
    clipped = (jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32)
    clipfrac = (clipped * mask).sum() / denom
    ptx = sft_loss(cfg, params, ptx_tokens, jnp.ones_like(ptx_tokens[:, 1:], jnp.float32))
    return pg_loss + ptx_coef * ptx, approx_kl, clipfrac


def ppo_critic_loss(cfg, params, tokens, returns, old_values, mask, hyper):
    """Clipped value loss over response positions. returns/old_values: [b, s-1]."""
    clip_eps = hyper[0]
    v = values_fn(cfg, params, tokens)[:, :-1]
    v_clip = old_values + jnp.clip(v - old_values, -clip_eps, clip_eps)
    l1 = (v - returns) ** 2
    l2 = (v_clip - returns) ** 2
    return 0.5 * (jnp.maximum(l1, l2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Generation (the Hybrid Engine's inference mode)
# ---------------------------------------------------------------------------


def _attn_prefill(cfg, params, i, x):
    """Like _attn_train but also returns per-head K/V for the cache."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    p = f"l{i}."
    q = x @ params[p + "wq"]
    k = x @ params[p + "wk"]
    v = x @ params[p + "wv"]

    def split(t):
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    qs, ks, vs = split(q), split(k), split(v)
    o = flash_attention_fwd(qs, ks, vs)
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ params[p + "wo"], ks, vs


def _attn_prefill_padded(cfg, params, i, x, start):
    """`_attn_prefill` over left-padded rows: keys before each row's
    valid start are masked (padded flash kernel). start: [b] int32."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    p = f"l{i}."
    q = x @ params[p + "wq"]
    k = x @ params[p + "wk"]
    v = x @ params[p + "wv"]

    def split(t):
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    qs, ks, vs = split(q), split(k), split(v)
    o = flash_attention_padded_fwd(qs, ks, vs, jnp.repeat(start, h))
    o = o.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ params[p + "wo"], ks, vs


def _padded_embed(cfg, params, prompt, start):
    """Token + position embedding for LEFT-PADDED prompts.

    Artifact position p of row r holds real token index p - start[r], so
    its position embedding is pos_embed[p - start[r]] (clamped to 0 for the
    don't-care padding positions). With start == 0 this is exactly the
    fixed-length `pos_embed[:sp]` gather.
    """
    _, sp = prompt.shape
    pos_idx = jnp.maximum(jnp.arange(sp)[None, :] - start[:, None], 0)
    return params["embed"][prompt] + params["pos_embed"][pos_idx]


def prefill(cfg: ModelConfig, params, prompt, smax, start=None):
    """Run the prompt, fill the KV cache.

    prompt: [b, sp] -> (last-position logits [b, vocab],
                        k_cache, v_cache: [L, b*h, smax, dh]).

    `start` (optional [b] int32) is the variable-prompt-length path: row
    r's real tokens sit LEFT-PADDED at positions [start[r], sp) of the
    fixed AOT shape. Attention masks keys before start[r], and position
    embeddings are shifted so real token j is embedded at logical position
    j — which makes the real positions (and the last-position logits)
    bit-identical to prefilling the unpadded prompt at its exact length;
    left-padding also keeps every row's next write position at `sp`, so the
    shared-position decode loop still advances mixed-length rows in
    lockstep. `start=None` keeps the legacy fixed-length path.
    """
    b, sp = prompt.shape
    bh, dh = b * cfg.n_heads, cfg.d_head
    if start is None:
        x = params["embed"][prompt] + params["pos_embed"][:sp][None]
    else:
        x = _padded_embed(cfg, params, prompt, start)
    kc = jnp.zeros((cfg.n_layers, bh, smax, dh), jnp.float32)
    vc = jnp.zeros((cfg.n_layers, bh, smax, dh), jnp.float32)
    for i in range(cfg.n_layers):
        xn = _ln(params, f"l{i}.ln1", x)
        if start is None:
            o, ks, vs = _attn_prefill(cfg, params, i, xn)
        else:
            o, ks, vs = _attn_prefill_padded(cfg, params, i, xn, start)
        kc = kc.at[i, :, :sp].set(ks)
        vc = vc.at[i, :, :sp].set(vs)
        x = x + o
        x = x + _mlp(cfg, params, i, _ln(params, f"l{i}.ln2", x))
    x = _ln(params, "lnf", x)
    logits = x[:, -1] @ params["embed"].T
    return logits, kc, vc


def decode_step(cfg: ModelConfig, params, k_cache, v_cache, token, pos):
    """One generation step (the paper's memory-bandwidth-bound hot loop).

    token: [b] int32 (the token at position `pos`); pos: [1] int32.
    Returns (logits [b, vocab] for position pos, updated caches).
    """
    b = token.shape[0]
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    p0 = pos[0]
    pos_emb = jax.lax.dynamic_slice_in_dim(params["pos_embed"], p0, 1, axis=0)
    x = params["embed"][token] + pos_emb  # [b, d]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        xn = layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
        q = (xn @ params[p + "wq"]).reshape(b * h, dh)
        k = (xn @ params[p + "wk"]).reshape(b * h, dh)
        v = (xn @ params[p + "wv"]).reshape(b * h, dh)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None, :, None, :], (i, 0, p0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None, :, None, :], (i, 0, p0, 0))
        o = decode_attention(q, k_cache[i], v_cache[i], pos)  # [b*h, dh]
        x = x + o.reshape(b, d) @ params[p + "wo"]
        xn = layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        x = (
            x
            + jax.nn.relu(xn @ params[p + "w1"] + params[p + "b1"]) @ params[p + "w2"]
            + params[p + "b2"]
        )
    x = layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T, k_cache, v_cache


def prefill_slot(cfg: ModelConfig, params, k_cache, v_cache, prompt, slot, start=None):
    """Prefill ONE sequence into one batch slot of a live cache.

    The continuous-batching admission path: a retired slot's K/V rows are
    overwritten with the new request's prompt while every other slot's rows
    are preserved, so the other slots can keep decoding across the admit.

    prompt: [1, sp] int32; slot: [1] int32 (batch-slot index); `start`
    (optional [1] int32) is the row's valid start for LEFT-PADDED
    variable-length prompts — see `prefill` for the masking contract. The
    last-position logits stay the real last token's logits because the
    padding sits on the left.
    Returns (last-position logits [1, vocab], updated caches).
    """
    _, sp = prompt.shape
    h = cfg.n_heads
    if start is None:
        x = params["embed"][prompt] + params["pos_embed"][:sp][None]
    else:
        x = _padded_embed(cfg, params, prompt, start)
    row0 = slot[0] * h  # first bh row owned by this slot
    for i in range(cfg.n_layers):
        xn = _ln(params, f"l{i}.ln1", x)
        if start is None:
            o, ks, vs = _attn_prefill(cfg, params, i, xn)
        else:
            o, ks, vs = _attn_prefill_padded(cfg, params, i, xn, start)
        # ks/vs: [h, sp, dh] -> rows [slot*h, slot*h + h), positions [0, sp).
        k_cache = jax.lax.dynamic_update_slice(k_cache, ks[None], (i, row0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vs[None], (i, row0, 0, 0))
        x = x + o
        x = x + _mlp(cfg, params, i, _ln(params, f"l{i}.ln2", x))
    x = _ln(params, "lnf", x)
    logits = x[:, -1] @ params["embed"].T
    return logits, k_cache, v_cache


def decode_slots(cfg: ModelConfig, params, k_cache, v_cache, token, pos, start=None):
    """One decode step with PER-SLOT positions (continuous batching).

    Unlike `decode_step` (one shared position for the whole batch), every
    batch slot carries its own sequence depth: slot r's token is written at
    `pos[r]` and attends to cache entries `0..pos[r]` only, so freshly
    admitted and nearly finished sequences advance in the same fused call.

    `start` (optional [b] int32) is the per-slot valid start for rows whose
    prompt was LEFT-PADDED: cache entries before start[r] hold padding and
    are masked out of attention, and the token's position embedding is
    pos_embed[pos[r] - start[r]] (its logical sequence position). With
    start == 0 both reduce to the unpadded behavior.

    token: [b] int32; pos: [b] int32. Returns (logits [b, vocab], caches).
    """
    b = token.shape[0]
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    if start is None:
        pos_emb = params["pos_embed"][pos]  # [b, d] per-row gather
    else:
        pos_emb = params["pos_embed"][jnp.maximum(pos - start, 0)]
    x = params["embed"][token] + pos_emb
    pos_bh = jnp.repeat(pos, h)  # [b*h]: every head row inherits its slot's pos
    start_bh = None if start is None else jnp.repeat(start, h)

    def scatter_row(cache_row, val, p):
        # cache_row: [smax, dh]; val: [dh]; p: scalar — write val at row p.
        return jax.lax.dynamic_update_slice(cache_row, val[None, :], (p, 0))

    for i in range(cfg.n_layers):
        p = f"l{i}."
        xn = layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
        q = (xn @ params[p + "wq"]).reshape(b * h, dh)
        k = (xn @ params[p + "wk"]).reshape(b * h, dh)
        v = (xn @ params[p + "wv"]).reshape(b * h, dh)
        k_cache = k_cache.at[i].set(jax.vmap(scatter_row)(k_cache[i], k, pos_bh))
        v_cache = v_cache.at[i].set(jax.vmap(scatter_row)(v_cache[i], v, pos_bh))
        if start_bh is None:
            o = decode_attention_pb(q, k_cache[i], v_cache[i], pos_bh)  # [b*h, dh]
        else:
            o = decode_attention_pbs(q, k_cache[i], v_cache[i], pos_bh, start_bh)
        x = x + o.reshape(b, d) @ params[p + "wo"]
        xn = layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        x = (
            x
            + jax.nn.relu(xn @ params[p + "w1"] + params[p + "b1"]) @ params[p + "w2"]
            + params[p + "b2"]
        )
    x = layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T, k_cache, v_cache


# ---------------------------------------------------------------------------
# Block-paged serving (the `_paged` artifact variants)
#
# The paged path replaces the per-slot arena rows with a physical page pool
# [L, h, n_pages * page_size, dh] shared by every slot: each slot's block
# table maps logical block kb onto a pool page, so retired pages return to a
# free list and pages holding a shared system-prompt prefix can appear in
# several tables at once (refcounted by the rust allocator). Unlike the
# arena path, paged prompts are FRONT-ALIGNED (real token j sits at logical
# position j, short prompts are right-padded and the garbage tail is masked
# by `pos`), which keeps the math bit-identical to the exact-length
# computation by the causal-mask argument — and therefore bit-identical to
# the arena left-padded path, which PR 5 pinned to the same exact-length
# reference.
#
# LAZY TABLES (`lazy_kv` capability): every entry here is shaped for the
# FULL [b, max_blocks] table, but only entries covering the live length
# (`ceil((pos+1) / page_size)` blocks) must name real pages. Reads mask
# `idx > pos` (see `decode_attention_paged`) and writes only target the
# page covering the written position, so dead tail entries may alias the
# reserved garbage page 0. The rust allocator exploits this to map pages
# on demand as decode crosses page boundaries instead of reserving
# `max_blocks` pages per slot at admission.
# ---------------------------------------------------------------------------


def _paged_dest(block_table, pos, page_size):
    """Physical pool row of logical position `pos` under `block_table`.

    block_table: [max_blocks] int32; pos: scalar or [n] int32 -> same shape.
    """
    return block_table[pos // page_size] * page_size + pos % page_size


def _paged_scatter(cache, layer, dest, vals):
    """Scatter per-head rows into the pool: cache [L, h, pool, dh];
    dest: [n] int32 pool rows; vals: [h, n, dh]."""
    return cache.at[layer].set(cache[layer].at[:, dest, :].set(vals))


def prefill_slot_paged(cfg: ModelConfig, params, k_cache, v_cache, prompt, block_table, last, page_size):
    """Prefill ONE sequence into a block-paged cache through its block table.

    Front-aligned: the prompt's true length-L tokens occupy logical
    positions [0, L) (short prompts arrive right-padded to the fixed [1, sp]
    shape); position embeddings are the plain `pos_embed[:sp]` gather and
    attention is plain causal, so rows [0, L) are bit-identical to the
    exact-length prefill — the garbage K/V the padding tail produces lands
    at logical positions >= L of the slot's own pages, where `pos` masking
    (and later decode overwrites) keep it unread. Every position's K/V is
    scattered to `block_table[p // page_size] * page_size + p % page_size`;
    pages holding a verified shared prefix are rewritten with bit-identical
    values (same tokens at same logical positions), which is what makes
    copy-on-write prefix sharing safe under a full-window prefill. Under
    the lazy contract the allocator maps only `ceil(L / page_size)` pages
    at admission and points the table tail at garbage page 0, so the
    padding tail's K/V writes land in page 0 — storage no live slot
    attends (and whose values stay finite, keeping the masked-read
    argument in `decode_attention_paged` sound).

    prompt: [1, sp] int32; block_table: [1, max_blocks] int32; `last`: [1]
    int32 = L - 1, the true last token's row, whose logits are returned.
    Returns (last-real-position logits [1, vocab], updated caches
    [L, h, n_pages * page_size, dh]).
    """
    _, sp = prompt.shape
    x = params["embed"][prompt] + params["pos_embed"][:sp][None]
    dest = _paged_dest(block_table[0], jnp.arange(sp), page_size)  # [sp]
    for i in range(cfg.n_layers):
        xn = _ln(params, f"l{i}.ln1", x)
        o, ks, vs = _attn_prefill(cfg, params, i, xn)
        # ks/vs: [h, sp, dh] -> pool rows dest, all heads.
        k_cache = _paged_scatter(k_cache, i, dest, ks)
        v_cache = _paged_scatter(v_cache, i, dest, vs)
        x = x + o
        x = x + _mlp(cfg, params, i, _ln(params, f"l{i}.ln2", x))
    x = _ln(params, "lnf", x)
    logits = x[:, last[0]] @ params["embed"].T
    return logits, k_cache, v_cache


def decode_slots_paged(cfg: ModelConfig, params, k_cache, v_cache, token, pos, block_tables, page_size):
    """One per-slot-position decode step over the block-paged cache.

    Like `decode_slots` with start == 0 everywhere (paged slots are
    front-aligned, so `pos` IS the logical sequence position), but K/V are
    written and attended through each slot's block table. Inactive slots'
    tables point every block at the reserved garbage page 0, so their PAD
    writes land in (and their outputs read) storage no live slot maps.
    Live slots need only the blocks covering `pos` mapped: the write
    targets the single page holding `pos` (which `reserve_rows` maps
    before dispatch) and reads mask `idx > pos`, so the table tail past
    the live length may also alias page 0 (the lazy contract).

    token, pos: [b] int32; block_tables: [b, max_blocks] int32.
    Returns (logits [b, vocab], updated caches).
    """
    b = token.shape[0]
    h, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model
    x = params["embed"][token] + params["pos_embed"][pos]
    pos_bh = jnp.repeat(pos, h)
    dest = block_tables[jnp.arange(b), pos // page_size] * page_size + pos % page_size  # [b]
    for i in range(cfg.n_layers):
        p = f"l{i}."
        xn = layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
        q = (xn @ params[p + "wq"]).reshape(b * h, dh)
        k = (xn @ params[p + "wk"]).reshape(b, h, dh)
        v = (xn @ params[p + "wv"]).reshape(b, h, dh)
        k_cache = _paged_scatter(k_cache, i, dest, k.transpose(1, 0, 2))
        v_cache = _paged_scatter(v_cache, i, dest, v.transpose(1, 0, 2))
        o = decode_attention_paged(
            q, k_cache[i], v_cache[i], pos_bh, block_tables, page_size
        )  # [b*h, dh]
        x = x + o.reshape(b, d) @ params[p + "wo"]
        xn = layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        x = (
            x
            + jax.nn.relu(xn @ params[p + "w1"] + params[p + "b1"]) @ params[p + "w2"]
            + params[p + "b2"]
        )
    x = layernorm(x, params["lnf_g"], params["lnf_b"])
    return x @ params["embed"].T, k_cache, v_cache


# ---------------------------------------------------------------------------
# Device-side sampling tail (the `_sampled` artifact variants)
#
# The plain generation entry points end at the logits matmul and ship the
# full [b, vocab] row to the host. The `_sampled` variants append the fused
# Pallas sampling tail so per-step host traffic is the greedy ids (O(b)) or
# the top-k candidates (O(b·k)); the host finishes temperature/top-p and the
# categorical draw over the candidates with its own seeded RNG.
# ---------------------------------------------------------------------------


def sample_tail(logits, k):
    """Device half of sampling over next-token logits.

    logits: [b, vocab] -> (ids [b] i32 — greedy argmax,
                           topk_logits [b, k] f32, topk_ids [b, k] i32 —
                           candidates sorted by descending logit).
    """
    ids = argmax_rows(logits)
    tv, ti = top_k_rows(logits, k)
    return ids, tv, ti


def prefill_sampled(cfg, params, prompt, smax, k, start=None):
    """`prefill` with the sampling tail on the last-position logits."""
    logits, kc, vc = prefill(cfg, params, prompt, smax, start)
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


def decode_step_sampled(cfg, params, k_cache, v_cache, token, pos, k):
    """`decode_step` with the sampling tail (shared-position batch decode)."""
    logits, kc, vc = decode_step(cfg, params, k_cache, v_cache, token, pos)
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


def prefill_slot_sampled(cfg, params, k_cache, v_cache, prompt, slot, k, start=None):
    """`prefill_slot` with the sampling tail on the admitted slot's logits."""
    logits, kc, vc = prefill_slot(cfg, params, k_cache, v_cache, prompt, slot, start)
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


def decode_slots_sampled(cfg, params, k_cache, v_cache, token, pos, k, start=None):
    """`decode_slots` with the sampling tail (per-slot-position decode)."""
    logits, kc, vc = decode_slots(cfg, params, k_cache, v_cache, token, pos, start)
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


def prefill_slot_paged_sampled(
    cfg, params, k_cache, v_cache, prompt, block_table, last, page_size, k
):
    """`prefill_slot_paged` with the sampling tail on the slot's logits."""
    logits, kc, vc = prefill_slot_paged(
        cfg, params, k_cache, v_cache, prompt, block_table, last, page_size
    )
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


def decode_slots_paged_sampled(
    cfg, params, k_cache, v_cache, token, pos, block_tables, page_size, k
):
    """`decode_slots_paged` with the sampling tail (paged per-slot decode)."""
    logits, kc, vc = decode_slots_paged(
        cfg, params, k_cache, v_cache, token, pos, block_tables, page_size
    )
    ids, tv, ti = sample_tail(logits, k)
    return ids, tv, ti, kc, vc


# ---------------------------------------------------------------------------
# Device RNG sampling tail (the `_rng` artifact variants) + fused N-step
# decode (the `decode_chunk{N}` artifacts)
#
# The `_sampled` family still ships the O(b·k) top-k candidates so the host
# can finish a stochastic draw with its own RNG. The `_rng` family finishes
# the draw ON DEVICE from a counter-based Threefry hash of (request_seed,
# step) — stochastic traffic drops to O(b) sampled ids — and the chunk
# entries then amortize dispatch by scanning N decode steps inside one
# artifact call, with a per-row freeze latch so rows that emit EOS (or
# exhaust their budget) mid-chunk stop advancing: no garbage KV writes, no
# RNG draws after retirement.
# ---------------------------------------------------------------------------


def sample_tail_rng(logits, k, seeds, steps, sparams):
    """`sample_tail` plus the device-side categorical draw.

    logits: [b, vocab]; seeds: [b, 2] i32; steps: [b] i32; sparams: [3] f32
    (temperature, top_k, top_p; temperature <= 0 -> greedy). Returns
    (ids [b], topk_logits [b, k], topk_ids [b, k], sampled_ids [b]).
    """
    ids = argmax_rows(logits)
    tv, ti = top_k_rows(logits, k)
    sampled = sample_draw_rows(tv, ti, seeds, steps, sparams)
    return ids, tv, ti, sampled


def prefill_rng(cfg, params, prompt, smax, k, seeds, steps, sparams, start=None):
    """`prefill` with the device-RNG sampling tail."""
    logits, kc, vc = prefill(cfg, params, prompt, smax, start)
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def decode_step_rng(cfg, params, k_cache, v_cache, token, pos, k, seeds, steps, sparams):
    """`decode_step` with the device-RNG sampling tail."""
    logits, kc, vc = decode_step(cfg, params, k_cache, v_cache, token, pos)
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def prefill_slot_rng(cfg, params, k_cache, v_cache, prompt, slot, k, seeds, steps, sparams, start=None):
    """`prefill_slot` with the device-RNG sampling tail."""
    logits, kc, vc = prefill_slot(cfg, params, k_cache, v_cache, prompt, slot, start)
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def decode_slots_rng(cfg, params, k_cache, v_cache, token, pos, k, seeds, steps, sparams, start=None):
    """`decode_slots` with the device-RNG sampling tail."""
    logits, kc, vc = decode_slots(cfg, params, k_cache, v_cache, token, pos, start)
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def prefill_slot_paged_rng(
    cfg, params, k_cache, v_cache, prompt, block_table, last, page_size, k, seeds, steps, sparams
):
    """`prefill_slot_paged` with the device-RNG sampling tail."""
    logits, kc, vc = prefill_slot_paged(
        cfg, params, k_cache, v_cache, prompt, block_table, last, page_size
    )
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def decode_slots_paged_rng(
    cfg, params, k_cache, v_cache, token, pos, block_tables, page_size, k, seeds, steps, sparams
):
    """`decode_slots_paged` with the device-RNG sampling tail."""
    logits, kc, vc = decode_slots_paged(
        cfg, params, k_cache, v_cache, token, pos, block_tables, page_size
    )
    ids, tv, ti, sampled = sample_tail_rng(logits, k, seeds, steps, sparams)
    return ids, tv, ti, sampled, kc, vc


def decode_chunk_loop(step_fn, draw_fn, caches, token, pos, steps, quota, frozen, n, eos_id):
    """Fused N-step decode loop with a per-row EOS/budget freeze latch.

    The scan's step-j semantics are EXACTLY one stepwise decode+sample tick:
    run the model on each row's last accepted token, draw its next token,
    append. Rows freeze when they draw `eos_id` or exhaust `quota`; frozen
    rows re-feed their last live (token, pos) — per-row decode attention
    makes the re-run write bit-identical K/V to the same destinations
    (idempotent: the freshly drawn EOS/overflow token is never written, just
    as the stepwise scheduler never decodes a retired row) — emit `eos_id`
    as a don't-care filler, and do NOT advance their step counter, so the
    request's RNG stream position equals the number of tokens it actually
    accepted and a resumed/stepwise replay continues the identical stream.

    step_fn(caches, token, pos) -> (logits [b, vocab], caches)
    draw_fn(logits, steps)      -> next ids [b] i32
    token, pos, steps, quota: [b] i32; frozen: [b] bool (True = dead slot).
    Returns (ids [n, b] i32 — trailing entries of frozen rows are eos_id —
    and the final caches).

    The loop is UNROLLED (n is baked per artifact, one `decode_chunk{n}`
    entry each) rather than a `lax.scan`: the image's jax cannot discharge
    interpret-mode Pallas state through a scan body, and unrolling lowers to
    the same single-dispatch artifact the scan would.
    """
    eos = jnp.int32(eos_id) if not hasattr(eos_id, "dtype") else eos_id
    tok, p, st, q, fz = token, pos, steps, quota, frozen
    emitted = []
    for _ in range(n):
        logits, caches = step_fn(caches, tok, p)
        drawn = draw_fn(logits, st)
        emit = jnp.where(fz, eos, drawn)
        q2 = jnp.where(fz, q, q - 1)
        fz2 = fz | (emit == eos) | (q2 <= 0)
        tok = jnp.where(fz2, tok, emit)
        p = jnp.where(fz2, p, p + 1)
        st = jnp.where(fz, st, st + 1)
        q, fz = q2, fz2
        emitted.append(emit)
    return jnp.stack(emitted), caches


def decode_chunk_paged(
    cfg,
    params,
    k_cache,
    v_cache,
    token,
    pos,
    block_tables,
    page_size,
    n,
    k,
    seeds,
    steps,
    quota,
    frozen,
    eos,
    sparams,
):
    """N fused `decode_slots_paged` + device-RNG sampling steps in one call.

    One dispatch advances every live slot by up to `n` tokens; the host sees
    only the [n, b] emitted ids (O(b) bytes per token, 1/n dispatches per
    token). `frozen`: [b] i32 (nonzero = dead slot — its PAD/garbage-page
    tick repeats exactly as in stepwise decode); `quota`: [b] i32 remaining
    generation budget; `eos`: [1] i32. Greedy (sparams[0] <= 0) emissions are
    bit-identical to n stepwise `decode_slots_paged` + argmax ticks.
    """

    def step_fn(caches, tok, p):
        kc, vc = caches
        logits, kc, vc = decode_slots_paged(
            cfg, params, kc, vc, tok, p, block_tables, page_size
        )
        return logits, (kc, vc)

    def draw_fn(logits, st):
        tv, ti = top_k_rows(logits, k)
        return sample_draw_rows(tv, ti, seeds, st, sparams)

    ids, (kc, vc) = decode_chunk_loop(
        step_fn,
        draw_fn,
        (k_cache, v_cache),
        token,
        pos,
        steps,
        quota,
        frozen != 0,
        n,
        eos[0],
    )
    return ids, kc, vc


def ema_update(ema_flat, params_flat, decay):
    """EMA checkpoint collection (paper Step-3 optional feature)."""
    return [decay * e + (1.0 - decay) * p for e, p in zip(ema_flat, params_flat)]
