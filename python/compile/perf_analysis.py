"""L1 structural performance analysis (the §Perf deliverable for the kernel
layer).

interpret=True gives CPU-numpy timing only — NOT a TPU proxy — so the Pallas
kernels are evaluated structurally: VMEM footprint per grid program vs the
~16 MiB budget, MXU-shaped matmul fraction, and HBM bytes-touched ratios.
Run: `cd python && python -m compile.perf_analysis`.
"""

from dataclasses import dataclass

from .configs import run_config_names, run_config

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TPU core


@dataclass
class KernelReport:
    name: str
    vmem_bytes: int
    mxu_fraction: float  # share of FLOPs in 128x128-tileable matmuls
    hbm_ratio: float  # bytes touched / minimum bytes
    notes: str

    def row(self):
        return (
            f"{self.name:<22} VMEM/program {self.vmem_bytes/1024:>8.1f} KiB "
            f"({100*self.vmem_bytes/VMEM_BUDGET:>5.2f}% of budget)  "
            f"MXU {self.mxu_fraction:>4.0%}  HBM x{self.hbm_ratio:.2f}  {self.notes}"
        )


def flash_attention_report(s, dh, block_q=32, block_k=32, dtype=4):
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    # Per program: q block + streamed k/v blocks + accumulator + m/l vectors.
    vmem = dtype * (block_q * dh + 2 * block_k * dh + block_q * dh + 2 * block_q)
    # FLOPs: 2*bq*bk*dh per score matmul + 2*bq*bk*dh for p@v -> all matmul;
    # softmax exp/sum is O(bq*bk) — negligible share.
    matmul = 4 * block_q * block_k * dh
    softmax = 6 * block_q * block_k
    # HBM: Q,O once; K,V re-read once per q block (causal skip halves it).
    n_qb = s // block_q
    touched = s * dh * (2 + 2 * (n_qb + 1) / 2)
    minimum = 4 * s * dh
    return KernelReport(
        "flash_attention",
        vmem,
        matmul / (matmul + softmax),
        touched / minimum,
        f"bq={block_q} bk={block_k} causal-skip on",
    )


def decode_attention_report(smax, dh, block_k=32, dtype=4):
    block_k = min(block_k, smax)
    vmem = dtype * (dh + 2 * block_k * dh + dh + 2)
    matmul = 4 * block_k * dh
    softmax = 6 * block_k
    # Each cache byte is read exactly once (single pass, pos-bounded).
    return KernelReport(
        "decode_attention",
        vmem,
        matmul / (matmul + softmax),
        1.0,
        f"bk={block_k} single-pass over cache",
    )


def layernorm_report(d, block_rows=32, dtype=4):
    vmem = dtype * (block_rows * d * 2 + 2 * d)
    return KernelReport(
        "layernorm", vmem, 0.0, 1.0, f"rows={block_rows} one read per element"
    )


def adam_report(block=4096, dtype=4):
    vmem = dtype * (4 * block + 3 * block + 8)
    return KernelReport(
        "fused_adam", vmem, 0.0, 1.0, f"block={block} p/m/v/g read+write once"
    )


def main():
    for run in run_config_names():
        rc = run_config(run)
        a = rc.actor
        s, dh = rc.seq_len, a.d_head
        print(f"== {run}: actor {a.name} (s={s}, d_head={dh}, d={a.d_model}) ==")
        for r in [
            flash_attention_report(s, dh),
            decode_attention_report(s, dh),
            layernorm_report(a.d_model),
            adam_report(),
        ]:
            print("  " + r.row())
            assert r.vmem_bytes < VMEM_BUDGET, f"{r.name} exceeds VMEM budget"
        print()


if __name__ == "__main__":
    main()
