"""Fused device-side RNG sampling + N-step decode dispatch.

These pin the invariants the rust `DeviceCategorical` backend and the
chunked scheduler path rely on:

  * the counter hash is Threefry-2x32 exactly (Random123 known-answer
    vectors, cross-checked against jax's own implementation when
    importable) — the rust mirror in rust/src/sampling/device.rs pins the
    same vectors, which is what makes mock-engine unit tests and the real
    device stream agree on keyed determinism;
  * `sample_draw_rows` (the Pallas draw kernel) is bit-identical to the
    pure-jnp oracle `device_draw_ref`, greedy (temperature <= 0) degrades
    to the argmax candidate, and the draw is a pure function of
    (seed, step) — invariant under row reordering, i.e. admission order
    and slot assignment;
  * `decode_chunk_loop`'s per-row latch: a fused N-step scan emits exactly
    what N stepwise decode+sample ticks emit, rows freeze on EOS or budget
    exhaustion (trailing emissions are EOS filler, step counters stop, the
    frozen row's K/V writes are idempotent re-writes of its last live row);
  * model-level: greedy `decode_chunk_paged` bit-matches stepwise
    `decode_slots_paged` + argmax including a mid-chunk EOS retirement, and
    the stochastic chunk replays the stepwise `_rng` stream exactly.

As in test_paged.py the attention/LN Pallas kernels are swapped for their
jnp oracles; the sampling kernels under test run for real.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import run_config
from compile.kernels import ref
from compile.kernels.sampling import sample_draw_rows, top_k_rows

RC = run_config("nano")
PS = RC.page_size
MB = RC.kv_blocks_per_slot
PAD = 0  # mirrors the rust Vocab::PAD token


@pytest.fixture(autouse=True)
def ref_kernels(monkeypatch):
    """Run the transformer on the pure-jnp kernel oracles; the sampling
    kernels stay real — they are what is under test."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_padded_fwd", ref.attention_padded_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)
    monkeypatch.setattr(model, "decode_attention_pbs", ref.decode_attention_pbs_ref)
    monkeypatch.setattr(model, "decode_attention_paged", ref.decode_attention_paged_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


# ---------------------------------------------------------------------------
# counter RNG: Threefry-2x32
# ---------------------------------------------------------------------------


def test_threefry_known_answer_vectors():
    """Random123 KAT vectors for threefry2x32, 20 rounds — also pinned by
    the rust mirror (sampling::device tests)."""
    x0, x1 = ref.threefry2x32_ref(0, 0, 0, 0)
    assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)
    m = np.uint32(0xFFFFFFFF)
    x0, x1 = ref.threefry2x32_ref(m, m, m, m)
    assert (int(x0), int(x1)) == (0x1CB996FC, 0xBB002BE7)
    x0, x1 = ref.threefry2x32_ref(
        np.uint32(0x13198A2E), np.uint32(0x03707344), np.uint32(0x243F6A88), np.uint32(0x85A308D3)
    )
    assert (int(x0), int(x1)) == (0xC4923A9C, 0x483DF7A0)


def test_threefry_matches_jax_internal():
    try:
        from jax._src.prng import threefry_2x32
    except ImportError:
        pytest.skip("jax internal threefry not importable")
    key = jax.random.randint(jax.random.PRNGKey(3), (2,), 0, 2**31 - 1).astype(jnp.uint32)
    ctr = jax.random.randint(jax.random.PRNGKey(4), (2,), 0, 2**31 - 1).astype(jnp.uint32)
    ours = ref.threefry2x32_ref(key[0], key[1], ctr[0], ctr[1])
    theirs = threefry_2x32(key, ctr)
    assert int(ours[0]) == int(theirs[0]) and int(ours[1]) == int(theirs[1])


def test_counter_uniform_pinned_and_ranged():
    """Pinned (seed, step) -> uniform words shared with the rust mirror."""
    cases = [((0, 0), 0, 0x6B200159), ((1, 2), 3, 0x8E9A2EAB), ((-1, -2), 7, 0x6D06F4B6)]
    for (hi, lo), st, word in cases:
        s = jnp.array([[hi, lo]], jnp.int32)
        t = jnp.array([st], jnp.int32)
        u = float(ref.counter_uniform_ref(s, t)[0])
        assert u == (word >> 8) * 2.0**-24
    seeds = jax.random.randint(jax.random.PRNGKey(0), (64, 2), -(2**31), 2**31 - 1, jnp.int32)
    steps = jnp.arange(64, dtype=jnp.int32)
    u = np.asarray(ref.counter_uniform_ref(seeds, steps))
    assert (u >= 0).all() and (u < 1).all()
    # stateless: same key/step -> same value on every call
    np.testing.assert_array_equal(u, np.asarray(ref.counter_uniform_ref(seeds, steps)))


# ---------------------------------------------------------------------------
# draw kernel vs oracle
# ---------------------------------------------------------------------------


def candidates(seed, b, vocab, k):
    tv, ti = ref.top_k_ref(3.0 * jax.random.normal(jax.random.PRNGKey(seed), (b, vocab)), k)
    seeds = jax.random.randint(jax.random.PRNGKey(seed + 100), (b, 2), -(2**31), 2**31 - 1)
    return tv, ti, seeds.astype(jnp.int32), jnp.arange(b, dtype=jnp.int32)


@pytest.mark.parametrize(
    "sp", [(1.0, 0.0, 1.0), (0.7, 4.0, 0.9), (0.0, 0.0, 1.0), (50.0, 0.0, 0.95), (1.3, 2.0, 0.5)]
)
@pytest.mark.parametrize("b,vocab,k", [(1, 16, 4), (5, 64, 8), (3, 256, 32)])
def test_sample_draw_rows_matches_oracle(b, vocab, k, sp):
    tv, ti, seeds, steps = candidates(b + vocab, b, vocab, k)
    spa = jnp.array(sp, jnp.float32)
    got = sample_draw_rows(tv, ti, seeds, steps, spa)
    want = ref.device_draw_ref(tv, ti, seeds, steps, spa)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every sampled id is one of the row's candidates
    for r in range(b):
        assert int(got[r]) in set(np.asarray(ti[r]).tolist())


def test_greedy_draw_is_argmax():
    tv, ti, seeds, steps = candidates(9, 6, 128, 8)
    spa = jnp.array([0.0, 0.0, 1.0], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sample_draw_rows(tv, ti, seeds, steps, spa)), np.asarray(ti[:, 0])
    )


def test_draw_stream_is_reorder_invariant():
    """The draw depends only on (seed, step) and the row's candidates — not
    on the row index. This is the device half of the per-request stream
    determinism golden: admission order / slot assignment cannot change a
    request's tokens."""
    tv, ti, seeds, steps = candidates(11, 6, 64, 8)
    spa = jnp.array([0.9, 0.0, 1.0], jnp.float32)
    base = np.asarray(sample_draw_rows(tv, ti, seeds, steps, spa))
    perm = np.array([3, 0, 5, 1, 4, 2])
    shuffled = np.asarray(
        sample_draw_rows(tv[perm], ti[perm], seeds[perm], steps[perm], spa)
    )
    np.testing.assert_array_equal(shuffled, base[perm])


def test_top_k_top_p_cutoffs_restrict_support():
    tv, ti, seeds, _ = candidates(13, 4, 64, 8)
    # steps sweep: many draws from one row's stream stay within the top-2
    steps = jnp.arange(4, dtype=jnp.int32)
    spa = jnp.array([5.0, 2.0, 1.0], jnp.float32)  # hot temp, top_k=2
    for st in range(16):
        got = sample_draw_rows(tv, ti, seeds, steps + st * 4, spa)
        for r in range(4):
            assert int(got[r]) in (int(ti[r, 0]), int(ti[r, 1]))
    # top_p -> 0 keeps only the first candidate regardless of temperature
    spa = jnp.array([5.0, 0.0, 1e-9], jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(sample_draw_rows(tv, ti, seeds, steps, spa)), np.asarray(ti[:, 0])
    )


# ---------------------------------------------------------------------------
# decode_chunk_loop latch semantics (toy step function)
# ---------------------------------------------------------------------------


def toy_step(caches, tok, p):
    """Toy 'model': caches is a [b, smax] write log; logits one-hot at
    (tok * 3 + 1) % VOCAB so the greedy next token is a deterministic
    function of the current one."""
    VOCAB = 32
    b = tok.shape[0]
    caches = caches.at[jnp.arange(b), p].set(tok)
    nxt = (tok * 3 + 1) % VOCAB
    logits = jax.nn.one_hot(nxt, VOCAB, dtype=jnp.float32)
    return logits, caches


def toy_draw(logits, st):
    return jnp.argmax(logits, -1).astype(jnp.int32)


def run_toy_chunk(token, quota, frozen, n, eos, steps=None):
    b = token.shape[0]
    caches = jnp.full((b, 16), -1, jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    st = jnp.zeros((b,), jnp.int32) if steps is None else steps
    return model.decode_chunk_loop(
        toy_step, toy_draw, caches, token, pos, st, quota, frozen, n, eos
    )


def test_chunk_loop_matches_step_loop_no_freezing():
    b, n = 3, 6
    token = jnp.array([1, 2, 5], jnp.int32)
    ids, caches = run_toy_chunk(token, jnp.full((b,), 100, jnp.int32), jnp.zeros((b,), bool), n, -1)
    # manual stepwise replay
    tok = token
    cj = jnp.full((b, 16), -1, jnp.int32)
    p = jnp.zeros((b,), jnp.int32)
    want = []
    for _ in range(n):
        logits, cj = toy_step(cj, tok, p)
        tok = toy_draw(logits, None)
        want.append(np.asarray(tok))
        p = p + 1
    np.testing.assert_array_equal(np.asarray(ids), np.stack(want))
    np.testing.assert_array_equal(np.asarray(caches), np.asarray(cj))


def test_chunk_loop_eos_latch_freezes_row():
    """Row 0's toy chain is 1 -> 4 -> 13 -> 8 -> 25...; with eos=13 it must
    emit [4, 13, eos-filler...], stop writing past its last live position,
    and stop advancing its step counter. Row 1 (no EOS in range) runs all n."""
    n, eos = 5, 13
    token = jnp.array([1, 2], jnp.int32)
    steps0 = jnp.array([10, 20], jnp.int32)
    ids, caches = run_toy_chunk(
        token, jnp.full((2,), 100, jnp.int32), jnp.zeros((2,), bool), n, eos, steps0
    )
    ids = np.asarray(ids)
    np.testing.assert_array_equal(ids[:, 0], [4, 13, eos, eos, eos])
    assert (ids[:, 1] != eos).all()
    caches = np.asarray(caches)
    # row 0 accepted token 4 (wrote 1@0, 4@1); the EOS itself is never
    # written and the frozen iterations only re-write 4@1 idempotently.
    np.testing.assert_array_equal(caches[0, :3], [1, 4, -1])
    np.testing.assert_array_equal(caches[1, :n], [2, 7, 22, 3, 10])


def test_chunk_loop_quota_freeze():
    """quota=2: the row emits exactly 2 tokens then EOS filler, matching the
    stepwise Length retirement (the budget-exhausting token is kept)."""
    ids, caches = run_toy_chunk(
        jnp.array([1, 1], jnp.int32),
        jnp.array([2, 100], jnp.int32),
        jnp.zeros((2,), bool),
        4,
        -7,
    )
    ids = np.asarray(ids)
    np.testing.assert_array_equal(ids[:, 0], [4, 13, -7, -7])
    np.testing.assert_array_equal(ids[:, 1], [4, 13, 8, 25])
    # the frozen row never wrote its overflow token
    np.testing.assert_array_equal(np.asarray(caches)[0, :3], [1, 4, -1])


def test_chunk_loop_dead_rows_emit_filler_and_consume_nothing():
    token = jnp.array([1, 2], jnp.int32)
    ids, _ = run_toy_chunk(
        token, jnp.array([0, 100], jnp.int32), jnp.array([True, False]), 3, -9
    )
    ids = np.asarray(ids)
    np.testing.assert_array_equal(ids[:, 0], [-9, -9, -9])
    assert (ids[:, 1] != -9).all()


# ---------------------------------------------------------------------------
# model-level: chunked vs stepwise paged decode
# ---------------------------------------------------------------------------

BT = np.array([[3, 5], [1, 6]], np.int32)


def paged_zero_caches():
    a = RC.actor
    shape = (a.n_layers, a.n_heads, RC.kv_pages * PS, a.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def prefill_slots(params):
    a, sp = RC.actor, RC.prompt_len
    prompts = jax.random.randint(
        jax.random.PRNGKey(5), (RC.batch, sp), 1, a.vocab
    ).astype(jnp.int32)
    kc, vc = paged_zero_caches()
    toks = []
    for s in range(RC.batch):
        logits, kc, vc = model.prefill_slot_paged(
            a, params, kc, vc, prompts[s : s + 1], jnp.asarray(BT[s : s + 1]),
            jnp.array([sp - 1], jnp.int32), PS,
        )
        toks.append(int(jnp.argmax(logits[0])))
    tok = jnp.array(toks, jnp.int32)
    pos = jnp.full((RC.batch,), sp, jnp.int32)
    return kc, vc, tok, pos


GREEDY = jnp.array([0.0, 0.0, 1.0], jnp.float32)


def test_chunked_greedy_matches_stepwise(params):
    """decode_chunk4 == four decode_slots_paged + argmax ticks, bit-exact
    (ids and caches)."""
    a, n = RC.actor, 4
    kc, vc, tok, pos = prefill_slots(params)
    seeds = jnp.zeros((RC.batch, 2), jnp.int32)
    steps = jnp.zeros((RC.batch,), jnp.int32)
    ids, kc_c, vc_c = model.decode_chunk_paged(
        a, params, kc, vc, tok, pos, jnp.asarray(BT), PS, n, RC.sample_k,
        seeds, steps, jnp.full((RC.batch,), 100, jnp.int32),
        jnp.zeros((RC.batch,), jnp.int32), jnp.array([-1], jnp.int32), GREEDY,
    )
    kc_s, vc_s, t, p = kc, vc, tok, pos
    want = []
    for _ in range(n):
        logits, kc_s, vc_s = model.decode_slots_paged(
            a, params, kc_s, vc_s, t, p, jnp.asarray(BT), PS
        )
        t = jnp.argmax(logits, -1).astype(jnp.int32)
        want.append(np.asarray(t))
        p = p + 1
    np.testing.assert_array_equal(np.asarray(ids), np.stack(want))
    np.testing.assert_array_equal(np.asarray(kc_c), np.asarray(kc_s))
    np.testing.assert_array_equal(np.asarray(vc_c), np.asarray(vc_s))


def test_chunked_greedy_mid_chunk_eos_matches_retirement(params):
    """Pick eos = row 0's second greedy emission: the chunk must emit
    [t1, eos, filler, filler] for row 0, keep row 1 bit-identical to the
    no-EOS run, and leave every non-garbage page bit-identical to a stepwise
    schedule that retires row 0 (parking it as a dead slot on garbage page
    0) after the EOS — the idempotent-rewrite claim, verified on real
    paged K/V."""
    a, n = RC.actor, 4
    kc, vc, tok, pos = prefill_slots(params)
    seeds = jnp.zeros((RC.batch, 2), jnp.int32)
    steps = jnp.zeros((RC.batch,), jnp.int32)
    # discover row 0's greedy chain
    probe, _, _ = model.decode_chunk_paged(
        a, params, kc, vc, tok, pos, jnp.asarray(BT), PS, n, RC.sample_k,
        seeds, steps, jnp.full((RC.batch,), 100, jnp.int32),
        jnp.zeros((RC.batch,), jnp.int32), jnp.array([-1], jnp.int32), GREEDY,
    )
    probe = np.asarray(probe)
    eos = int(probe[1, 0])
    if int(probe[0, 1]) == eos or int(probe[1, 1]) == eos:
        pytest.skip("toy chains collide on the chosen eos id")
    ids, kc_c, vc_c = model.decode_chunk_paged(
        a, params, kc, vc, tok, pos, jnp.asarray(BT), PS, n, RC.sample_k,
        seeds, steps, jnp.full((RC.batch,), 100, jnp.int32),
        jnp.zeros((RC.batch,), jnp.int32), jnp.array([eos], jnp.int32), GREEDY,
    )
    ids = np.asarray(ids)
    np.testing.assert_array_equal(ids[:, 0], [probe[0, 0], eos, eos, eos])
    np.testing.assert_array_equal(ids[:, 1], probe[:, 1])
    # stepwise schedule with real retirement: after row 0 emits eos it
    # becomes a dead slot (PAD token, pos 0, garbage page 0) as the rust
    # scheduler parks it.
    kc_s, vc_s = kc, vc
    t, p = tok, pos
    bt = np.array(BT)
    t_np, p_np = np.asarray(t).copy(), np.asarray(p).copy()
    retired = False
    for j in range(n):
        logits, kc_s, vc_s = model.decode_slots_paged(
            a, params, kc_s, vc_s, jnp.asarray(t_np), jnp.asarray(p_np), jnp.asarray(bt), PS
        )
        nxt = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        if not retired:
            if int(nxt[0]) == eos:
                retired = True
                bt[0] = 0
                t_np[0], p_np[0] = PAD, 0
            else:
                t_np[0], p_np[0] = int(nxt[0]), p_np[0] + 1
        row1_live = j + 1 < n
        if row1_live:
            t_np[1], p_np[1] = int(nxt[1]), p_np[1] + 1
    # every page except the reserved garbage page is bit-identical
    kc_c, vc_c, kc_s, vc_s = (np.asarray(x) for x in (kc_c, vc_c, kc_s, vc_s))
    np.testing.assert_array_equal(kc_c[:, :, PS:], kc_s[:, :, PS:])
    np.testing.assert_array_equal(vc_c[:, :, PS:], vc_s[:, :, PS:])


def test_chunked_stochastic_replays_stepwise_rng_stream(params):
    """The fused chunk consumes the SAME (seed, step)-keyed draws as n
    stepwise `decode_slots_paged_rng` calls — fusing dispatch cannot move a
    request's stream position."""
    a, n = RC.actor, 4
    kc, vc, tok, pos = prefill_slots(params)
    seeds = jnp.array([[11, 22], [-33, 44]], jnp.int32)
    steps0 = jnp.array([1, 5], jnp.int32)
    sp = jnp.array([0.9, 0.0, 1.0], jnp.float32)
    ids, kc_c, vc_c = model.decode_chunk_paged(
        a, params, kc, vc, tok, pos, jnp.asarray(BT), PS, n, RC.sample_k,
        seeds, steps0, jnp.full((RC.batch,), 100, jnp.int32),
        jnp.zeros((RC.batch,), jnp.int32), jnp.array([-1], jnp.int32), sp,
    )
    kc_s, vc_s, t, p, st = kc, vc, tok, pos, steps0
    want = []
    for _ in range(n):
        _, _, _, sampled, kc_s, vc_s = model.decode_slots_paged_rng(
            a, params, kc_s, vc_s, t, p, jnp.asarray(BT), PS, RC.sample_k, seeds, st, sp
        )
        t = sampled
        want.append(np.asarray(sampled))
        p = p + 1
        st = st + 1
    np.testing.assert_array_equal(np.asarray(ids), np.stack(want))
    np.testing.assert_array_equal(np.asarray(kc_c), np.asarray(kc_s))
    np.testing.assert_array_equal(np.asarray(vc_c), np.asarray(vc_s))


# ---------------------------------------------------------------------------
# AOT contract
# ---------------------------------------------------------------------------


def test_rng_entries_trace_with_expected_shapes():
    entries = aot.build_entries(RC)
    B, K = RC.batch, RC.sample_k
    for name, nb in [
        ("prefill_rng", B),
        ("decode_step_rng", B),
        ("prefill_slot_rng", 1),
        ("decode_slots_rng", B),
        ("prefill_slot_paged_rng", 1),
        ("decode_slots_paged_rng", B),
    ]:
        entry = entries[name]
        fn, specs, outputs = entry[0], entry[1], entry[2]
        assert outputs == ["ids", "topk_logits", "topk_ids", "sampled_ids", "k_cache", "v_cache"]
        out = jax.eval_shape(fn, *specs)
        assert out[0].shape == (nb,) and out[0].dtype == jnp.int32, name
        assert out[1].shape == (nb, K) and out[2].shape == (nb, K), name
        assert out[3].shape == (nb,) and out[3].dtype == jnp.int32, name


def test_decode_chunk_entries_trace_with_expected_shapes():
    entries = aot.build_entries(RC)
    B = RC.batch
    kv_shape = (RC.actor.n_layers, RC.actor.n_heads, RC.kv_pages * PS, RC.actor.d_head)
    for n in aot.DECODE_CHUNK_SIZES:
        entry = entries[f"decode_chunk{n}"]
        fn, specs, outputs, donate = entry
        assert outputs == ["chunk_ids", "k_cache", "v_cache"]
        assert donate == (len(_actor_pspecs()), len(_actor_pspecs()) + 1)
        out = jax.eval_shape(fn, *specs)
        assert out[0].shape == (n, B) and out[0].dtype == jnp.int32
        assert out[1].shape == kv_shape and out[2].shape == kv_shape


def _actor_pspecs():
    return model.param_spec(RC.actor, "lm")
