"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed-seed numpy data keeps runs
deterministic per example.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    adam_update,
    decode_attention,
    flash_attention,
    flash_attention_fwd,
    layernorm,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bh=st.sampled_from([1, 2, 6]),
    s=st.sampled_from([16, 32, 64, 128]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_flash_fwd_matches_ref(bh, s, dh, seed):
    q, k, v = (rnd(seed + i, (bh, s, dh)) for i in range(3))
    out = flash_attention_fwd(q, k, v)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    s=st.sampled_from([16, 64]),
    block_q=st.sampled_from([8, 16]),
    block_k=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_flash_fwd_block_shape_invariance(s, block_q, block_k, seed):
    """Output must not depend on the tiling choice."""
    q, k, v = (rnd(seed + i, (2, s, 16)) for i in range(3))
    out = flash_attention_fwd(q, k, v, block_q=block_q, block_k=block_k)
    np.testing.assert_allclose(out, ref.attention_ref(q, k, v), rtol=2e-5, atol=2e-5)


def test_flash_fwd_bf16():
    q, k, v = (rnd(i, (2, 32, 16), jnp.bfloat16) for i in range(3))
    out = flash_attention_fwd(q, k, v)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32),
        ref.attention_ref(q, k, v).astype(jnp.float32),
        rtol=3e-2,
        atol=3e-2,
    )


def test_flash_causality():
    """Future K/V rows must not influence earlier outputs."""
    q, k, v = (rnd(i, (1, 32, 8)) for i in range(3))
    out1 = flash_attention_fwd(q, k, v)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    out2 = flash_attention_fwd(q, k2, v2)
    np.testing.assert_allclose(out1[:, :20], out2[:, :20], rtol=1e-6, atol=1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([16, 32]))
def test_flash_vjp_matches_ref_grads(seed, s):
    q, k, v = (rnd(seed + i, (2, s, 8)) for i in range(3))

    def loss_k(q, k, v):
        return (flash_attention(q, k, v) ** 2).sum()

    def loss_r(q, k, v):
        return (ref.attention_ref(q, k, v) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    bh=st.sampled_from([1, 4, 8]),
    smax=st.sampled_from([32, 64, 128]),
    dh=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
    frac=st.floats(0.0, 1.0),
)
def test_decode_matches_ref(bh, smax, dh, seed, frac):
    pos = int(frac * (smax - 1))
    q = rnd(seed, (bh, dh))
    k = rnd(seed + 1, (bh, smax, dh))
    v = rnd(seed + 2, (bh, smax, dh))
    out = decode_attention(q, k, v, jnp.array([pos], jnp.int32))
    np.testing.assert_allclose(
        out, ref.decode_attention_ref(q, k, v, pos), rtol=2e-5, atol=2e-5
    )


def test_decode_ignores_stale_cache():
    """Entries beyond `pos` are garbage from earlier sequences — must not leak."""
    q = rnd(0, (2, 8))
    k = rnd(1, (2, 64, 8))
    v = rnd(2, (2, 64, 8))
    pos = jnp.array([10], jnp.int32)
    out1 = decode_attention(q, k, v, pos)
    k2 = k.at[:, 11:].set(1e6)
    v2 = v.at[:, 11:].set(-1e6)
    out2 = decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_decode_block_invariance():
    q, k, v = rnd(0, (4, 16)), rnd(1, (4, 96, 16)), rnd(2, (4, 96, 16))
    pos = jnp.array([77], jnp.int32)
    a = decode_attention(q, k, v, pos, block_k=16)
    b = decode_attention(q, k, v, pos, block_k=96)
    np.testing.assert_allclose(a, b, rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([4, 32, 96]),
    d=st.sampled_from([16, 48, 128]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(n, d, seed):
    x = rnd(seed, (n, d), scale=3.0)
    g = rnd(seed + 1, (d,)) + 1.0
    b = rnd(seed + 2, (d,))
    np.testing.assert_allclose(layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)


def test_layernorm_output_stats():
    x = rnd(7, (64, 256), scale=10.0)
    y = layernorm(x, jnp.ones(256), jnp.zeros(256))
    np.testing.assert_allclose(np.mean(y, -1), np.zeros(64), atol=1e-5)
    np.testing.assert_allclose(np.std(y, -1), np.ones(64), atol=1e-3)


# ---------------------------------------------------------------------------
# fused adam
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 5, 4096, 5000]),
    t=st.integers(1, 100),
    seed=st.integers(0, 2**16),
    wd=st.sampled_from([0.0, 0.01]),
)
def test_adam_matches_ref(n, t, seed, wd):
    p = rnd(seed, (n,))
    m = rnd(seed + 1, (n,), scale=0.1)
    v = jnp.abs(rnd(seed + 2, (n,), scale=0.01))
    g = rnd(seed + 3, (n,))
    lr, b1, b2, eps = 1e-3, 0.9, 0.95, 1e-8
    hyper = jnp.array([lr, b1, b2, eps, wd, t, 0, 0], jnp.float32)
    out = adam_update(p, m, v, g, hyper)
    expect = ref.adam_ref(p, m, v, g, lr, b1, b2, eps, wd, float(t))
    for a, b in zip(out, expect):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_adam_descends_quadratic():
    """200 fused-Adam steps on f(p)=||p||² must shrink the iterate."""
    p = rnd(0, (64,), scale=2.0)
    m = jnp.zeros(64)
    v = jnp.zeros(64)
    for t in range(1, 201):
        g = 2.0 * p
        hyper = jnp.array([0.05, 0.9, 0.999, 1e-8, 0.0, t, 0, 0], jnp.float32)
        p, m, v = adam_update(p, m, v, g, hyper)
    assert float(jnp.abs(p).max()) < 0.05
