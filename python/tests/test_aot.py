"""AOT contract tests: the lowered HLO must execute (via jax itself) and the
manifest must describe exactly what rust will see."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import adam, aot, model
from compile.configs import run_config

RC = run_config("nano")


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries(RC)


def test_every_entry_traces(entries):
    # Lowering (tracing) every entry is the expensive part of `make
    # artifacts`; this asserts none of them fails to trace.
    for name, entry in entries.items():
        fn, specs = entry[0], entry[1]
        jax.eval_shape(fn, *specs)


def test_entry_names_complete(entries):
    expected = {
        "init_actor",
        "init_critic",
        "sft_step",
        "sft_eval",
        "rm_step",
        "rm_forward",
        "rm_eval",
        "logprobs_forward",
        "logits_forward",
        "critic_forward",
        "prefill",
        "decode_step",
        "prefill_slot",
        "decode_slots",
        "prefill_slot_paged",
        "decode_slots_paged",
        "prefill_sampled",
        "decode_step_sampled",
        "prefill_slot_sampled",
        "decode_slots_sampled",
        "prefill_slot_paged_sampled",
        "decode_slots_paged_sampled",
        "prefill_rng",
        "decode_step_rng",
        "prefill_slot_rng",
        "decode_slots_rng",
        "prefill_slot_paged_rng",
        "decode_slots_paged_rng",
        "ppo_actor_step",
        "ppo_critic_step",
        "ema_update",
    }
    expected |= {f"decode_chunk{n}" for n in aot.DECODE_CHUNK_SIZES}
    assert set(entries) == expected


def test_decode_entries_donate_kv(entries):
    """Every decode-family entry must donate exactly its K/V cache inputs
    (in-place cache update); admission/prefill entries must donate nothing
    (their prompt buffers are host-staged per call)."""
    na = len(model.param_spec(RC.actor, "lm"))
    donated = {
        "decode_step",
        "decode_slots",
        "decode_slots_paged",
        "decode_step_sampled",
        "decode_slots_sampled",
        "decode_slots_paged_sampled",
        "decode_step_rng",
        "decode_slots_rng",
        "decode_slots_paged_rng",
    } | {f"decode_chunk{n}" for n in aot.DECODE_CHUNK_SIZES}
    for name, entry in entries.items():
        donate = tuple(entry[3]) if len(entry) > 3 else ()
        if name in donated:
            assert donate == (na, na + 1), (name, donate)
        else:
            assert donate == (), (name, donate)


def test_sft_step_executes_and_reduces_loss(entries):
    fn, specs, _ = entries["sft_step"]
    na = len(model.param_spec(RC.actor, "lm"))
    noa = len(adam.opt_spec(RC.actor, "lm"))
    P = model.flatten_params(RC.actor, "lm", model.init_params(RC.actor, "lm", jnp.int32(0)))
    O = adam.init_opt(RC.actor, "lm")
    B, S = RC.batch, RC.seq_len
    start = jnp.arange(B, dtype=jnp.int32)[:, None]
    seq = (start + 3 * jnp.arange(S, dtype=jnp.int32)[None]) % RC.actor.vocab
    mask = jnp.ones((B, S - 1), jnp.float32)
    jfn = jax.jit(fn)
    losses = []
    for _ in range(12):
        out = jfn(*P, *O, seq, mask, jnp.float32(5e-3))
        P = list(out[:na])
        O = list(out[na : na + noa])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0], losses


def test_decode_step_artifact_consistency(entries):
    """prefill + decode artifacts must agree with the full forward."""
    pre_fn = entries["prefill"][0]
    dec_fn = entries["decode_step"][0]
    P = model.flatten_params(RC.actor, "lm", model.init_params(RC.actor, "lm", jnp.int32(0)))
    B, SP = RC.batch, RC.prompt_len
    prompt = jax.random.randint(jax.random.PRNGKey(0), (B, SP), 0, RC.actor.vocab)
    logits, kc, vc = jax.jit(pre_fn)(*P, prompt, jnp.zeros((B,), jnp.int32))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, kc, vc = jax.jit(dec_fn)(*P, kc, vc, tok, jnp.array([SP], jnp.int32))
    seq = jnp.concatenate([prompt, tok[:, None]], axis=1)
    params = model.unflatten_params(RC.actor, "lm", P)
    ref_logits = model.logits_fn(RC.actor, params, seq)[:, -1]
    np.testing.assert_allclose(logits2, ref_logits, rtol=2e-4, atol=2e-4)


def test_manifest_contents(tmp_path, entries):
    aot.build("nano", str(tmp_path), only={"init_actor", "logprobs_forward"})
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["run"] == "nano"
    assert man["config"]["batch"] == RC.batch
    assert man["config"]["seq_len"] == RC.seq_len
    assert man["config"]["sample_k"] == RC.sample_k
    # Variable-prompt-length capability: the rust runtime gates short-prompt
    # admission on this flag (absent in pre-padding artifact sets).
    assert man["config"]["padded_prompts"] is True
    # Block-paged serving capability + pool geometry: the rust runtime
    # gates paged serving (and shared-prefix reuse) on these.
    assert man["config"]["paged_kv"] is True
    assert man["config"]["page_size"] == RC.page_size
    assert man["config"]["kv_pages"] == RC.kv_pages
    # Lazy block-table capability: the rust runtime gates on-demand page
    # growth and pool oversubscription on this (absent in artifact sets
    # whose paged entries read unmasked table tails).
    assert man["config"]["lazy_kv"] is True
    assert len(man["actor_params"]) == len(model.param_spec(RC.actor, "lm"))
    assert len(man["actor_opt"]) == 2 * len(man["actor_params"]) + 1
    art = man["artifacts"]["logprobs_forward"]
    assert (tmp_path / art["file"]).exists()
    # input count = actor params + tokens
    assert len(art["inputs"]) == len(man["actor_params"]) + 1
    assert art["inputs"][-1]["dtype"] == "int32"
    hlo = (tmp_path / art["file"]).read_text()
    assert hlo.startswith("HloModule")


def test_hyper_vector_layout():
    """rust encodes (clip, ptx_coef) at hyper[0], hyper[1] — pin it."""
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, RC.actor.vocab)
    P = model.init_params(RC.actor, "lm", jnp.int32(0))
    old = model.token_logprobs(RC.actor, P, t)
    mask = jnp.ones_like(old)
    # ptx_coef=0 vs 1 must change the loss by exactly the sft term
    h0 = jnp.array([0.2, 0.0, 0, 0], jnp.float32)
    h1 = jnp.array([0.2, 1.0, 0, 0], jnp.float32)
    l0, _, _ = model.ppo_actor_loss(RC.actor, P, t, old, jnp.zeros_like(old), mask, t, h0)
    l1, _, _ = model.ppo_actor_loss(RC.actor, P, t, old, jnp.zeros_like(old), mask, t, h1)
    sft = model.sft_loss(RC.actor, P, t, jnp.ones_like(old))
    np.testing.assert_allclose(float(l1 - l0), float(sft), rtol=1e-5)
