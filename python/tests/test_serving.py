"""Continuous-batching model functions: per-slot prefill + per-row-pos decode.

These pin the invariants the rust scheduler (rust/src/serving) relies on:

  * `decode_slots` with a uniform position vector reproduces `decode_step`;
  * `prefill_slot` writes ONLY its slot's cache rows and reproduces the
    full-batch `prefill` logits for that sequence;
  * a staggered schedule (admit slot 0, decode, admit slot 1 mid-flight,
    decode both) yields, per sequence, the same logits as the no-cache full
    forward — slot isolation across admissions.

The Pallas kernels are swapped for their pure-jnp oracles (kernels/ref.py)
so the tests execute under any jax version; the kernels themselves are
checked against the same oracles in test_kernels.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import run_config
from compile.kernels import ref

RC = run_config("nano")
TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(autouse=True)
def ref_kernels(monkeypatch):
    """Run the model on the pure-jnp kernel oracles (forward-only tests)."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


def zero_caches():
    a = RC.actor
    shape = (a.n_layers, RC.batch * a.n_heads, RC.seq_len, a.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def sample_prompts(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (RC.batch, RC.prompt_len), 0, RC.actor.vocab
    ).astype(jnp.int32)


def test_decode_slots_uniform_pos_matches_decode_step(params):
    a, sp = RC.actor, RC.prompt_len
    prompt = sample_prompts(1)
    logits, kc, vc = model.prefill(a, params, prompt, RC.seq_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    l_shared, kc_s, vc_s = model.decode_step(
        a, params, kc, vc, tok, jnp.array([sp], jnp.int32)
    )
    pos = jnp.full((RC.batch,), sp, jnp.int32)
    l_slots, kc_p, vc_p = model.decode_slots(a, params, kc, vc, tok, pos)

    np.testing.assert_allclose(l_slots, l_shared, **TOL)
    np.testing.assert_allclose(kc_p, kc_s, **TOL)
    np.testing.assert_allclose(vc_p, vc_s, **TOL)


def test_prefill_slot_writes_only_its_rows(params):
    a, sp = RC.actor, RC.prompt_len
    h = a.n_heads
    prompt = sample_prompts(2)
    sentinel = 7.25
    kc = jnp.full_like(zero_caches()[0], sentinel)
    vc = jnp.full_like(kc, sentinel)

    slot = 1
    logits, kc2, vc2 = model.prefill_slot(
        a, params, kc, vc, prompt[slot : slot + 1], jnp.array([slot], jnp.int32)
    )

    # Rows outside [slot*h, slot*h + h) are untouched, as are positions >= sp.
    rows = np.arange(RC.batch * h)
    outside = (rows < slot * h) | (rows >= (slot + 1) * h)
    np.testing.assert_array_equal(np.asarray(kc2)[:, outside], sentinel)
    np.testing.assert_array_equal(np.asarray(vc2)[:, outside], sentinel)
    np.testing.assert_array_equal(np.asarray(kc2)[:, ~outside, sp:], sentinel)
    np.testing.assert_array_equal(np.asarray(vc2)[:, ~outside, sp:], sentinel)

    # The slot's rows now hold the same K/V the full-batch prefill computes,
    # and the returned logits match that sequence's prefill logits.
    full_logits, full_kc, full_vc = model.prefill(a, params, prompt, RC.seq_len)
    np.testing.assert_allclose(
        np.asarray(kc2)[:, ~outside, :sp],
        np.asarray(full_kc)[:, ~outside, :sp],
        **TOL,
    )
    np.testing.assert_allclose(
        np.asarray(vc2)[:, ~outside, :sp],
        np.asarray(full_vc)[:, ~outside, :sp],
        **TOL,
    )
    np.testing.assert_allclose(logits[0], full_logits[slot], **TOL)


def test_staggered_schedule_matches_full_forward(params):
    """Admit slot 0, decode it alone, admit slot 1 two ticks later, decode
    both — every emitted logits row must equal the no-cache full forward on
    that sequence's prefix (cross-slot isolation under staggered admission)."""
    a, sp = RC.actor, RC.prompt_len
    prompts = sample_prompts(3)
    kc, vc = zero_caches()

    def ref_logits(tokens):
        seq = jnp.asarray(tokens, jnp.int32)[None, :]
        return model.logits_fn(a, params, seq)[0, -1]

    def check(row, tokens):
        np.testing.assert_allclose(row, ref_logits(tokens), **TOL)

    seqs = [list(np.asarray(prompts[0])), list(np.asarray(prompts[1]))]
    pending = [None, None]  # last logits row per slot, None = not admitted

    # Tick 0: admit sequence 0 into slot 0.
    l0, kc, vc = model.prefill_slot(
        a, params, kc, vc, prompts[0:1], jnp.array([0], jnp.int32)
    )
    check(l0[0], seqs[0])
    pending[0] = l0[0]

    for tick in range(4):
        if tick == 2:
            # Mid-flight admission into the free slot.
            l1, kc, vc = model.prefill_slot(
                a, params, kc, vc, prompts[1:2], jnp.array([1], jnp.int32)
            )
            check(l1[0], seqs[1])
            pending[1] = l1[0]
        toks, pos, active = [], [], []
        for slot in range(2):
            if pending[slot] is None:
                toks.append(0)
                pos.append(0)
                active.append(False)
            else:
                t = int(jnp.argmax(pending[slot]))
                seqs[slot].append(t)
                toks.append(t)
                pos.append(len(seqs[slot]) - 1)
                active.append(True)
        logits, kc, vc = model.decode_slots(
            a,
            params,
            kc,
            vc,
            jnp.array(toks, jnp.int32),
            jnp.array(pos, jnp.int32),
        )
        for slot in range(2):
            if active[slot]:
                check(logits[slot], seqs[slot])
                pending[slot] = logits[slot]

    # Both sequences advanced to different depths in the shared cache.
    assert len(seqs[0]) == sp + 4
    assert len(seqs[1]) == sp + 2
