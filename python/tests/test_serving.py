"""Continuous-batching model functions: per-slot prefill + per-row-pos decode.

These pin the invariants the rust scheduler (rust/src/serving) relies on:

  * `decode_slots` with a uniform position vector reproduces `decode_step`;
  * `prefill_slot` writes ONLY its slot's cache rows and reproduces the
    full-batch `prefill` logits for that sequence;
  * a staggered schedule (admit slot 0, decode, admit slot 1 mid-flight,
    decode both) yields, per sequence, the same logits as the no-cache full
    forward — slot isolation across admissions;
  * the LEFT-PADDED variable-length path: `prefill`/`prefill_slot` with a
    per-row `start` (valid-start) mask reproduce the exact-length unpadded
    computation for EVERY valid_start in 0..prompt_len, `start == 0` is
    bit-identical to the legacy fixed-length path, and a mixed-length
    staggered schedule through `decode_slots(start=...)` matches the
    no-cache full forward per sequence.

The Pallas kernels are swapped for their pure-jnp oracles (kernels/ref.py)
so the tests execute under any jax version; the kernels themselves are
checked against the same oracles in test_kernels.py and (for the padded
variants) in the kernel-parity section at the bottom of this file, which
skips itself when the installed jax cannot run pallas interpret mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import run_config
from compile.kernels import ref
from compile.kernels.attention import flash_attention_padded_fwd
from compile.kernels.decode import decode_attention_pbs

RC = run_config("nano")
TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(autouse=True)
def ref_kernels(monkeypatch):
    """Run the model on the pure-jnp kernel oracles (forward-only tests)."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_padded_fwd", ref.attention_padded_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)
    monkeypatch.setattr(model, "decode_attention_pbs", ref.decode_attention_pbs_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


def zero_caches():
    a = RC.actor
    shape = (a.n_layers, RC.batch * a.n_heads, RC.seq_len, a.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def sample_prompts(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (RC.batch, RC.prompt_len), 0, RC.actor.vocab
    ).astype(jnp.int32)


def test_decode_slots_uniform_pos_matches_decode_step(params):
    a, sp = RC.actor, RC.prompt_len
    prompt = sample_prompts(1)
    logits, kc, vc = model.prefill(a, params, prompt, RC.seq_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    l_shared, kc_s, vc_s = model.decode_step(
        a, params, kc, vc, tok, jnp.array([sp], jnp.int32)
    )
    pos = jnp.full((RC.batch,), sp, jnp.int32)
    l_slots, kc_p, vc_p = model.decode_slots(a, params, kc, vc, tok, pos)

    np.testing.assert_allclose(l_slots, l_shared, **TOL)
    np.testing.assert_allclose(kc_p, kc_s, **TOL)
    np.testing.assert_allclose(vc_p, vc_s, **TOL)


def test_prefill_slot_writes_only_its_rows(params):
    a, sp = RC.actor, RC.prompt_len
    h = a.n_heads
    prompt = sample_prompts(2)
    sentinel = 7.25
    kc = jnp.full_like(zero_caches()[0], sentinel)
    vc = jnp.full_like(kc, sentinel)

    slot = 1
    logits, kc2, vc2 = model.prefill_slot(
        a, params, kc, vc, prompt[slot : slot + 1], jnp.array([slot], jnp.int32)
    )

    # Rows outside [slot*h, slot*h + h) are untouched, as are positions >= sp.
    rows = np.arange(RC.batch * h)
    outside = (rows < slot * h) | (rows >= (slot + 1) * h)
    np.testing.assert_array_equal(np.asarray(kc2)[:, outside], sentinel)
    np.testing.assert_array_equal(np.asarray(vc2)[:, outside], sentinel)
    np.testing.assert_array_equal(np.asarray(kc2)[:, ~outside, sp:], sentinel)
    np.testing.assert_array_equal(np.asarray(vc2)[:, ~outside, sp:], sentinel)

    # The slot's rows now hold the same K/V the full-batch prefill computes,
    # and the returned logits match that sequence's prefill logits.
    full_logits, full_kc, full_vc = model.prefill(a, params, prompt, RC.seq_len)
    np.testing.assert_allclose(
        np.asarray(kc2)[:, ~outside, :sp],
        np.asarray(full_kc)[:, ~outside, :sp],
        **TOL,
    )
    np.testing.assert_allclose(
        np.asarray(vc2)[:, ~outside, :sp],
        np.asarray(full_vc)[:, ~outside, :sp],
        **TOL,
    )
    np.testing.assert_allclose(logits[0], full_logits[slot], **TOL)


def test_staggered_schedule_matches_full_forward(params):
    """Admit slot 0, decode it alone, admit slot 1 two ticks later, decode
    both — every emitted logits row must equal the no-cache full forward on
    that sequence's prefix (cross-slot isolation under staggered admission)."""
    a, sp = RC.actor, RC.prompt_len
    prompts = sample_prompts(3)
    kc, vc = zero_caches()

    def ref_logits(tokens):
        seq = jnp.asarray(tokens, jnp.int32)[None, :]
        return model.logits_fn(a, params, seq)[0, -1]

    def check(row, tokens):
        np.testing.assert_allclose(row, ref_logits(tokens), **TOL)

    seqs = [list(np.asarray(prompts[0])), list(np.asarray(prompts[1]))]
    pending = [None, None]  # last logits row per slot, None = not admitted

    # Tick 0: admit sequence 0 into slot 0.
    l0, kc, vc = model.prefill_slot(
        a, params, kc, vc, prompts[0:1], jnp.array([0], jnp.int32)
    )
    check(l0[0], seqs[0])
    pending[0] = l0[0]

    for tick in range(4):
        if tick == 2:
            # Mid-flight admission into the free slot.
            l1, kc, vc = model.prefill_slot(
                a, params, kc, vc, prompts[1:2], jnp.array([1], jnp.int32)
            )
            check(l1[0], seqs[1])
            pending[1] = l1[0]
        toks, pos, active = [], [], []
        for slot in range(2):
            if pending[slot] is None:
                toks.append(0)
                pos.append(0)
                active.append(False)
            else:
                t = int(jnp.argmax(pending[slot]))
                seqs[slot].append(t)
                toks.append(t)
                pos.append(len(seqs[slot]) - 1)
                active.append(True)
        logits, kc, vc = model.decode_slots(
            a,
            params,
            kc,
            vc,
            jnp.array(toks, jnp.int32),
            jnp.array(pos, jnp.int32),
        )
        for slot in range(2):
            if active[slot]:
                check(logits[slot], seqs[slot])
                pending[slot] = logits[slot]

    # Both sequences advanced to different depths in the shared cache.
    assert len(seqs[0]) == sp + 4
    assert len(seqs[1]) == sp + 2


# ---------------------------------------------------------------------------
# Left-padded variable-length prompts (per-row valid-start masking).
#
# The contract the rust scheduler relies on: a prompt of true length
# L <= prompt_len arrives LEFT-PADDED into the fixed AOT shape with
# start = prompt_len - L; attention masks keys before start and position
# embeddings are shifted so the real positions compute exactly what the
# unpadded exact-length prompt computes.
# ---------------------------------------------------------------------------

PAD = 0  # mirrors the rust Vocab::PAD token


def left_pad(rows, start):
    """rows: [b, L] -> [b, start + L] with PAD tokens on the left."""
    b = rows.shape[0]
    pad = jnp.full((b, start), PAD, jnp.int32)
    return jnp.concatenate([pad, rows], axis=1)


@pytest.mark.parametrize("start", list(range(RC.prompt_len)))
def test_padded_prefill_matches_exact_length_for_every_start(params, start):
    """Masked full-batch prefill of a left-padded length-L prompt vs the
    unpadded prompt prefilled at its exact length: last-position logits and
    the slot's real cache entries must agree BIT-EXACTLY, for every
    valid_start — masked-out padding contributes exact zeros to every
    softmax-weighted sum (and the leading fully-masked region is rescaled
    away by exp(-inf) = 0), so no tolerance is needed."""
    a, sp = RC.actor, RC.prompt_len
    L = sp - start
    exact = sample_prompts(10 + start)[:, :L]
    padded = left_pad(exact, start)
    starts = jnp.full((RC.batch,), start, jnp.int32)

    le, kce, vce = model.prefill(a, params, exact, RC.seq_len)
    lp, kcp, vcp = model.prefill(a, params, padded, RC.seq_len, starts)

    np.testing.assert_array_equal(np.asarray(lp), np.asarray(le))
    # Real cache entries live at artifact positions [start, sp) and must
    # hold what the exact-length prefill wrote at [0, L).
    np.testing.assert_array_equal(
        np.asarray(kcp)[:, :, start:sp], np.asarray(kce)[:, :, :L]
    )
    np.testing.assert_array_equal(
        np.asarray(vcp)[:, :, start:sp], np.asarray(vce)[:, :, :L]
    )


def test_padded_prefill_all_valid_row_is_bit_identical_to_unmasked(params):
    """start == 0 (the all-valid row) pins backward compatibility: the
    masked path must reproduce the legacy unmasked prefill bit for bit."""
    a = RC.actor
    prompt = sample_prompts(4)
    l0, kc0, vc0 = model.prefill(a, params, prompt, RC.seq_len)
    lz, kcz, vcz = model.prefill(
        a, params, prompt, RC.seq_len, jnp.zeros((RC.batch,), jnp.int32)
    )
    np.testing.assert_array_equal(np.asarray(lz), np.asarray(l0))
    np.testing.assert_array_equal(np.asarray(kcz), np.asarray(kc0))
    np.testing.assert_array_equal(np.asarray(vcz), np.asarray(vc0))


@pytest.mark.parametrize("start", [1, RC.prompt_len // 2, RC.prompt_len - 5])
def test_padded_prefill_slot_matches_exact_length(params, start):
    """Slot admission of a left-padded short prompt: the admitted slot's
    logits equal the exact-length prefill's, other slots' rows untouched."""
    a, sp = RC.actor, RC.prompt_len
    h = a.n_heads
    L = sp - start
    exact = sample_prompts(20 + start)[:1, :L]
    padded = left_pad(exact, start)
    sentinel = 7.25
    kc = jnp.full_like(zero_caches()[0], sentinel)
    vc = jnp.full_like(kc, sentinel)

    slot = 1
    logits, kc2, vc2 = model.prefill_slot(
        a,
        params,
        kc,
        vc,
        padded,
        jnp.array([slot], jnp.int32),
        jnp.array([start], jnp.int32),
    )
    le, _, _ = model.prefill(a, params, exact, RC.seq_len)
    np.testing.assert_allclose(logits[0], le[0], **TOL)
    rows = np.arange(RC.batch * h)
    outside = (rows < slot * h) | (rows >= (slot + 1) * h)
    np.testing.assert_array_equal(np.asarray(kc2)[:, outside], sentinel)
    np.testing.assert_array_equal(np.asarray(vc2)[:, outside], sentinel)


def test_mixed_length_staggered_schedule_matches_full_forward(params):
    """The full mixed-length serving discipline: a full-length prompt in
    slot 0, a SHORT left-padded prompt admitted into slot 1 mid-flight,
    both advanced by `decode_slots` with per-slot valid starts — every
    emitted logits row must equal the no-cache forward on that sequence's
    true (unpadded) token prefix."""
    a, sp = RC.actor, RC.prompt_len
    L1 = sp - 3  # short prompt's true length
    prompts = sample_prompts(31)
    kc, vc = zero_caches()

    def ref_logits(tokens):
        seq = jnp.asarray(tokens, jnp.int32)[None, :]
        return model.logits_fn(a, params, seq)[0, -1]

    def check(row, tokens):
        np.testing.assert_allclose(row, ref_logits(tokens), **TOL)

    # True token lists (no padding) per slot; slot 1 not yet admitted.
    seqs = [list(np.asarray(prompts[0])), list(np.asarray(prompts[1][:L1]))]
    starts = [0, sp - L1]
    pending = [None, None]

    l0, kc, vc = model.prefill_slot(
        a,
        params,
        kc,
        vc,
        prompts[0:1],
        jnp.array([0], jnp.int32),
        jnp.array([0], jnp.int32),
    )
    check(l0[0], seqs[0])
    pending[0] = l0[0]

    for tick in range(4):
        if tick == 2:
            short = left_pad(prompts[1:2, :L1], starts[1])
            l1, kc, vc = model.prefill_slot(
                a,
                params,
                kc,
                vc,
                short,
                jnp.array([1], jnp.int32),
                jnp.array([starts[1]], jnp.int32),
            )
            check(l1[0], seqs[1])
            pending[1] = l1[0]
        toks, pos, st, active = [], [], [], []
        for slot in range(2):
            if pending[slot] is None:
                toks.append(0)
                pos.append(0)
                st.append(0)
                active.append(False)
            else:
                t = int(jnp.argmax(pending[slot]))
                seqs[slot].append(t)
                toks.append(t)
                # Artifact cache position of the token = valid start + its
                # index within the true sequence.
                pos.append(starts[slot] + len(seqs[slot]) - 1)
                st.append(starts[slot])
                active.append(True)
        logits, kc, vc = model.decode_slots(
            a,
            params,
            kc,
            vc,
            jnp.array(toks, jnp.int32),
            jnp.array(pos, jnp.int32),
            jnp.array(st, jnp.int32),
        )
        for slot in range(2):
            if active[slot]:
                check(logits[slot], seqs[slot])
                pending[slot] = logits[slot]

    # The short sequence advanced past the fixed prompt boundary: its pads
    # never leaked into attention despite sharing the cache with a
    # full-length neighbor.
    assert len(seqs[0]) == sp + 4
    assert len(seqs[1]) == L1 + 2


# ---------------------------------------------------------------------------
# Pallas kernel parity for the padded variants (kernel vs jnp oracle).
# Skips itself when the installed jax cannot execute pallas interpret mode
# (a known-broken combination exists in some containers); the oracle-level
# tests above pin the model math either way, and the same oracles are what
# the kernels are compared against here.
# ---------------------------------------------------------------------------


def _pallas_interpret_works():
    try:
        from compile.kernels.attention import flash_attention_fwd

        z = jnp.zeros((1, 8, 4), jnp.float32)
        flash_attention_fwd(z, z, z)
        return True
    except Exception:
        return False


pallas_parity = pytest.mark.skipif(
    not _pallas_interpret_works(),
    reason="pallas interpret mode unavailable under the installed jax",
)


def _qkv(seed, s=8, bh=4, dh=16):
    key = jax.random.PRNGKey(seed)
    mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (bh, s, dh), jnp.float32)
    return mk(0), mk(1), mk(2)


@pallas_parity
@pytest.mark.parametrize("start", list(range(RC.prompt_len)))
def test_padded_flash_kernel_matches_oracle_for_every_start(start):
    q, k, v = _qkv(start, s=RC.prompt_len)
    starts = jnp.full((q.shape[0],), start, jnp.int32)
    out = flash_attention_padded_fwd(q, k, v, starts)
    want = ref.attention_padded_ref(q, k, v, starts)
    # Pad query rows (positions < start) are don't-care but must be finite.
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(
        np.asarray(out)[:, start:], np.asarray(want)[:, start:], **TOL
    )


@pallas_parity
def test_padded_flash_kernel_all_valid_matches_unmasked_kernel():
    from compile.kernels.attention import flash_attention_fwd

    q, k, v = _qkv(99)
    zeros = jnp.zeros((q.shape[0],), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(flash_attention_padded_fwd(q, k, v, zeros)),
        np.asarray(flash_attention_fwd(q, k, v)),
    )


@pallas_parity
@pytest.mark.parametrize("start", [0, 3, 7])
def test_padded_decode_kernel_matches_oracle(start):
    bh, smax, dh = 4, 16, 8
    key = jax.random.PRNGKey(start)
    q = jax.random.normal(key, (bh, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, smax, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, smax, dh), jnp.float32)
    pos = jnp.array([start + 1, start + 3, smax - 1, start], jnp.int32)
    starts = jnp.full((bh,), start, jnp.int32)
    out = decode_attention_pbs(q, k, v, pos, starts)
    want = ref.decode_attention_pbs_ref(q, k, v, pos, starts)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)
