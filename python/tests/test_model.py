"""L2 correctness: model shapes, generation/training consistency, losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import adam, model
from compile.configs import model_config, run_config

CFG = model_config("nano")


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, "lm", jnp.int32(0))


@pytest.fixture(scope="module")
def sparams():
    return model.init_params(CFG, "scalar", jnp.int32(1))


def toks(key, b, s, vocab=None):
    return jax.random.randint(jax.random.PRNGKey(key), (b, s), 0, vocab or CFG.vocab)


# ---------------------------------------------------------------------------
# shapes & flatten contract
# ---------------------------------------------------------------------------


def test_param_spec_roundtrip(params):
    flat = model.flatten_params(CFG, "lm", params)
    back = model.unflatten_params(CFG, "lm", flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_param_count_matches_config():
    spec = model.param_spec(CFG, "lm")
    total = sum(int(np.prod(s)) for _, s in spec)
    assert total == CFG.n_params()


def test_forward_shapes(params, sparams):
    t = toks(0, 2, 16)
    assert model.logits_fn(CFG, params, t).shape == (2, 16, CFG.vocab)
    assert model.token_logprobs(CFG, params, t).shape == (2, 15)
    assert model.values_fn(CFG, sparams, t).shape == (2, 16)
    lens = jnp.array([15, 7], jnp.int32)
    assert model.rewards_fn(CFG, sparams, t, lens).shape == (2,)


def test_logprobs_are_logprobs(params):
    t = toks(1, 2, 16)
    lp = model.token_logprobs(CFG, params, t)
    assert (np.asarray(lp) <= 1e-6).all()


def test_reward_picks_len_position(sparams):
    t = toks(2, 2, 16)
    v = model.values_fn(CFG, sparams, t)
    lens = jnp.array([3, 12], jnp.int32)
    r = model.rewards_fn(CFG, sparams, t, lens)
    np.testing.assert_allclose(r, np.asarray(v)[np.arange(2), [3, 12]], rtol=1e-6)


# ---------------------------------------------------------------------------
# generation == training forward (the hybrid-engine consistency invariant:
# the inference-mode path must produce exactly the same distribution the
# training-mode path scores).
# ---------------------------------------------------------------------------


def test_decode_matches_full_forward(params):
    b, sp, sg = 2, 8, 6
    smax = sp + sg
    prompt = toks(3, b, sp)
    logits_full = model.logits_fn(CFG, params, prompt)
    logits_pre, kc, vc = model.prefill(CFG, params, prompt, smax)
    np.testing.assert_allclose(logits_pre, logits_full[:, -1], rtol=1e-4, atol=1e-4)

    # Greedy-decode a few tokens; at each step the decode path must match a
    # fresh full forward over the growing sequence.
    seq = prompt
    tok = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    for i in range(sg):
        pos = jnp.array([sp + i], jnp.int32)
        logits_dec, kc, vc = model.decode_step(CFG, params, kc, vc, tok, pos)
        seq = jnp.concatenate([seq, tok[:, None]], axis=1)
        logits_ref = model.logits_fn(CFG, params, seq)[:, -1]
        np.testing.assert_allclose(logits_dec, logits_ref, rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(logits_dec, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def test_sft_loss_uniform_at_init(params):
    """Fresh model ≈ uniform predictions -> CE ≈ log(vocab)."""
    t = toks(4, 4, 32)
    mask = jnp.ones((4, 31), jnp.float32)
    loss = float(model.sft_loss(CFG, params, t, mask))
    assert abs(loss - np.log(CFG.vocab)) < 0.5


def test_sft_loss_mask_selects_positions(params):
    t = toks(5, 2, 16)
    m0 = jnp.zeros((2, 15), jnp.float32).at[:, :5].set(1.0)
    m1 = jnp.zeros((2, 15), jnp.float32).at[:, 5:].set(1.0)
    full = jnp.ones((2, 15), jnp.float32)
    l0 = float(model.sft_loss(CFG, params, t, m0))
    l1 = float(model.sft_loss(CFG, params, t, m1))
    lf = float(model.sft_loss(CFG, params, t, full))
    np.testing.assert_allclose(lf, (l0 * 10 + l1 * 20) / 30, rtol=1e-5)


def test_rm_loss_symmetry(sparams):
    c, r = toks(6, 2, 16), toks(7, 2, 16)
    lens = jnp.full((2,), 15, jnp.int32)
    l_cr, acc_cr = model.rm_pair_loss(CFG, sparams, c, r, lens, lens)
    l_rc, acc_rc = model.rm_pair_loss(CFG, sparams, r, c, lens, lens)
    # -log sigmoid(x) + -log sigmoid(-x) >= 2 log 2, equality iff x = 0
    assert float(l_cr + l_rc) >= 2 * np.log(2.0) - 1e-5
    assert abs(float(acc_cr + acc_rc) - 1.0) <= 0.5 + 1e-6  # ties allowed


def test_ppo_actor_loss_zero_adv_no_gradient_signal(params):
    """adv == 0 and ptx_coef == 0 -> surrogate loss is exactly 0."""
    t = toks(8, 2, 16)
    old_logp = model.token_logprobs(CFG, params, t)
    zeros = jnp.zeros_like(old_logp)
    mask = jnp.ones_like(old_logp)
    hyper = jnp.array([0.2, 0.0, 0, 0], jnp.float32)
    loss, kl, clipfrac = model.ppo_actor_loss(
        CFG, params, t, old_logp, zeros, mask, t, hyper
    )
    assert abs(float(loss)) < 1e-6
    assert abs(float(kl)) < 1e-6
    assert float(clipfrac) == 0.0


def test_ppo_actor_loss_positive_adv_pushes_up(params):
    """With adv > 0, the gradient must increase the chosen tokens' logprobs."""
    t = toks(9, 2, 16)
    old_logp = model.token_logprobs(CFG, params, t)
    adv = jnp.ones_like(old_logp)
    mask = jnp.ones_like(old_logp)
    hyper = jnp.array([0.2, 0.0, 0, 0], jnp.float32)
    flat = model.flatten_params(CFG, "lm", params)

    def loss_fn(fl):
        loss, _, _ = model.ppo_actor_loss(
            CFG, model.unflatten_params(CFG, "lm", fl), t, old_logp, adv, mask, t, hyper
        )
        return loss

    grads = jax.grad(loss_fn)(flat)
    # One SGD step against the gradient must raise the mean logprob.
    stepped = [p - 0.5 * g for p, g in zip(flat, grads)]
    lp2 = model.token_logprobs(CFG, model.unflatten_params(CFG, "lm", stepped), t)
    assert float(lp2.mean()) > float(old_logp.mean())


def test_ppo_critic_loss_perfect_values_is_zero(sparams):
    t = toks(10, 2, 16)
    v = model.values_fn(CFG, sparams, t)[:, :-1]
    mask = jnp.ones_like(v)
    hyper = jnp.array([0.2, 0, 0, 0], jnp.float32)
    loss = model.ppo_critic_loss(CFG, sparams, t, v, v, mask, hyper)
    assert abs(float(loss)) < 1e-8


def test_ema_update_converges_toward_params(params):
    flat = model.flatten_params(CFG, "lm", params)
    ema = [jnp.zeros_like(p) for p in flat]
    for _ in range(60):
        ema = model.ema_update(ema, flat, jnp.float32(0.9))
    for e, p in zip(ema, flat):
        np.testing.assert_allclose(e, p, rtol=0, atol=2e-2 * (1 + float(jnp.abs(p).max())))


# ---------------------------------------------------------------------------
# training actually learns (micro end-to-end at nano scale)
# ---------------------------------------------------------------------------


def test_sft_training_reduces_loss(params):
    flat = model.flatten_params(CFG, "lm", params)
    opt = adam.init_opt(CFG, "lm")
    # Deterministic structured data: token i+1 = (token i + 3) mod vocab.
    start = jnp.arange(4, dtype=jnp.int32)[:, None]
    seq = (start + 3 * jnp.arange(16, dtype=jnp.int32)[None]) % CFG.vocab
    mask = jnp.ones((4, 15), jnp.float32)

    def loss_fn(fl):
        return model.sft_loss(CFG, model.unflatten_params(CFG, "lm", fl), seq, mask)

    l0 = float(loss_fn(flat))
    step = jax.jit(
        lambda fl, op: (lambda l, g: (l, *adam.apply_adam(fl, op, g, jnp.float32(3e-3))))(
            *jax.value_and_grad(loss_fn)(fl)
        )
    )
    for _ in range(30):
        _, flat, opt = step(flat, opt)
    l1 = float(loss_fn(flat))
    assert l1 < l0 * 0.5, (l0, l1)
