"""Lazy block tables: dead tail entries alias garbage page 0, bit-safely.

These pin the `lazy_kv` artifact capability the rust oversubscribed allocator
(rust/src/hybrid/kv.rs) relies on: a slot's block table is always shaped for
the full `max_blocks` window, but only the first `ceil((pos+1) / page_size)`
entries need to name real pages — the rest may point at the reserved garbage
page 0 (or any valid pool page holding finite junk), because

  * reads mask every score at `idx > pos` to NEG_INF, so a dead entry's K
    feeds a zero softmax weight and its V is multiplied by exactly 0;
  * writes only target the single page holding the written position, which
    the rust `reserve_rows` maps before dispatching the decode step;
  * a right-padded short prompt's padding-tail K/V writes land in page 0
    itself — storage no live slot attends.

Each test runs the SAME traffic twice — once with fully-mapped tables, once
with tables grown one page per boundary crossing (the rust allocator's
discipline) — and requires BIT-IDENTICAL outputs at every step. Page 0 is
poisoned with large finite garbage first, so a table tail that were actually
read (rather than masked) would corrupt the bits and fail loudly.

The Pallas kernel itself is checked in the parity section at the bottom,
which skips itself when the installed jax cannot run pallas interpret mode
(same discipline as test_paged.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import run_config
from compile.kernels import ref
from compile.kernels.decode import decode_attention_paged, decode_attention_pb

RC = run_config("nano")
PAD = 0  # mirrors the rust Vocab::PAD token

# Small-page geometry: nano's seq_len = 16 split into 4-token pages so a
# full-window sequence spans 4 blocks — decode crosses page boundaries at
# pos 8 and 12, and the prompt (sp = 8) covers exactly 2 of the 4 blocks.
PS4 = 4
MB4 = RC.seq_len // PS4
N_PAGES = RC.batch * MB4 + 1  # page 0 reserved as garbage
POISON = 1.0e4  # finite, loud; inf/nan would break the 0-weight argument

# Fully-mapped tables: a deliberate non-identity page assignment.
FULL_BT = np.array([[3, 1, 4, 2], [7, 5, 8, 6]], np.int32)


@pytest.fixture(autouse=True)
def ref_kernels(monkeypatch):
    """Run the model on the pure-jnp kernel oracles (forward-only tests)."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_padded_fwd", ref.attention_padded_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)
    monkeypatch.setattr(model, "decode_attention_pbs", ref.decode_attention_pbs_ref)
    monkeypatch.setattr(model, "decode_attention_paged", ref.decode_attention_paged_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


def poisoned_caches():
    """Zero page pools with garbage page 0 poisoned (finite, large)."""
    a = RC.actor
    shape = (a.n_layers, a.n_heads, N_PAGES * PS4, a.d_head)
    kc = np.zeros(shape, np.float32)
    kc[:, :, :PS4, :] = POISON
    return jnp.asarray(kc), jnp.asarray(kc.copy())


def live_blocks(pos):
    """Blocks a row at logical position `pos` has really written: the rust
    allocator maps exactly these and parks the tail on page 0."""
    return (pos + PS4) // PS4  # == ceil((pos + 1) / PS4)


def lazy_row(full_row, pos):
    n = live_blocks(pos)
    out = np.zeros_like(full_row)
    out[:n] = full_row[:n]
    return out


def sample_prompts(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (RC.batch, RC.prompt_len), 0, RC.actor.vocab
    ).astype(jnp.int32)


def right_pad(row, sp):
    L = row.shape[1]
    return jnp.concatenate([row, jnp.full((1, sp - L), PAD, jnp.int32)], axis=1)


def scatter_pool(contig, bt, poison_page0=True):
    """Contiguous [b*h, smax, dh] -> poisoned [h, N_PAGES*PS4, dh] pool."""
    b, mb = bt.shape
    bh, smax, dh = contig.shape
    h = bh // b
    assert smax == mb * PS4
    pool = np.zeros((h, N_PAGES * PS4, dh), np.float32)
    if poison_page0:
        pool[:, :PS4] = POISON
    c = np.asarray(contig).reshape(b, h, smax, dh)
    for s in range(b):
        for blk in range(mb):
            page = int(bt[s, blk])
            pool[:, page * PS4 : (page + 1) * PS4] = c[s, :, blk * PS4 : (blk + 1) * PS4]
    return jnp.asarray(pool)


# ---------------------------------------------------------------------------
# Oracle-level: at EVERY position, a table whose dead tail points at the
# poisoned garbage page is bit-identical to the fully-mapped table.
# ---------------------------------------------------------------------------


def test_dead_tail_table_matches_full_table_at_every_pos():
    a = RC.actor
    bh = RC.batch * a.n_heads
    smax = MB4 * PS4
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (bh, a.d_head), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, smax, a.d_head))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, smax, a.d_head))
    kp, vp = scatter_pool(k, FULL_BT), scatter_pool(v, FULL_BT)

    for p in range(smax):
        pos = jnp.full((bh,), p, jnp.int32)
        lazy_bt = np.stack([lazy_row(FULL_BT[s], p) for s in range(RC.batch)])
        out_lazy = ref.decode_attention_paged_ref(q, kp, vp, pos, jnp.asarray(lazy_bt), PS4)
        out_full = ref.decode_attention_paged_ref(q, kp, vp, pos, jnp.asarray(FULL_BT), PS4)
        np.testing.assert_array_equal(
            np.asarray(out_lazy), np.asarray(out_full), err_msg=f"pos {p}"
        )
        # And both equal the contiguous oracle — the tail truly never leaks.
        want = ref.decode_attention_pb_ref(q, k, v, pos)
        np.testing.assert_array_equal(np.asarray(out_full), np.asarray(want))


def test_mixed_depth_rows_grow_independently():
    """Rows at different depths carry different live-block counts in ONE
    batched call — the per-row mask keeps each row's dead tail inert."""
    a = RC.actor
    bh = RC.batch * a.n_heads
    smax = MB4 * PS4
    key = jax.random.PRNGKey(12)
    q = jax.random.normal(key, (bh, a.d_head), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, smax, a.d_head))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, smax, a.d_head))
    kp, vp = scatter_pool(k, FULL_BT), scatter_pool(v, FULL_BT)

    slot_pos = [2, smax - 3]  # 1 live block vs 4 live blocks
    pos = jnp.asarray(np.repeat(slot_pos, a.n_heads).astype(np.int32))
    lazy_bt = np.stack([lazy_row(FULL_BT[s], slot_pos[s]) for s in range(RC.batch)])
    out_lazy = ref.decode_attention_paged_ref(q, kp, vp, pos, jnp.asarray(lazy_bt), PS4)
    out_full = ref.decode_attention_paged_ref(q, kp, vp, pos, jnp.asarray(FULL_BT), PS4)
    np.testing.assert_array_equal(np.asarray(out_lazy), np.asarray(out_full))


# ---------------------------------------------------------------------------
# Model-level: the full admit -> greedy-decode chain with tables grown one
# page per boundary crossing is bit-identical to fully-mapped tables.
# ---------------------------------------------------------------------------


def test_lazy_growth_chain_bit_matches_full_tables(params):
    """Both slots admitted full-length, then greedily decoded to the window
    edge. The lazy run starts with only the prompt's 2 blocks mapped and
    maps block `pos // PS4` right before the step that writes into it —
    exactly the rust `reserve_rows`-before-dispatch discipline. Every
    logits row must match the fully-mapped run BIT-EXACTLY."""
    a, sp = RC.actor, RC.prompt_len
    prompts = sample_prompts(21)
    full_bt = jnp.asarray(FULL_BT)
    lazy_bt = np.stack([lazy_row(FULL_BT[s], sp - 1) for s in range(RC.batch)])
    assert live_blocks(sp - 1) == 2  # prompt covers half the window

    kcf, vcf = poisoned_caches()
    kcl, vcl = poisoned_caches()
    full_logits, lazy_logits = [], []
    for slot in range(RC.batch):
        lf, kcf, vcf = model.prefill_slot_paged(
            a, params, kcf, vcf, prompts[slot : slot + 1],
            full_bt[slot : slot + 1], jnp.array([sp - 1], jnp.int32), PS4,
        )
        ll, kcl, vcl = model.prefill_slot_paged(
            a, params, kcl, vcl, prompts[slot : slot + 1],
            jnp.asarray(lazy_bt[slot : slot + 1]), jnp.array([sp - 1], jnp.int32), PS4,
        )
        np.testing.assert_array_equal(np.asarray(ll[0]), np.asarray(lf[0]))
        full_logits.append(lf[0])
        lazy_logits.append(ll[0])

    pos = [sp, sp]
    for step in range(RC.gen_len - 1):
        toks = jnp.array(
            [int(jnp.argmax(full_logits[s])) for s in range(RC.batch)], jnp.int32
        )
        posv = jnp.array(pos, jnp.int32)
        # Grow: map the block the coming write needs (rust reserve_rows).
        for s in range(RC.batch):
            blk = pos[s] // PS4
            if lazy_bt[s, blk] == 0:
                lazy_bt[s, blk] = FULL_BT[s, blk]
        lf, kcf, vcf = model.decode_slots_paged(
            a, params, kcf, vcf, toks, posv, full_bt, PS4
        )
        ll, kcl, vcl = model.decode_slots_paged(
            a, params, kcl, vcl, toks, posv, jnp.asarray(lazy_bt), PS4
        )
        np.testing.assert_array_equal(
            np.asarray(ll), np.asarray(lf), err_msg=f"step {step}"
        )
        full_logits = [lf[s] for s in range(RC.batch)]
        pos = [p + 1 for p in pos]

    assert all(int(b) != 0 for b in lazy_bt.flatten())  # grew to full window


def test_lazy_short_prompt_admission_pads_into_page_zero(params):
    """A right-padded short prompt (L = 3 < one page) admitted with ONLY
    `ceil(L / PS4) = 1` block mapped: the padding tail's K/V writes land in
    garbage page 0, and decode grows the table through fresh pages whose
    pristine contents differ from the full-table run's padding garbage —
    both differences sit strictly above `pos`, so every emitted logits row
    still matches the fully-mapped run BIT-EXACTLY."""
    a, sp, L = RC.actor, RC.prompt_len, 3
    assert live_blocks(L - 1) == 1
    prompt = right_pad(sample_prompts(22)[:1, :L], sp)
    full_row = FULL_BT[0].copy()
    lazy = lazy_row(full_row, L - 1)

    kcf, vcf = poisoned_caches()
    kcl, vcl = poisoned_caches()
    last = jnp.array([L - 1], jnp.int32)
    lf, kcf, vcf = model.prefill_slot_paged(
        a, params, kcf, vcf, prompt, jnp.asarray(full_row[None]), last, PS4
    )
    ll, kcl, vcl = model.prefill_slot_paged(
        a, params, kcl, vcl, prompt, jnp.asarray(lazy[None]), last, PS4
    )
    np.testing.assert_array_equal(np.asarray(ll[0]), np.asarray(lf[0]))

    parked = jnp.zeros((MB4,), jnp.int32)  # slot 1 inactive on page 0
    pos = L
    want, got = lf, ll
    for step in range(RC.gen_len):
        tok = int(jnp.argmax(want[0]))
        blk = pos // PS4
        if lazy[blk] == 0:
            lazy[blk] = full_row[blk]
        toks = jnp.array([tok, PAD], jnp.int32)
        posv = jnp.array([pos, 0], jnp.int32)
        want, kcf, vcf = model.decode_slots_paged(
            a, params, kcf, vcf, toks, posv,
            jnp.stack([jnp.asarray(full_row), parked]), PS4,
        )
        got, kcl, vcl = model.decode_slots_paged(
            a, params, kcl, vcl, toks, posv,
            jnp.stack([jnp.asarray(lazy), parked]), PS4,
        )
        np.testing.assert_array_equal(
            np.asarray(got[0]), np.asarray(want[0]), err_msg=f"step {step}"
        )
        pos += 1


# ---------------------------------------------------------------------------
# Pallas kernel parity: the kernel's `idx <= pos` mask (not the oracle's)
# is what the deployed artifact runs — same dead-tail guarantee, same bits.
# Skips itself when the installed jax cannot execute pallas interpret mode.
# ---------------------------------------------------------------------------


def _pallas_interpret_works():
    try:
        from compile.kernels.attention import flash_attention_fwd

        z = jnp.zeros((1, 8, 4), jnp.float32)
        flash_attention_fwd(z, z, z)
        return True
    except Exception:
        return False


pallas_parity = pytest.mark.skipif(
    not _pallas_interpret_works(),
    reason="pallas interpret mode unavailable under the installed jax",
)


@pallas_parity
@pytest.mark.parametrize("slot_pos", [[2, 13], [5, 9], [0, 15]])
def test_paged_kernel_dead_tail_bit_matches_full_table(slot_pos):
    """`decode_attention_paged` with poisoned-page-0 tails == fully-mapped
    tables == the contiguous kernel, bit for bit, at mixed row depths."""
    a = RC.actor
    bh = RC.batch * a.n_heads
    smax = MB4 * PS4
    key = jax.random.PRNGKey(31)
    q = jax.random.normal(key, (bh, a.d_head), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, smax, a.d_head))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, smax, a.d_head))
    kp, vp = scatter_pool(k, FULL_BT), scatter_pool(v, FULL_BT)
    pos = jnp.asarray(np.repeat(slot_pos, a.n_heads).astype(np.int32))
    lazy_bt = np.stack([lazy_row(FULL_BT[s], slot_pos[s]) for s in range(RC.batch)])

    out_lazy = decode_attention_paged(q, kp, vp, pos, jnp.asarray(lazy_bt), PS4)
    out_full = decode_attention_paged(q, kp, vp, pos, jnp.asarray(FULL_BT), PS4)
    want = decode_attention_pb(q, k, v, pos)
    np.testing.assert_array_equal(np.asarray(out_lazy), np.asarray(out_full))
    np.testing.assert_array_equal(np.asarray(out_lazy), np.asarray(want))
