"""Device-side sampling tail: Pallas kernels vs oracles, and the `_sampled`
model entry points vs their full-logits counterparts.

These pin the invariants the rust `SamplingBackend` refactor relies on:

  * `argmax_rows` / `top_k_rows` match the pure-jnp oracles, including the
    first-index tie-break (what makes device-greedy generation bit-identical
    to the host full-row argmax path);
  * every `*_sampled` entry returns exactly (argmax ids, top-k candidates)
    of the logits its plain counterpart returns, with the caches untouched
    by the tail;
  * the candidate rows are sorted descending, so the rust host-side finish
    (temperature → top-p prefix → categorical) can run without re-sorting.

As in test_serving.py, the attention/LN Pallas kernels are swapped for
their jnp oracles so the model runs under any jax version; the SAMPLING
kernels under test run for real (they avoid the ref-indexing idioms that
tie other kernels to specific jax versions).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.configs import run_config
from compile.kernels import ref
from compile.kernels.sampling import argmax_rows, top_k_rows

RC = run_config("nano")
TOL = dict(rtol=2e-4, atol=2e-4)


@pytest.fixture(autouse=True)
def ref_attention_kernels(monkeypatch):
    """Run the transformer on the jnp kernel oracles (forward-only tests);
    the sampling-tail kernels stay real — they are what is under test."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


def rows(seed, b, vocab, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (b, vocab))


# ---------------------------------------------------------------------------
# kernels vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,vocab,seed", [(1, 16, 0), (4, 64, 1), (8, 256, 2), (3, 512, 3)])
def test_argmax_rows_matches_ref(b, vocab, seed):
    x = rows(seed, b, vocab)
    np.testing.assert_array_equal(argmax_rows(x), ref.argmax_ref(x))


@pytest.mark.parametrize(
    "b,vocab,k,seed", [(1, 16, 1, 0), (4, 64, 8, 1), (8, 256, 32, 2), (2, 64, 64, 3)]
)
def test_top_k_rows_matches_ref(b, vocab, k, seed):
    x = rows(seed, b, vocab)
    tv, ti = top_k_rows(x, k)
    rv, ri = ref.top_k_ref(x, k)
    np.testing.assert_allclose(tv, rv, rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(ti, ri)


def test_tie_break_is_first_index():
    """Equal logits must resolve to the LOWER vocab index, in both kernels —
    the rust host sampler's argmax does the same, which is what makes the
    device-greedy golden bit-exact."""
    x = jnp.zeros((1, 12)).at[0, 3].set(2.0).at[0, 7].set(2.0).at[0, 9].set(1.0)
    assert int(argmax_rows(x)[0]) == 3
    tv, ti = top_k_rows(x, 3)
    np.testing.assert_array_equal(ti[0], jnp.array([3, 7, 9], jnp.int32))
    rv, ri = ref.top_k_ref(x, 3)
    np.testing.assert_array_equal(ti, ri)


def test_top_k_rows_sorted_descending():
    tv, _ = top_k_rows(rows(7, 4, 128), 16)
    tv = np.asarray(tv)
    assert (np.diff(tv, axis=1) <= 0).all()


# ---------------------------------------------------------------------------
# model-level `_sampled` entry points
# ---------------------------------------------------------------------------


def sample_prompts(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (RC.batch, RC.prompt_len), 0, RC.actor.vocab
    ).astype(jnp.int32)


def assert_tail_matches(logits, ids, tv, ti, k):
    np.testing.assert_array_equal(ids, ref.argmax_ref(logits))
    rv, ri = ref.top_k_ref(logits, k)
    np.testing.assert_allclose(tv, rv, **TOL)
    np.testing.assert_array_equal(ti, ri)


def test_prefill_sampled_matches_prefill(params):
    a, k = RC.actor, RC.sample_k
    prompt = sample_prompts(1)
    logits, kc, vc = model.prefill(a, params, prompt, RC.seq_len)
    ids, tv, ti, kc2, vc2 = model.prefill_sampled(a, params, prompt, RC.seq_len, k)
    assert_tail_matches(logits, ids, tv, ti, k)
    np.testing.assert_allclose(kc2, kc, **TOL)
    np.testing.assert_allclose(vc2, vc, **TOL)


def test_decode_step_sampled_matches_decode_step(params):
    a, sp, k = RC.actor, RC.prompt_len, RC.sample_k
    prompt = sample_prompts(2)
    logits, kc, vc = model.prefill(a, params, prompt, RC.seq_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.array([sp], jnp.int32)
    l2, kc_p, vc_p = model.decode_step(a, params, kc, vc, tok, pos)
    ids, tv, ti, kc_s, vc_s = model.decode_step_sampled(a, params, kc, vc, tok, pos, k)
    assert_tail_matches(l2, ids, tv, ti, k)
    np.testing.assert_allclose(kc_s, kc_p, **TOL)
    np.testing.assert_allclose(vc_s, vc_p, **TOL)


def test_decode_slots_sampled_matches_decode_slots(params):
    a, sp, k = RC.actor, RC.prompt_len, RC.sample_k
    prompt = sample_prompts(3)
    logits, kc, vc = model.prefill(a, params, prompt, RC.seq_len)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # Staggered per-slot depths: slot r decodes at position sp (all rows just
    # prefilled); run one shared step first to de-align, then compare.
    pos = jnp.full((RC.batch,), sp, jnp.int32)
    l2, kc2, vc2 = model.decode_slots(a, params, kc, vc, tok, pos)
    ids, tv, ti, kc_s, vc_s = model.decode_slots_sampled(a, params, kc, vc, tok, pos, k)
    assert_tail_matches(l2, ids, tv, ti, k)
    np.testing.assert_allclose(kc_s, kc2, **TOL)
    np.testing.assert_allclose(vc_s, vc2, **TOL)


def test_prefill_slot_sampled_matches_prefill_slot(params):
    a, k = RC.actor, RC.sample_k
    shape = (a.n_layers, RC.batch * a.n_heads, RC.seq_len, a.d_head)
    kc = jnp.zeros(shape, jnp.float32)
    vc = jnp.zeros(shape, jnp.float32)
    prompt = sample_prompts(4)[1:2]
    slot = jnp.array([1], jnp.int32)
    logits, kc2, vc2 = model.prefill_slot(a, params, kc, vc, prompt, slot)
    ids, tv, ti, kc_s, vc_s = model.prefill_slot_sampled(a, params, kc, vc, prompt, slot, k)
    assert_tail_matches(logits, ids, tv, ti, k)
    np.testing.assert_allclose(kc_s, kc2, **TOL)
    np.testing.assert_allclose(vc_s, vc2, **TOL)


# ---------------------------------------------------------------------------
# AOT contract
# ---------------------------------------------------------------------------


def test_sampled_entries_trace_with_expected_shapes():
    entries = aot.build_entries(RC)
    B, K = RC.batch, RC.sample_k
    kv_shape = (
        RC.actor.n_layers,
        B * RC.actor.n_heads,
        RC.seq_len,
        RC.actor.d_head,
    )
    for name, nb in [
        ("prefill_sampled", B),
        ("decode_step_sampled", B),
        ("prefill_slot_sampled", 1),
        ("decode_slots_sampled", B),
    ]:
        entry = entries[name]
        fn, specs, outputs = entry[0], entry[1], entry[2]
        assert outputs == ["ids", "topk_logits", "topk_ids", "k_cache", "v_cache"]
        out = jax.eval_shape(fn, *specs)
        assert out[0].shape == (nb,) and out[0].dtype == jnp.int32, name
        assert out[1].shape == (nb, K) and out[1].dtype == jnp.float32, name
        assert out[2].shape == (nb, K) and out[2].dtype == jnp.int32, name
        assert out[3].shape == kv_shape and out[4].shape == kv_shape, name
