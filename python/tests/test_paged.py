"""Block-paged KV cache: per-slot block tables over a shared page pool.

These pin the invariants the rust page allocator (rust/src/hybrid/kv.rs) and
the paged serving path rely on:

  * scatter/gather round trip: K/V written through a block table and
    gathered back via `gather_paged_kv` reproduce the contiguous cache
    BIT-EXACTLY (pure data movement);
  * `prefill_slot_paged` of a FRONT-ALIGNED (right-padded) short prompt
    reproduces the exact-length prefill's last-real-position logits, with
    the slot's pages holding exactly what the contiguous prefill wrote;
  * a full greedy serving chain through the paged path is BIT-IDENTICAL to
    the arena (left-padded) path for the same traffic — the golden the rust
    integration test repeats against real artifacts;
  * a staggered paged schedule (mid-flight admission of a short prompt,
    inactive slots parked on the garbage page) matches the no-cache full
    forward per sequence;
  * two slots SHARING a prefix page produce completions bit-identical to
    independent, unshared runs — the copy-on-write prefix-reuse safety
    argument (prefill rewrites shared pages with bit-identical values;
    decode writes land past the page-aligned shared region).

The Pallas kernels are swapped for their pure-jnp oracles (kernels/ref.py)
as in test_serving.py; the paged kernel itself is checked against the oracle
AND bit-compared to the contiguous kernel in the parity section at the
bottom, which skips itself when the installed jax cannot run pallas
interpret mode.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import run_config
from compile.kernels import ref
from compile.kernels.decode import decode_attention_paged, decode_attention_pb

RC = run_config("nano")
PS = RC.page_size
MB = RC.kv_blocks_per_slot
TOL = dict(rtol=2e-4, atol=2e-4)
PAD = 0  # mirrors the rust Vocab::PAD token


@pytest.fixture(autouse=True)
def ref_kernels(monkeypatch):
    """Run the model on the pure-jnp kernel oracles (forward-only tests)."""
    monkeypatch.setattr(model, "layernorm", ref.layernorm_ref)
    monkeypatch.setattr(model, "flash_attention", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_fwd", ref.attention_ref)
    monkeypatch.setattr(model, "flash_attention_padded_fwd", ref.attention_padded_ref)
    monkeypatch.setattr(model, "decode_attention", ref.decode_attention_ref)
    monkeypatch.setattr(model, "decode_attention_pb", ref.decode_attention_pb_ref)
    monkeypatch.setattr(model, "decode_attention_pbs", ref.decode_attention_pbs_ref)
    monkeypatch.setattr(model, "decode_attention_paged", ref.decode_attention_paged_ref)


@pytest.fixture(scope="module")
def params():
    return model.init_params(RC.actor, "lm", jnp.int32(0))


def arena_zero_caches():
    a = RC.actor
    shape = (a.n_layers, RC.batch * a.n_heads, RC.seq_len, a.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def paged_zero_caches():
    a = RC.actor
    shape = (a.n_layers, a.n_heads, RC.kv_pages * PS, a.d_head)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def sample_prompts(seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (RC.batch, RC.prompt_len), 0, RC.actor.vocab
    ).astype(jnp.int32)


def right_pad(row, sp):
    """row: [1, L] -> [1, sp] with PAD tokens on the right (front-aligned)."""
    L = row.shape[1]
    return jnp.concatenate([row, jnp.full((1, sp - L), PAD, jnp.int32)], axis=1)


def scatter_pool(contig, bt, n_pages):
    """Place a contiguous [b*h, smax, dh] cache into a [h, n_pages*PS, dh]
    pool under block tables `bt` [b, MB] (distinct pages per slot)."""
    b, mb = bt.shape
    bh, smax, dh = contig.shape
    h = bh // b
    assert smax == mb * PS
    pool = np.zeros((h, n_pages * PS, dh), np.float32)
    c = np.asarray(contig).reshape(b, h, smax, dh)
    for s in range(b):
        for blk in range(mb):
            page = int(bt[s, blk])
            pool[:, page * PS : (page + 1) * PS] = c[s, :, blk * PS : (blk + 1) * PS]
    return jnp.asarray(pool)


# Slot -> pages mapping used throughout: a deliberate non-identity
# permutation of the nano pool (7 pages; page 0 reserved as garbage).
BT = np.array([[3, 5], [1, 6]], np.int32)


def test_gather_scatter_round_trip_is_bit_exact():
    a = RC.actor
    key = jax.random.PRNGKey(0)
    contig = jax.random.normal(
        key, (RC.batch * a.n_heads, RC.seq_len, a.d_head), jnp.float32
    )
    pool = scatter_pool(contig, BT, RC.kv_pages)
    back = ref.gather_paged_kv(pool, jnp.asarray(BT), PS, a.n_heads)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(contig))


def test_paged_oracle_matches_contiguous_oracle_bitwise():
    a = RC.actor
    bh = RC.batch * a.n_heads
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (bh, a.d_head), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, RC.seq_len, a.d_head))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, RC.seq_len, a.d_head))
    pos = jnp.array([5, 5, 12, 12], jnp.int32)  # per-head rows share slot pos
    kp, vp = scatter_pool(k, BT, RC.kv_pages), scatter_pool(v, BT, RC.kv_pages)
    out = ref.decode_attention_paged_ref(q, kp, vp, pos, jnp.asarray(BT), PS)
    want = ref.decode_attention_pb_ref(q, k, v, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("L", [RC.prompt_len, RC.prompt_len - 3, 1])
def test_paged_prefill_matches_exact_length(params, L):
    """Front-aligned paged admission: the true-length-L prompt's logits (and
    its pages' real entries) must equal the exact-length prefill BIT-EXACTLY
    — the causal mask keeps rows [0, L) independent of the padding tail."""
    a, sp = RC.actor, RC.prompt_len
    exact = sample_prompts(40 + L)[:1, :L]
    kc, vc = paged_zero_caches()
    bt = jnp.asarray(BT[:1])

    logits, kc2, vc2 = model.prefill_slot_paged(
        a, params, kc, vc, right_pad(exact, sp), bt, jnp.array([L - 1], jnp.int32), PS
    )
    le, kce, vce = model.prefill(a, params, exact, RC.seq_len)
    np.testing.assert_array_equal(np.asarray(logits[0]), np.asarray(le[0]))

    # The slot's pages hold the contiguous prefill's K/V at logical [0, L).
    gathered_k = ref.gather_paged_kv(kc2[0], bt, PS, a.n_heads)
    gathered_v = ref.gather_paged_kv(vc2[0], bt, PS, a.n_heads)
    np.testing.assert_array_equal(
        np.asarray(gathered_k)[:, :L], np.asarray(kce)[0, : a.n_heads, :L]
    )
    np.testing.assert_array_equal(
        np.asarray(gathered_v)[:, :L], np.asarray(vce)[0, : a.n_heads, :L]
    )


def test_paged_chain_bit_matches_arena_chain(params):
    """The golden: identical full-length greedy traffic through the paged
    path and the arena path yields BIT-IDENTICAL logits at every step."""
    a, sp = RC.actor, RC.prompt_len
    prompts = sample_prompts(50)
    bt = jnp.asarray(BT)

    # Arena: admit both slots, then decode.
    kca, vca = arena_zero_caches()
    arena_logits = []
    for slot in range(RC.batch):
        l, kca, vca = model.prefill_slot(
            a, params, kca, vca, prompts[slot : slot + 1], jnp.array([slot], jnp.int32)
        )
        arena_logits.append(l[0])

    # Paged: same admissions through block tables.
    kcp, vcp = paged_zero_caches()
    paged_logits = []
    for slot in range(RC.batch):
        l, kcp, vcp = model.prefill_slot_paged(
            a,
            params,
            kcp,
            vcp,
            prompts[slot : slot + 1],
            bt[slot : slot + 1],
            jnp.array([sp - 1], jnp.int32),
            PS,
        )
        paged_logits.append(l[0])

    for slot in range(RC.batch):
        np.testing.assert_array_equal(
            np.asarray(paged_logits[slot]), np.asarray(arena_logits[slot])
        )

    pos = [sp, sp]
    for _ in range(RC.gen_len - 1):
        toks = jnp.array(
            [int(jnp.argmax(arena_logits[s])) for s in range(RC.batch)], jnp.int32
        )
        posv = jnp.array(pos, jnp.int32)
        la, kca, vca = model.decode_slots(a, params, kca, vca, toks, posv)
        lp, kcp, vcp = model.decode_slots_paged(a, params, kcp, vcp, toks, posv, bt, PS)
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(la))
        arena_logits = [la[s] for s in range(RC.batch)]
        pos = [p + 1 for p in pos]


def test_staggered_paged_schedule_matches_full_forward(params):
    """Admit slot 0 (full-length), decode alone with slot 1 parked on the
    garbage page, admit a SHORT front-aligned prompt into slot 1 mid-flight,
    decode both — every emitted logits row must equal the no-cache forward
    on that sequence's true token prefix."""
    a, sp = RC.actor, RC.prompt_len
    L1 = sp - 3
    prompts = sample_prompts(60)
    kc, vc = paged_zero_caches()

    def ref_logits(tokens):
        seq = jnp.asarray(tokens, jnp.int32)[None, :]
        return model.logits_fn(a, params, seq)[0, -1]

    def check(row, tokens):
        np.testing.assert_allclose(row, ref_logits(tokens), **TOL)

    seqs = [list(np.asarray(prompts[0])), list(np.asarray(prompts[1][:L1]))]
    pending = [None, None]
    # Slot 1 not yet admitted: every block parked on the garbage page 0.
    tables = np.array([[3, 5], [0, 0]], np.int32)

    l0, kc, vc = model.prefill_slot_paged(
        a,
        params,
        kc,
        vc,
        prompts[0:1],
        jnp.asarray(tables[0:1]),
        jnp.array([sp - 1], jnp.int32),
        PS,
    )
    check(l0[0], seqs[0])
    pending[0] = l0[0]

    for tick in range(4):
        if tick == 2:
            tables[1] = [1, 6]
            l1, kc, vc = model.prefill_slot_paged(
                a,
                params,
                kc,
                vc,
                right_pad(prompts[1:2, :L1], sp),
                jnp.asarray(tables[1:2]),
                jnp.array([L1 - 1], jnp.int32),
                PS,
            )
            check(l1[0], seqs[1])
            pending[1] = l1[0]
        toks, pos, active = [], [], []
        for slot in range(2):
            if pending[slot] is None:
                toks.append(0)
                pos.append(0)
                active.append(False)
            else:
                t = int(jnp.argmax(pending[slot]))
                seqs[slot].append(t)
                toks.append(t)
                # Front-aligned: position IS the true sequence depth.
                pos.append(len(seqs[slot]) - 1)
                active.append(True)
        logits, kc, vc = model.decode_slots_paged(
            a,
            params,
            kc,
            vc,
            jnp.array(toks, jnp.int32),
            jnp.array(pos, jnp.int32),
            jnp.asarray(tables),
            PS,
        )
        for slot in range(2):
            if active[slot]:
                check(logits[slot], seqs[slot])
                pending[slot] = logits[slot]

    assert len(seqs[0]) == sp + 4
    assert len(seqs[1]) == L1 + 2


def test_shared_prefix_page_is_bit_identical_to_unshared(params):
    """Two slots whose prompts are the same full-page prefix SHARE the
    prefix's physical page; their completions (forced to diverge at the
    first generated token) must be bit-identical to runs in private pools.
    Safe because (a) the second prefill rewrites the shared page with
    bit-identical values — same tokens at the same logical positions — and
    (b) decode writes land at positions >= prompt_len, past the page-aligned
    shared region, in each slot's private pages. (Inactive slots are parked
    on the garbage page, the scheduler's discipline — a parked slot must
    NEVER keep a real table, or its PAD write would corrupt live pages.)"""
    a, sp = RC.actor, RC.prompt_len
    assert sp == PS  # nano geometry: the whole prompt is one shareable page
    prompt = sample_prompts(70)[:1]

    def admit(kc, vc, table_row):
        return model.prefill_slot_paged(
            a, params, kc, vc, prompt, table_row, jnp.array([sp - 1], jnp.int32), PS
        )

    # Shared pool: slot 0 owns pages [3, 5]; slot 1 maps the SAME prefix
    # page 3 plus its own page 6 for generated tokens.
    shared_bt = jnp.asarray(np.array([[3, 5], [3, 6]], np.int32))
    kc, vc = paged_zero_caches()
    l0, kc, vc = admit(kc, vc, shared_bt[0:1])
    l1, kc, vc = admit(kc, vc, shared_bt[1:2])
    # The second admission rewrote the shared page bit-identically, so both
    # slots see the same prefix logits.
    np.testing.assert_array_equal(np.asarray(l1[0]), np.asarray(l0[0]))

    ranked = np.argsort(-np.asarray(l0[0]))
    firsts = [int(ranked[0]), int(ranked[1])]  # force divergent completions

    # Concurrent greedy decode of both slots over the shared pool.
    shared_out = [[np.asarray(l0[0])], [np.asarray(l1[0])]]
    toks, pos = list(firsts), [sp, sp]
    for _ in range(3):
        l, kc, vc = model.decode_slots_paged(
            a,
            params,
            kc,
            vc,
            jnp.array(toks, jnp.int32),
            jnp.array(pos, jnp.int32),
            shared_bt,
            PS,
        )
        for s in range(2):
            shared_out[s].append(np.asarray(l[s]))
            toks[s] = int(jnp.argmax(l[s]))
            pos[s] += 1

    # Unshared reference: each sequence alone in a private pool, the other
    # slot parked on the garbage page.
    for slot in range(2):
        solo_bt = jnp.asarray(np.array([[1, 2], [0, 0]], np.int32))
        kcs, vcs = paged_zero_caches()
        l, kcs, vcs = admit(kcs, vcs, solo_bt[0:1])
        want = [np.asarray(l[0])]
        tok, p = firsts[slot], sp
        for _ in range(3):
            l, kcs, vcs = model.decode_slots_paged(
                a,
                params,
                kcs,
                vcs,
                jnp.array([tok, 0], jnp.int32),
                jnp.array([p, 0], jnp.int32),
                solo_bt,
                PS,
            )
            want.append(np.asarray(l[0]))
            tok, p = int(jnp.argmax(l[0])), p + 1
        for step, (g, w) in enumerate(zip(shared_out[slot], want)):
            np.testing.assert_array_equal(g, w, err_msg=f"slot {slot} step {step}")


# ---------------------------------------------------------------------------
# Pallas kernel parity (kernel vs jnp oracle, and paged vs contiguous kernel
# bit-equality — the tile-reassembly claim). Skips itself when the installed
# jax cannot execute pallas interpret mode, exactly as in test_serving.py.
# ---------------------------------------------------------------------------


def _pallas_interpret_works():
    try:
        from compile.kernels.attention import flash_attention_fwd

        z = jnp.zeros((1, 8, 4), jnp.float32)
        flash_attention_fwd(z, z, z)
        return True
    except Exception:
        return False


pallas_parity = pytest.mark.skipif(
    not _pallas_interpret_works(),
    reason="pallas interpret mode unavailable under the installed jax",
)


@pallas_parity
@pytest.mark.parametrize("seed", [0, 1])
def test_paged_kernel_bit_matches_contiguous_kernel(seed):
    """`decode_attention_paged` reassembles the contiguous kernel's block_k
    tiles from whole pages, so its accumulation order — and its BITS — equal
    `decode_attention_pb` over the gathered logical cache."""
    a = RC.actor
    bh = RC.batch * a.n_heads
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (bh, a.d_head), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, RC.seq_len, a.d_head))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, RC.seq_len, a.d_head))
    pos = jnp.array([3, 3, RC.seq_len - 1, RC.seq_len - 1], jnp.int32)
    kp, vp = scatter_pool(k, BT, RC.kv_pages), scatter_pool(v, BT, RC.kv_pages)

    out = decode_attention_paged(q, kp, vp, pos, jnp.asarray(BT), PS)
    want_kernel = decode_attention_pb(q, k, v, pos)
    want_oracle = ref.decode_attention_pb_ref(q, k, v, pos)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want_kernel))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_oracle), **TOL)


@pallas_parity
def test_paged_kernel_small_page_reassembly():
    """page_size < block_k forces multi-page tile reassembly (concatenate
    path); shapes chosen so block_k = 16 spans 4 pages of 4."""
    h, b, dh, ps, mb = 2, 3, 8, 4, 4
    smax, n_pages = mb * ps, b * mb + 1
    bh = b * h
    key = jax.random.PRNGKey(7)
    q = jax.random.normal(key, (bh, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, smax, dh), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, smax, dh), jnp.float32)
    # Non-identity page assignment, one private page set per slot.
    perm = np.random.RandomState(3).permutation(np.arange(1, n_pages))
    bt = perm.reshape(b, mb).astype(np.int32)
    pool_k = np.zeros((h, n_pages * ps, dh), np.float32)
    pool_v = np.zeros((h, n_pages * ps, dh), np.float32)
    ck = np.asarray(k).reshape(b, h, smax, dh)
    cv = np.asarray(v).reshape(b, h, smax, dh)
    for s in range(b):
        for blk in range(mb):
            page = int(bt[s, blk])
            pool_k[:, page * ps : (page + 1) * ps] = ck[s, :, blk * ps : (blk + 1) * ps]
            pool_v[:, page * ps : (page + 1) * ps] = cv[s, :, blk * ps : (blk + 1) * ps]
    pos = jnp.array([2, 2, 9, 9, smax - 1, smax - 1], jnp.int32)

    out = decode_attention_paged(
        q, jnp.asarray(pool_k), jnp.asarray(pool_v), pos, jnp.asarray(bt), ps
    )
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(decode_attention_pb(q, k, v, pos))
    )
