//! Timing and throughput instrumentation for the real runs (the measured
//! side of EXPERIMENTS.md) plus the paper's TFLOPs bookkeeping.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Instant;

use crate::config::ModelConfig;

/// Accumulating named timer (scopes keyed by label). Accumulation sits
/// behind a `RefCell` so scopes borrow shared and NEST: an outer
/// "iteration" scope stays live while inner "gen"/"train" scopes open and
/// close inside it, each folding into its own label on drop.
#[derive(Debug, Default)]
pub struct Timers {
    acc: RefCell<BTreeMap<String, (f64, u64)>>,
}

pub struct Scope<'a> {
    timers: &'a Timers,
    label: String,
    start: Instant,
}

impl Timers {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn scope(&self, label: &str) -> Scope<'_> {
        Scope { label: label.to_string(), start: Instant::now(), timers: self }
    }

    pub fn add(&self, label: &str, secs: f64) {
        let mut acc = self.acc.borrow_mut();
        let e = acc.entry(label.to_string()).or_insert((0.0, 0));
        e.0 += secs;
        e.1 += 1;
    }

    pub fn total(&self, label: &str) -> f64 {
        self.acc.borrow().get(label).map(|e| e.0).unwrap_or(0.0)
    }

    pub fn count(&self, label: &str) -> u64 {
        self.acc.borrow().get(label).map(|e| e.1).unwrap_or(0)
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (k, (secs, n)) in self.acc.borrow().iter() {
            s.push_str(&format!(
                "{k:<28} total {:>10}  calls {n:>7}  mean {:>10}\n",
                crate::util::fmt_duration(*secs),
                crate::util::fmt_duration(*secs / (*n).max(1) as f64),
            ));
        }
        s
    }
}

impl Drop for Scope<'_> {
    fn drop(&mut self) {
        let secs = self.start.elapsed().as_secs_f64();
        self.timers.add(&self.label, secs);
    }
}

/// The paper's throughput accounting for one RLHF iteration (§5.3 and the
/// benchmark-settings formulas): generation FLOPs + training FLOPs.
#[derive(Debug, Clone, Copy)]
pub struct RlhfFlops {
    pub gen_flops: f64,
    pub train_flops: f64,
}

pub fn rlhf_iteration_flops(
    actor: &ModelConfig,
    critic: &ModelConfig,
    pairs: u64,
    prompt_len: u64,
    gen_len: u64,
) -> RlhfFlops {
    let seq = prompt_len + gen_len;
    let gen =
        actor.fwd_flops(pairs * gen_len, seq) as f64 + actor.fwd_flops(pairs * prompt_len, seq) as f64;
    let toks = (pairs * seq) as f64;
    let train = toks * (10.0 * actor.n_params() as f64 + 8.0 * critic.n_params() as f64);
    RlhfFlops { gen_flops: gen, train_flops: train }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model;

    #[test]
    fn timer_accumulates() {
        let t = Timers::new();
        {
            let _s = t.scope("x");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        {
            let _s = t.scope("x");
        }
        assert_eq!(t.count("x"), 2);
        assert!(t.total("x") >= 0.005);
        assert!(t.report().contains("x"));
    }

    #[test]
    fn scopes_nest_and_the_outer_covers_the_inner() {
        let t = Timers::new();
        {
            let _iter = t.scope("iter");
            {
                let _gen = t.scope("gen");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            {
                let _train = t.scope("train");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }
        for label in ["iter", "gen", "train"] {
            assert_eq!(t.count(label), 1, "{label}");
        }
        // The outer scope was live for both inner ones, so its total
        // bounds their sum from above.
        assert!(
            t.total("iter") >= t.total("gen") + t.total("train"),
            "iter {} < gen {} + train {}",
            t.total("iter"),
            t.total("gen"),
            t.total("train")
        );
    }

    #[test]
    fn add_accumulates_exactly_and_missing_labels_are_zero() {
        let t = Timers::new();
        t.add("a", 1.5);
        t.add("a", 2.5);
        t.add("b", 0.25);
        assert_eq!(t.total("a"), 4.0);
        assert_eq!(t.count("a"), 2);
        assert_eq!(t.total("b"), 0.25);
        assert_eq!(t.count("b"), 1);
        assert_eq!(t.total("never"), 0.0);
        assert_eq!(t.count("never"), 0);
        let rep = t.report();
        assert!(rep.contains('a') && rep.contains('b'), "{rep}");
    }

    #[test]
    fn flops_generation_fraction_matches_paper() {
        // §5.3: generation ≈ 20% of Step-3 computation for the benchmark
        // recipe (256 prompt + 256 generated).
        let f = rlhf_iteration_flops(&model("opt-13b"), &model("opt-350m"), 1024, 256, 256);
        let frac = f.gen_flops / (f.gen_flops + f.train_flops);
        assert!((0.1..0.3).contains(&frac), "generation fraction {frac}");
    }
}
