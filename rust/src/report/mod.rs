//! Paper-artifact regeneration: one function per table/figure of the
//! evaluation section, each returning a [`Table`] with the same rows/series
//! the paper reports. Used by `examples/paper_tables.rs`,
//! `examples/paper_figures.rs`, the CLI, and the benches.

use crate::baselines::{all_systems, ds_he};
use crate::config::{model, model_zoo, ModelConfig};
use crate::sim::{
    a100_40g, a100_80g, a6000_48g, max_model_single_gpu, simulate_e2e, simulate_step3,
    v100_32g, Cluster, PipelineDatasets, Recipe,
};
use crate::util::csv::Table;
use crate::util::{fmt_count, fmt_duration};

fn critic() -> ModelConfig {
    model("opt-350m")
}

fn fmt_cost(d: f64) -> String {
    format!("${d:.0}")
}

/// Table 1: single-node 8x A100 training time and Azure cost (step 3 e2e).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — Single-Node 8x A100: e2e time & cost (DeepSpeed-HE)",
        &["GPUs", "OPT-6.7B", "OPT-13B", "OPT-30B", "OPT-66B"],
    );
    let r = Recipe::default();
    let d = PipelineDatasets::default();
    for gpu in [a100_40g(), a100_80g()] {
        let cluster = Cluster::dgx(gpu.clone(), 1);
        let mut row = vec![format!("8x {}", gpu.name)];
        for m in ["opt-6.7b", "opt-13b", "opt-30b", "opt-66b"] {
            row.push(
                match simulate_e2e(&ds_he(), &model(m), &critic(), &cluster, &r, &d) {
                    Some(e) => format!(
                        "{} ({})",
                        fmt_duration(e.total_secs()),
                        fmt_cost(e.dollars)
                    ),
                    None => "NA".into(),
                },
            );
        }
        t.row(row);
    }
    t
}

/// Table 2: multi-node 64x A100-80G time and cost.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table 2 — Multi-Node 64x A100-80GB: e2e time & cost",
        &["GPUs", "OPT-13B", "OPT-30B", "OPT-66B", "OPT-175B"],
    );
    let r = Recipe::default();
    let d = PipelineDatasets::default();
    let cluster = Cluster::dgx(a100_80g(), 8);
    let mut row = vec!["64x A100-80G".to_string()];
    for m in ["opt-13b", "opt-30b", "opt-66b", "opt-175b"] {
        row.push(
            match simulate_e2e(&ds_he(), &model(m), &critic(), &cluster, &r, &d) {
                Some(e) => format!("{} ({})", fmt_duration(e.total_secs()), fmt_cost(e.dollars)),
                None => "NA".into(),
            },
        );
    }
    t.row(row);
    t
}

/// Table 3: max model size on a single GPU.
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table 3 — Max model size supported by DeepSpeed-HE on a single GPU",
        &["", "V100 32G", "A6000 48G", "A100 40G", "A100 80G"],
    );
    let zoo = model_zoo();
    let mut row = vec!["Model Size".to_string()];
    for gpu in [v100_32g(), a6000_48g(), a100_40g(), a100_80g()] {
        row.push(
            max_model_single_gpu(&gpu, &zoo)
                .map(|m| m.name.replace("opt-", "OPT-").to_uppercase())
                .unwrap_or_else(|| "NA".into()),
        );
    }
    t.row(row);
    t
}

/// Tables 4/5/6: per-step e2e breakdown for three deployments.
pub fn tables456() -> Vec<Table> {
    let r = Recipe::default();
    let d = PipelineDatasets::default();
    let cases = [
        (
            "Table 4 — 13B actor + 350M reward on 1 DGX (8x A100-40G)",
            "opt-13b",
            Cluster::dgx(a100_40g(), 1),
        ),
        (
            "Table 5 — 66B actor + 350M reward on 8 DGX (64x A100-80G)",
            "opt-66b",
            Cluster::dgx(a100_80g(), 8),
        ),
        (
            "Table 6 — 1.3B actor + 350M reward on 1x A6000-48G (single dataset)",
            "opt-1.3b",
            Cluster::single(a6000_48g()),
        ),
    ];
    cases
        .iter()
        .map(|(title, m, cluster)| {
            // Table 6 is the paper's reduced single-dataset recipe (§2.2).
            let (r, d) = if title.contains("single dataset") {
                (Recipe::single_dataset(), PipelineDatasets::single_dataset())
            } else {
                (r.clone(), d.clone())
            };
            let mut t = Table::new(title, &["Model", "Step 1", "Step 2", "Step 3", "Total"]);
            match simulate_e2e(&ds_he(), &model(m), &critic(), cluster, &r, &d) {
                Some(e) => {
                    t.row(vec![
                        format!("Actor {}, RM 350M", m.replace("opt-", "OPT-")),
                        fmt_duration(e.step1_secs),
                        fmt_duration(e.step2_secs),
                        fmt_duration(e.step3_secs),
                        fmt_duration(e.total_secs()),
                    ]);
                }
                None => {
                    t.row(vec![m.to_string(), "OOM".into(), "-".into(), "-".into(), "-".into()]);
                }
            }
            t
        })
        .collect()
}

/// Figure 3: single-GPU step-3 throughput vs baselines (OOM markers).
pub fn figure3() -> Table {
    let mut t = Table::new(
        "Figure 3 — Step-3 throughput on one A100-40G (pairs/sec; NA = OOM)",
        &["Model", "DeepSpeed-HE", "Colossal-AI", "HF-DDP", "DS speedup vs best baseline"],
    );
    let cluster = Cluster::single(a100_40g());
    let r = Recipe::default();
    for m in ["opt-125m", "opt-350m", "opt-1.3b", "opt-2.7b", "opt-6.7b"] {
        let a = model(m);
        let outs: Vec<Option<f64>> = all_systems()
            .iter()
            .map(|s| simulate_step3(s, &a, &critic(), &cluster, &r).map(|o| o.pairs_per_sec))
            .collect();
        let ds = outs[0];
        let best_base = outs[1].into_iter().chain(outs[2]).fold(None::<f64>, |acc, x| {
            Some(acc.map_or(x, |a| a.max(x)))
        });
        let speed = match (ds, best_base) {
            (Some(d), Some(b)) => format!("{:.1}x", d / b),
            _ => "-".into(),
        };
        t.row(vec![
            m.replace("opt-", "OPT-"),
            outs[0].map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            outs[2].map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            outs[1].map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            speed,
        ]);
    }
    t
}

/// Figure 4: single-node (8x A100-40G) e2e step-3 throughput vs baselines.
pub fn figure4() -> Table {
    let mut t = Table::new(
        "Figure 4 — Step-3 throughput on 8x A100-40G (pairs/sec; NA = OOM)",
        &["Model", "DeepSpeed-HE", "Colossal-AI", "HF-DDP", "vs CAI", "vs HF"],
    );
    let cluster = Cluster::dgx(a100_40g(), 1);
    let r = Recipe::default();
    for m in ["opt-1.3b", "opt-6.7b", "opt-13b"] {
        let a = model(m);
        let get = |s: &crate::baselines::SystemModel| {
            simulate_step3(s, &a, &critic(), &cluster, &r).map(|o| o.pairs_per_sec)
        };
        let sys = all_systems();
        let (ds, hf, cai) = (get(&sys[0]), get(&sys[1]), get(&sys[2]));
        let rel = |d: Option<f64>, b: Option<f64>| match (d, b) {
            (Some(d), Some(b)) => format!("{:.1}x", d / b),
            _ => "-".into(),
        };
        t.row(vec![
            m.replace("opt-", "OPT-"),
            ds.map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            cai.map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            hf.map(|x| format!("{x:.3}")).unwrap_or("NA".into()),
            rel(ds, cai),
            rel(ds, hf),
        ]);
    }
    t
}

/// Figure 5: time/seq breakdown (generation vs training) for 1.3B on 8 GPUs.
pub fn figure5() -> Table {
    let mut t = Table::new(
        "Figure 5 — Step-3 time per pair, 1.3B actor on 8x A100-40G (secs)",
        &["System", "Generation", "RL training", "Total", "Gen share"],
    );
    let cluster = Cluster::dgx(a100_40g(), 1);
    let r = Recipe::default();
    let a = model("opt-1.3b");
    for s in all_systems() {
        if let Some(o) = simulate_step3(&s, &a, &critic(), &cluster, &r) {
            let per_pair = r.global_batch as f64;
            t.row(vec![
                s.name.clone(),
                format!("{:.3}", o.gen_secs / per_pair),
                format!("{:.3}", o.train_secs / per_pair),
                format!("{:.3}", o.iter_secs() / per_pair),
                format!("{:.0}%", 100.0 * o.gen_secs / o.iter_secs()),
            ]);
        }
    }
    t
}

/// Figure 6: generation/training/effective TFLOPs per GPU vs model size at
/// the GPU count that maximizes efficiency.
pub fn figure6() -> Table {
    let mut t = Table::new(
        "Figure 6 — Best-achievable throughput per GPU (TFLOPs)",
        &["Model", "GPUs", "Generation", "Training", "Effective"],
    );
    let r = Recipe::default();
    for m in [
        "opt-1.3b", "opt-6.7b", "opt-13b", "opt-30b", "opt-66b", "opt-175b",
    ] {
        let a = model(m);
        // search over node counts for the best effective TFLOPs/GPU
        let mut best: Option<(usize, crate::sim::Step3Breakdown)> = None;
        for nodes in [1usize, 2, 4, 8] {
            let cluster = Cluster::dgx(a100_80g(), nodes);
            if let Some(o) = simulate_step3(&ds_he(), &a, &critic(), &cluster, &r) {
                if best
                    .as_ref()
                    .map(|(_, b)| o.effective_tflops_per_gpu > b.effective_tflops_per_gpu)
                    .unwrap_or(true)
                {
                    best = Some((cluster.world(), o));
                }
            }
        }
        match best {
            Some((gpus, o)) => {
                t.row(vec![
                    m.replace("opt-", "OPT-"),
                    gpus.to_string(),
                    format!("{:.0}", o.gen_tflops_per_gpu),
                    format!("{:.0}", o.train_tflops_per_gpu),
                    format!("{:.0}", o.effective_tflops_per_gpu),
                ]);
            }
            None => {
                t.row(vec![m.into(), "-".into(), "OOM".into(), "-".into(), "-".into()]);
            }
        }
    }
    t
}

/// Figure 7: scalability of 13B / 66B actors across DGX node counts.
pub fn figure7() -> Vec<Table> {
    let r = Recipe::default();
    let cases = [
        ("Figure 7 (left) — 13B actor, A100-40G nodes", "opt-13b", a100_40g(), vec![1, 2, 4, 8]),
        ("Figure 7 (right) — 66B actor, A100-80G nodes", "opt-66b", a100_80g(), vec![2, 4, 8]),
    ];
    cases
        .iter()
        .map(|(title, m, gpu, node_counts)| {
            let mut t = Table::new(
                title,
                &["Nodes", "GPUs", "pairs/sec", "pairs/sec/GPU", "scaling vs first"],
            );
            let a = model(m);
            let mut first: Option<f64> = None;
            for &nodes in node_counts {
                let cluster = Cluster::dgx(gpu.clone(), nodes);
                match simulate_step3(&ds_he(), &a, &critic(), &cluster, &r) {
                    Some(o) => {
                        let per_gpu = o.pairs_per_sec / cluster.world() as f64;
                        let base = *first.get_or_insert(o.pairs_per_sec);
                        let ideal = o.pairs_per_sec / base / (nodes as f64 / node_counts[0] as f64);
                        t.row(vec![
                            nodes.to_string(),
                            cluster.world().to_string(),
                            format!("{:.3}", o.pairs_per_sec),
                            format!("{per_gpu:.4}"),
                            format!("{:.2}x ideal", ideal),
                        ]);
                    }
                    None => {
                        t.row(vec![
                            nodes.to_string(),
                            (nodes * 8).to_string(),
                            "OOM".into(),
                            "-".into(),
                            "-".into(),
                        ]);
                    }
                }
            }
            t
        })
        .collect()
}

/// Section 5.2's model-scalability claim (DS 7.5x larger models).
pub fn scalability_claim() -> Table {
    let mut t = Table::new(
        "§5.2 — Max trainable actor (single A100-40G and one DGX node)",
        &["System", "1x A100-40G", "8x A100-40G"],
    );
    let zoo = model_zoo();
    let opts: Vec<ModelConfig> =
        zoo.into_iter().filter(|m| m.name.starts_with("opt-")).collect();
    let r = Recipe::default();
    for s in all_systems() {
        let single = crate::sim::max_model(&s, &opts, &critic(), &Cluster::single(a100_40g()), &r);
        let node = crate::sim::max_model(&s, &opts, &critic(), &Cluster::dgx(a100_40g(), 1), &r);
        t.row(vec![
            s.name.clone(),
            single.map(|m| fmt_count(m.n_params() as f64)).unwrap_or("-".into()),
            node.map(|m| fmt_count(m.n_params() as f64)).unwrap_or("-".into()),
        ]);
    }
    t
}

pub fn all_tables() -> Vec<Table> {
    let mut v = vec![table1(), table2(), table3()];
    v.extend(tables456());
    v
}

pub fn all_figures() -> Vec<Table> {
    let mut v = vec![figure3(), figure4(), figure5(), figure6()];
    v.extend(figure7());
    v.push(scalability_claim());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_table_renders() {
        for t in all_tables().iter().chain(all_figures().iter()) {
            let md = t.to_markdown();
            assert!(md.contains('|'), "{}", t.title);
            assert!(!t.rows.is_empty(), "{}", t.title);
        }
    }

    #[test]
    fn table3_row_matches_paper() {
        let t = table3();
        assert_eq!(
            t.rows[0],
            vec!["Model Size", "OPT-2.7B", "OPT-6.7B", "OPT-6.7B", "OPT-13B"]
        );
    }

    #[test]
    fn figure3_ds_wins_everywhere_it_runs() {
        let t = figure3();
        for row in &t.rows {
            if row[1] != "NA" && (row[2] != "NA" || row[3] != "NA") {
                let speed: f64 = row[4].trim_end_matches('x').parse().unwrap();
                assert!(speed > 1.0, "{row:?}");
            }
        }
    }
}
