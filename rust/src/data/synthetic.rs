//! The synthetic instruction-following task (human-data substitution).
//!
//! A prompt encodes an *instruction*: `[BOS, mode, a, b, noise..., SEP]`.
//! The correct response is a deterministic token pattern:
//!   * `Repeat`    — alternate `a, b, a, b, ...`
//!   * `Constant`  — repeat `a`
//!   * `Count`     — `a, a+1, a+2, ...` (wrapping within the content range)
//!   * `Mirror`    — `b, a, b, a, ...`
//! followed by `EOS`. The ground-truth reward is the fraction of response
//! positions matching the rule — measurable at every stage of the pipeline,
//! which is exactly what the human preference data gives the paper's
//! pipeline, but verifiable.

use crate::util::rng::Rng;

use super::{PairBatch, TokenBatch};

/// Special token ids (shared with the chat example's detokenizer).
#[derive(Debug, Clone, Copy)]
pub struct Vocab {
    pub size: usize,
}

impl Vocab {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const SEP: i32 = 3;
    pub const MODE_BASE: i32 = 4; // mode tokens 4..8
    pub const CONTENT_BASE: i32 = 8;

    pub fn content_range(&self) -> (i32, i32) {
        (Self::CONTENT_BASE, self.size as i32)
    }

    pub fn n_content(&self) -> i32 {
        self.size as i32 - Self::CONTENT_BASE
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Repeat = 0,
    Constant = 1,
    Count = 2,
    Mirror = 3,
}

impl Mode {
    pub fn all() -> [Mode; 4] {
        [Mode::Repeat, Mode::Constant, Mode::Count, Mode::Mirror]
    }

    pub fn token(self) -> i32 {
        Vocab::MODE_BASE + self as i32
    }

    pub fn from_token(t: i32) -> Option<Mode> {
        match t - Vocab::MODE_BASE {
            0 => Some(Mode::Repeat),
            1 => Some(Mode::Constant),
            2 => Some(Mode::Count),
            3 => Some(Mode::Mirror),
            _ => None,
        }
    }
}

/// A sampled instruction prompt.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub mode: Mode,
    pub a: i32,
    pub b: i32,
    pub tokens: Vec<i32>, // length = prompt_len
}

/// Task generator bound to one deployment's shapes.
#[derive(Debug, Clone)]
pub struct TaskGen {
    pub vocab: Vocab,
    pub prompt_len: usize,
    pub gen_len: usize,
    /// Which modes this source emits (data-blending sources differ here).
    pub modes: Vec<Mode>,
    /// Response length before EOS (fixed per task instance, < gen_len).
    pub resp_len: usize,
}

impl TaskGen {
    pub fn new(vocab_size: usize, prompt_len: usize, gen_len: usize) -> Self {
        assert!(prompt_len >= 5, "prompt too short for [BOS, mode, a, b, .., SEP]");
        assert!(gen_len >= 4);
        TaskGen {
            vocab: Vocab { size: vocab_size },
            prompt_len,
            gen_len,
            modes: Mode::all().to_vec(),
            resp_len: gen_len - 2, // leave room for EOS (+1 spare)
        }
    }

    pub fn with_modes(mut self, modes: Vec<Mode>) -> Self {
        assert!(!modes.is_empty());
        self.modes = modes;
        self
    }

    pub fn seq_len(&self) -> usize {
        self.prompt_len + self.gen_len
    }

    /// Structural minimum prompt length: `[BOS, mode, a, b, SEP]`.
    pub const MIN_PROMPT_LEN: usize = 5;

    pub fn sample_prompt(&self, rng: &mut Rng) -> Prompt {
        self.sample_prompt_len(rng, self.prompt_len)
    }

    /// Sample a prompt with an explicit TRUE length `len` (heterogeneous
    /// prompt lengths for the variable-length serving path). The
    /// instruction layout is identical — `[BOS, mode, a, b, filler..,
    /// SEP]` — only the deterministic filler shrinks, so the expected
    /// response and the reward oracle (functions of mode/a/b alone) are
    /// shared across lengths. `len` must be in
    /// `MIN_PROMPT_LEN..=prompt_len`.
    pub fn sample_prompt_len(&self, rng: &mut Rng, len: usize) -> Prompt {
        assert!(
            (Self::MIN_PROMPT_LEN..=self.prompt_len).contains(&len),
            "prompt length {len} outside {}..={}",
            Self::MIN_PROMPT_LEN,
            self.prompt_len
        );
        let mode = *rng.choose(&self.modes);
        let (lo, hi) = self.vocab.content_range();
        let a = rng.range(lo as i64, hi as i64) as i32;
        let b = rng.range(lo as i64, hi as i64) as i32;
        let mut tokens = Vec::with_capacity(len);
        tokens.push(Vocab::BOS);
        tokens.push(mode.token());
        tokens.push(a);
        tokens.push(b);
        // Deterministic filler (repeats a/b) so the prompt carries no noise
        // the model must ignore spuriously.
        while tokens.len() < len - 1 {
            let i = tokens.len();
            tokens.push(if i % 2 == 0 { a } else { b });
        }
        tokens.push(Vocab::SEP);
        Prompt { mode, a, b, tokens }
    }

    /// The rule-correct response (length == gen_len, EOS then PAD).
    pub fn expected_response(&self, p: &Prompt) -> Vec<i32> {
        let n = self.vocab.n_content();
        let base = Vocab::CONTENT_BASE;
        let mut r = Vec::with_capacity(self.gen_len);
        for i in 0..self.resp_len {
            let t = match p.mode {
                Mode::Repeat => {
                    if i % 2 == 0 {
                        p.a
                    } else {
                        p.b
                    }
                }
                Mode::Constant => p.a,
                Mode::Count => base + ((p.a - base) + i as i32).rem_euclid(n),
                Mode::Mirror => {
                    if i % 2 == 0 {
                        p.b
                    } else {
                        p.a
                    }
                }
            };
            r.push(t);
        }
        r.push(Vocab::EOS);
        while r.len() < self.gen_len {
            r.push(Vocab::PAD);
        }
        r
    }

    /// Ground-truth reward in [0, 1]: match fraction over the rule region
    /// plus an EOS-placement bonus. This is the oracle the paper gets from
    /// human preference; PPO must raise it.
    pub fn reward(&self, p: &Prompt, response: &[i32]) -> f32 {
        let expected = self.expected_response(p);
        let mut hits = 0usize;
        for i in 0..self.resp_len.min(response.len()) {
            if response[i] == expected[i] {
                hits += 1;
            }
        }
        let match_frac = hits as f32 / self.resp_len as f32;
        let eos_bonus = if response.get(self.resp_len) == Some(&Vocab::EOS) {
            0.2
        } else {
            0.0
        };
        (match_frac * 0.8 + eos_bonus).clamp(0.0, 1.0)
    }

    /// Corrupt a correct response (for preference-pair "rejected" sides).
    /// severity in (0, 1]: fraction of positions replaced with random
    /// content tokens.
    pub fn corrupt(&self, response: &[i32], rng: &mut Rng, severity: f32) -> Vec<i32> {
        let (lo, hi) = self.vocab.content_range();
        let mut out = response.to_vec();
        let mut changed = false;
        for x in out.iter_mut().take(self.resp_len) {
            if rng.f32() < severity {
                let mut t = rng.range(lo as i64, hi as i64) as i32;
                if t == *x {
                    t = lo + ((t - lo + 1) % self.vocab.n_content());
                }
                *x = t;
                changed = true;
            }
        }
        if !changed {
            // Guarantee the pair is strictly ordered.
            let i = rng.below(self.resp_len as u32) as usize;
            out[i] = lo + ((out[i] - lo + 1).rem_euclid(self.vocab.n_content()));
        }
        out
    }

    /// Full sequence = prompt ++ response (the artifacts' `[b, s]` layout).
    pub fn full_sequence(&self, p: &Prompt, response: &[i32]) -> Vec<i32> {
        let mut seq = p.tokens.clone();
        seq.extend_from_slice(response);
        assert_eq!(seq.len(), self.seq_len());
        seq
    }

    /// An SFT batch: correct demonstrations, loss on response positions only.
    pub fn sft_batch(&self, rng: &mut Rng, b: usize) -> TokenBatch {
        let s = self.seq_len();
        let mut batch = TokenBatch::new(b, s);
        for i in 0..b {
            let p = self.sample_prompt(rng);
            let resp = self.expected_response(&p);
            let seq = self.full_sequence(&p, &resp);
            batch.row_mut(i).copy_from_slice(&seq);
            let mask = batch.mask_row_mut(i);
            // Mask indexes next-token predictions: position j predicts
            // token j+1; response tokens live at [prompt_len, prompt_len +
            // resp_len] inclusive of EOS.
            for j in self.prompt_len - 1..self.prompt_len + self.resp_len {
                mask[j] = 1.0;
            }
        }
        batch
    }

    /// A preference batch: (correct, corrupted-with-random-severity).
    pub fn pair_batch(&self, rng: &mut Rng, b: usize) -> PairBatch {
        let s = self.seq_len();
        let mut pb = PairBatch {
            chosen: Vec::with_capacity(b * s),
            rejected: Vec::with_capacity(b * s),
            lens_chosen: Vec::with_capacity(b),
            lens_rejected: Vec::with_capacity(b),
            b,
            s,
        };
        for _ in 0..b {
            let p = self.sample_prompt(rng);
            let good = self.expected_response(&p);
            let severity = 0.3 + 0.7 * rng.f32();
            let bad = self.corrupt(&good, rng, severity);
            pb.chosen.extend(self.full_sequence(&p, &good));
            pb.rejected.extend(self.full_sequence(&p, &bad));
            let last = (self.prompt_len + self.resp_len) as i32; // EOS position
            pb.lens_chosen.push(last);
            pb.lens_rejected.push(last);
        }
        pb
    }

    /// A prompt-only batch for PPO experience generation.
    pub fn prompt_batch(&self, rng: &mut Rng, b: usize) -> Vec<Prompt> {
        (0..b).map(|_| self.sample_prompt(rng)).collect()
    }

    /// A plain-LM batch for mixture (ptx) training: full correct sequences,
    /// loss everywhere — the "pretraining data" of the paper's Step 3.
    pub fn ptx_batch(&self, rng: &mut Rng, b: usize) -> TokenBatch {
        let s = self.seq_len();
        let mut batch = TokenBatch::new(b, s);
        for i in 0..b {
            let p = self.sample_prompt(rng);
            let resp = self.expected_response(&p);
            let seq = self.full_sequence(&p, &resp);
            batch.row_mut(i).copy_from_slice(&seq);
            for m in batch.mask_row_mut(i) {
                *m = 1.0;
            }
        }
        batch
    }

    /// Render tokens for the chat example.
    pub fn detokenize(&self, tokens: &[i32]) -> String {
        tokens
            .iter()
            .map(|&t| match t {
                Vocab::PAD => "·".to_string(),
                Vocab::BOS => "<s>".to_string(),
                Vocab::EOS => "</s>".to_string(),
                Vocab::SEP => "|".to_string(),
                t if t >= Vocab::MODE_BASE && t < Vocab::CONTENT_BASE => {
                    format!("<{:?}>", Mode::from_token(t).unwrap())
                }
                t => {
                    let i = (t - Vocab::CONTENT_BASE) as u32;
                    char::from_u32('a' as u32 + i % 26)
                        .map(|c| {
                            if i >= 26 {
                                format!("{c}{}", i / 26)
                            } else {
                                c.to_string()
                            }
                        })
                        .unwrap_or_else(|| format!("[{t}]"))
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    fn gen() -> TaskGen {
        TaskGen::new(256, 16, 16)
    }

    #[test]
    fn prompt_layout() {
        let g = gen();
        let mut rng = Rng::new(0);
        let p = g.sample_prompt(&mut rng);
        assert_eq!(p.tokens.len(), 16);
        assert_eq!(p.tokens[0], Vocab::BOS);
        assert_eq!(p.tokens[1], p.mode.token());
        assert_eq!(p.tokens[15], Vocab::SEP);
    }

    #[test]
    fn short_prompt_keeps_instruction_layout_and_oracle() {
        // Heterogeneous lengths: the instruction head and SEP tail are
        // preserved at every length, and the reward oracle is shared (a
        // perfect response scores 1.0 regardless of prompt length).
        let g = gen();
        let mut rng = Rng::new(1);
        for len in TaskGen::MIN_PROMPT_LEN..=g.prompt_len {
            let p = g.sample_prompt_len(&mut rng, len);
            assert_eq!(p.tokens.len(), len);
            assert_eq!(p.tokens[0], Vocab::BOS);
            assert_eq!(p.tokens[1], p.mode.token());
            assert_eq!(p.tokens[2], p.a);
            assert_eq!(p.tokens[3], p.b);
            assert_eq!(*p.tokens.last().unwrap(), Vocab::SEP);
            let r = g.expected_response(&p);
            assert!((g.reward(&p, &r) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn prompt_below_structural_floor_panics() {
        let g = gen();
        let mut rng = Rng::new(2);
        g.sample_prompt_len(&mut rng, TaskGen::MIN_PROMPT_LEN - 1);
    }

    #[test]
    fn expected_response_is_rewarded_1() {
        let g = gen();
        Prop::new(128).check("perfect response has reward 1", |rng| {
            let p = g.sample_prompt(rng);
            let r = g.expected_response(&p);
            let rew = g.reward(&p, &r);
            prop_assert!((rew - 1.0).abs() < 1e-6, "reward {rew} != 1");
            Ok(())
        });
    }

    #[test]
    fn corruption_strictly_lowers_reward() {
        let g = gen();
        Prop::new(128).check("corrupt < perfect", |rng| {
            let p = g.sample_prompt(rng);
            let good = g.expected_response(&p);
            let bad = g.corrupt(&good, rng, 0.5);
            let rg = g.reward(&p, &good);
            let rb = g.reward(&p, &bad);
            prop_assert!(rb < rg, "corrupt reward {rb} !< {rg}");
            Ok(())
        });
    }

    #[test]
    fn severity_orders_reward_on_average() {
        let g = gen();
        let mut rng = Rng::new(3);
        let mut sum_low = 0.0;
        let mut sum_high = 0.0;
        for _ in 0..200 {
            let p = g.sample_prompt(&mut rng);
            let good = g.expected_response(&p);
            sum_low += g.reward(&p, &g.corrupt(&good, &mut rng, 0.2));
            sum_high += g.reward(&p, &g.corrupt(&good, &mut rng, 0.9));
        }
        assert!(sum_low > sum_high, "{sum_low} vs {sum_high}");
    }

    #[test]
    fn count_mode_wraps() {
        let g = gen();
        let p = Prompt {
            mode: Mode::Count,
            a: g.vocab.size as i32 - 1, // last content token
            b: Vocab::CONTENT_BASE,
            tokens: vec![],
        };
        let r = g.expected_response(&p);
        assert_eq!(r[0], g.vocab.size as i32 - 1);
        assert_eq!(r[1], Vocab::CONTENT_BASE); // wrapped
    }

    #[test]
    fn modes_produce_distinct_responses() {
        let g = gen();
        let mk = |mode| {
            let p = Prompt { mode, a: 10, b: 11, tokens: vec![] };
            g.expected_response(&p)
        };
        let rs: Vec<_> = Mode::all().iter().map(|&m| mk(m)).collect();
        for i in 0..rs.len() {
            for j in i + 1..rs.len() {
                assert_ne!(rs[i], rs[j], "modes {i} and {j} collide");
            }
        }
    }

    #[test]
    fn sft_batch_masks_response_region_only() {
        let g = gen();
        let mut rng = Rng::new(5);
        let b = g.sft_batch(&mut rng, 4);
        for i in 0..4 {
            let mask = &b.loss_mask[i * 31..(i + 1) * 31];
            let on: f32 = mask.iter().sum();
            assert_eq!(on as usize, g.resp_len + 1); // response + EOS
            // prompt-interior predictions are unmasked
            assert_eq!(mask[..g.prompt_len - 1].iter().sum::<f32>(), 0.0);
        }
    }

    #[test]
    fn pair_batch_chosen_beats_rejected() {
        let g = gen();
        let mut rng = Rng::new(6);
        let pb = g.pair_batch(&mut rng, 8);
        assert_eq!(pb.chosen.len(), 8 * 32);
        for i in 0..8 {
            let c = &pb.chosen[i * 32..(i + 1) * 32];
            let r = &pb.rejected[i * 32..(i + 1) * 32];
            assert_eq!(&c[..16], &r[..16], "prompts must match");
            assert_ne!(&c[16..], &r[16..], "responses must differ");
        }
    }

    #[test]
    fn detokenize_smoke() {
        let g = gen();
        let s = g.detokenize(&[Vocab::BOS, Mode::Count.token(), 8, Vocab::SEP, Vocab::EOS]);
        assert!(s.contains("<s>"));
        assert!(s.contains("<Count>"));
        assert!(s.contains("|"));
    }
}
