//! Data abstraction & blending (paper §3): multiple data sources are
//! blended by weight and *split* across the three training stages so no
//! stage trains on another stage's examples — the paper's
//! "splitting/blending" capability.

use crate::util::rng::Rng;

use super::synthetic::TaskGen;
use super::{PairBatch, TokenBatch};

/// The three pipeline stages data must be partitioned across.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Sft = 0,
    Reward = 1,
    Rlhf = 2,
}

/// Deterministic example→stage assignment: example ids are hashed into
/// [0,1) and bucketed by the cumulative split fractions, so the split is
/// stable across runs and sources (mirrors DeepSpeed-Chat's
/// `data_split="2,4,4"`-style config).
#[derive(Debug, Clone)]
pub struct DataSplit {
    fracs: [f64; 3],
}

impl DataSplit {
    /// e.g. `DataSplit::new(2.0, 4.0, 4.0)` — proportions, not fractions.
    pub fn new(sft: f64, reward: f64, rlhf: f64) -> Self {
        let total = sft + reward + rlhf;
        assert!(total > 0.0);
        DataSplit { fracs: [sft / total, reward / total, rlhf / total] }
    }

    pub fn frac(&self, stage: Stage) -> f64 {
        self.fracs[stage as usize]
    }

    /// Which stage does example `id` belong to?
    pub fn assign(&self, id: u64) -> Stage {
        // splitmix64 finalizer as the hash
        let mut z = id.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^= z >> 31;
        let u = (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.fracs[0] {
            Stage::Sft
        } else if u < self.fracs[0] + self.fracs[1] {
            Stage::Reward
        } else {
            Stage::Rlhf
        }
    }
}

/// A weighted blend of task sources. Every batch draws each row's source
/// i.i.d. by weight, and each row's example id is tagged with the stage so
/// the split is respected.
pub struct Blend {
    sources: Vec<(TaskGen, f64)>,
    split: DataSplit,
    /// Monotone example counter per stage (drives deterministic ids).
    next_id: [u64; 3],
}

impl Blend {
    pub fn new(sources: Vec<(TaskGen, f64)>, split: DataSplit) -> Self {
        assert!(!sources.is_empty());
        let total: f64 = sources.iter().map(|(_, w)| w).sum();
        assert!(total > 0.0, "blend weights must be positive");
        let sources = sources
            .into_iter()
            .map(|(g, w)| (g, w / total))
            .collect();
        Blend { sources, split, next_id: [0; 3] }
    }

    /// All sources must share shapes; return them.
    pub fn shapes(&self) -> (usize, usize) {
        let g = &self.sources[0].0;
        (g.prompt_len, g.gen_len)
    }

    fn pick_source(&self, rng: &mut Rng) -> &TaskGen {
        let u = rng.f64();
        let mut cum = 0.0;
        for (g, w) in &self.sources {
            cum += w;
            if u < cum {
                return g;
            }
        }
        &self.sources.last().unwrap().0
    }

    /// Draw a fresh example id for `stage`, skipping ids the split assigns
    /// elsewhere (rejection over the deterministic hash).
    fn draw_id(&mut self, stage: Stage) -> u64 {
        loop {
            let id = self.next_id[stage as usize];
            self.next_id[stage as usize] += 1;
            if self.split.assign(id) == stage {
                return id;
            }
        }
    }

    /// Per-row rng derived from the example id (reproducible examples).
    fn row_rng(&mut self, stage: Stage) -> Rng {
        let id = self.draw_id(stage);
        Rng::new(id.wrapping_mul(0x2545f4914f6cdd1d) ^ (stage as u64) << 56)
    }

    pub fn sft_batch(&mut self, rng: &mut Rng, b: usize) -> TokenBatch {
        let g0 = self.sources[0].0.clone();
        let s = g0.seq_len();
        let mut out = TokenBatch::new(b, s);
        for i in 0..b {
            let g = self.pick_source(rng).clone();
            let mut rr = self.row_rng(Stage::Sft);
            let row = g.sft_batch(&mut rr, 1);
            out.row_mut(i).copy_from_slice(row.row(0));
            out.mask_row_mut(i).copy_from_slice(&row.loss_mask);
        }
        out
    }

    pub fn pair_batch(&mut self, rng: &mut Rng, b: usize) -> PairBatch {
        let g0 = self.sources[0].0.clone();
        let s = g0.seq_len();
        let mut pb = PairBatch {
            chosen: Vec::with_capacity(b * s),
            rejected: Vec::with_capacity(b * s),
            lens_chosen: Vec::with_capacity(b),
            lens_rejected: Vec::with_capacity(b),
            b,
            s,
        };
        for _ in 0..b {
            let g = self.pick_source(rng).clone();
            let mut rr = self.row_rng(Stage::Reward);
            let one = g.pair_batch(&mut rr, 1);
            pb.chosen.extend_from_slice(&one.chosen);
            pb.rejected.extend_from_slice(&one.rejected);
            pb.lens_chosen.extend_from_slice(&one.lens_chosen);
            pb.lens_rejected.extend_from_slice(&one.lens_rejected);
        }
        pb
    }

    /// Draw `b` RLHF prompts (with their generating task, for the
    /// ground-truth reward oracle). `b` is any size — the artifact batch
    /// for the fixed experience path, or `PpoConfig::rollout_batch` when
    /// the scheduler rollout oversubscribes its prompt queue; example ids
    /// stay a single monotone per-stage stream either way, so the drawn
    /// prompts depend only on how many were drawn before, not on the
    /// consumer's batching.
    pub fn prompt_batch(&mut self, rng: &mut Rng, b: usize) -> Vec<(TaskGen, super::Prompt)> {
        (0..b)
            .map(|_| {
                let g = self.pick_source(rng).clone();
                let mut rr = self.row_rng(Stage::Rlhf);
                let p = g.sample_prompt(&mut rr);
                (g, p)
            })
            .collect()
    }

    /// Draw `b` RLHF prompts with HETEROGENEOUS true lengths: each row's
    /// length is uniform in `[min_len, prompt_len]` (clamped to the
    /// task's structural floor), drawn from the row's own deterministic
    /// rng — the mixed-length traffic the left-padded serving path
    /// carries. The stage's example-id stream is shared with
    /// [`Blend::prompt_batch`], so mixing lengths does not perturb which
    /// examples later fixed-length batches see.
    pub fn prompt_batch_mixed(
        &mut self,
        rng: &mut Rng,
        b: usize,
        min_len: usize,
    ) -> Vec<(TaskGen, super::Prompt)> {
        (0..b)
            .map(|_| {
                let g = self.pick_source(rng).clone();
                let lo = min_len.max(TaskGen::MIN_PROMPT_LEN).min(g.prompt_len);
                let mut rr = self.row_rng(Stage::Rlhf);
                let len = rr.range(lo as i64, g.prompt_len as i64 + 1) as usize;
                let p = g.sample_prompt_len(&mut rr, len);
                (g, p)
            })
            .collect()
    }

    pub fn ptx_batch(&mut self, rng: &mut Rng, b: usize) -> TokenBatch {
        let g0 = self.sources[0].0.clone();
        let s = g0.seq_len();
        let mut out = TokenBatch::new(b, s);
        for i in 0..b {
            let g = self.pick_source(rng).clone();
            let mut rr = self.row_rng(Stage::Rlhf);
            let row = g.ptx_batch(&mut rr, 1);
            out.row_mut(i).copy_from_slice(row.row(0));
            out.mask_row_mut(i).copy_from_slice(&row.loss_mask);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::Mode;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn split_fractions_converge() {
        let split = DataSplit::new(2.0, 4.0, 4.0);
        let mut counts = [0usize; 3];
        let n = 100_000u64;
        for id in 0..n {
            counts[split.assign(id) as usize] += 1;
        }
        let fracs: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
        assert!((fracs[0] - 0.2).abs() < 0.01, "{fracs:?}");
        assert!((fracs[1] - 0.4).abs() < 0.01, "{fracs:?}");
        assert!((fracs[2] - 0.4).abs() < 0.01, "{fracs:?}");
    }

    #[test]
    fn split_is_deterministic() {
        let s1 = DataSplit::new(1.0, 1.0, 1.0);
        let s2 = DataSplit::new(1.0, 1.0, 1.0);
        for id in 0..1000 {
            assert_eq!(s1.assign(id), s2.assign(id));
        }
    }

    #[test]
    fn stages_draw_disjoint_ids() {
        // Any id a stage draws must be assigned to that stage by the split.
        Prop::new(32).check("stage ids disjoint", |rng| {
            let split = DataSplit::new(
                0.5 + rng.f64(),
                0.5 + rng.f64(),
                0.5 + rng.f64(),
            );
            let g = TaskGen::new(64, 8, 8);
            let mut blend = Blend::new(vec![(g, 1.0)], split.clone());
            for stage in [Stage::Sft, Stage::Reward, Stage::Rlhf] {
                for _ in 0..20 {
                    let id = blend.draw_id(stage);
                    prop_assert!(
                        split.assign(id) == stage,
                        "id {id} drawn for {stage:?} but assigned {:?}",
                        split.assign(id)
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn blend_weights_respected() {
        let g1 = TaskGen::new(64, 8, 8).with_modes(vec![Mode::Repeat]);
        let g2 = TaskGen::new(64, 8, 8).with_modes(vec![Mode::Count]);
        let mut blend =
            Blend::new(vec![(g1, 3.0), (g2, 1.0)], DataSplit::new(1.0, 1.0, 1.0));
        let mut rng = Rng::new(0);
        let mut repeat = 0;
        let n = 4000;
        let batch = blend.sft_batch(&mut rng, n);
        for i in 0..n {
            let mode = Mode::from_token(batch.row(i)[1]).unwrap();
            if mode == Mode::Repeat {
                repeat += 1;
            }
        }
        let frac = repeat as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.05, "{frac}");
    }

    #[test]
    fn batches_have_consistent_shapes() {
        let g = TaskGen::new(64, 8, 8);
        let mut blend = Blend::new(vec![(g, 1.0)], DataSplit::new(1.0, 1.0, 1.0));
        let mut rng = Rng::new(1);
        let tb = blend.sft_batch(&mut rng, 3);
        assert_eq!((tb.b, tb.s), (3, 16));
        let pb = blend.pair_batch(&mut rng, 3);
        assert_eq!(pb.chosen.len(), 3 * 16);
        let pr = blend.prompt_batch(&mut rng, 3);
        assert_eq!(pr.len(), 3);
    }

    #[test]
    fn mixed_prompt_batch_spans_the_length_range() {
        let g = TaskGen::new(64, 12, 8);
        let mut blend = Blend::new(vec![(g, 1.0)], DataSplit::new(1.0, 1.0, 1.0));
        let mut rng = Rng::new(2);
        let prompts = blend.prompt_batch_mixed(&mut rng, 200, 5);
        let lens: Vec<usize> = prompts.iter().map(|(_, p)| p.tokens.len()).collect();
        assert!(lens.iter().all(|&l| (5..=12).contains(&l)), "{lens:?}");
        assert!(lens.iter().any(|&l| l < 12), "some rows must be short");
        assert!(lens.iter().any(|&l| l == 12), "some rows must be full length");
        // min_len below the structural floor clamps up instead of panicking.
        let clamped = blend.prompt_batch_mixed(&mut rng, 50, 1);
        assert!(clamped.iter().all(|(_, p)| p.tokens.len() >= 5));
    }
}
