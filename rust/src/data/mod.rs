//! Data layer: the paper's "Data Abstraction and Blending" capability plus
//! the synthetic corpus that replaces human-labelled SFT/preference data.
//!
//! The substitution (DESIGN.md §1): instead of human annotations we use a
//! deterministic instruction-following task with a *rule-defined* reward, so
//! every stage has measurable ground truth — SFT loss must fall, the reward
//! model must recover the rule's ranking, and PPO must raise the true reward.

pub mod blend;
pub mod synthetic;

pub use blend::{Blend, DataSplit, Stage};
pub use synthetic::{TaskGen, Vocab, Prompt};

/// A token batch bound for an artifact: `[b, s]` row-major i32.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub tokens: Vec<i32>,
    pub b: usize,
    pub s: usize,
    /// Next-token loss mask `[b, s-1]` (1.0 on response positions).
    pub loss_mask: Vec<f32>,
}

impl TokenBatch {
    pub fn new(b: usize, s: usize) -> Self {
        TokenBatch {
            tokens: vec![0; b * s],
            b,
            s,
            loss_mask: vec![0.0; b * (s - 1)],
        }
    }

    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.s..(i + 1) * self.s]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [i32] {
        &mut self.tokens[i * self.s..(i + 1) * self.s]
    }

    pub fn mask_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.loss_mask[i * (self.s - 1)..(i + 1) * (self.s - 1)]
    }
}

/// A preference pair batch for reward-model training.
#[derive(Debug, Clone)]
pub struct PairBatch {
    pub chosen: Vec<i32>,
    pub rejected: Vec<i32>,
    /// Index of the last real (scored) token per row.
    pub lens_chosen: Vec<i32>,
    pub lens_rejected: Vec<i32>,
    pub b: usize,
    pub s: usize,
}
