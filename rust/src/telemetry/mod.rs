//! Unified telemetry: bounded event tracing + log-bucketed latency
//! histograms for every subsystem (serving scheduler, hybrid engine,
//! rollout, PPO pipeline).
//!
//! # Event model
//!
//! A [`Telemetry`] handle is a cheaply-cloneable reference to one shared
//! recorder (all clones append to the same buffer — the scheduler, the
//! engine, and the PPO trainer each hold a clone). Events are typed and
//! fixed-size ([`Event`]): span begin/end pairs, instants, and counter
//! samples, each stamped with a monotonic microsecond timestamp and a
//! *track* id ([`Event::tid`]) that groups them into timelines — one track
//! per batch slot ([`slot_tid`]), one for the request queue
//! ([`TID_QUEUE`]), one for fused engine dispatches ([`TID_ENGINE`]), and
//! one per RLHF pipeline phase ([`TID_ROLLOUT`] / [`TID_SCORE`] /
//! [`TID_TRAIN`] / [`TID_CHECKPOINT`] / [`TID_GUARD`]).
//!
//! The canonical request lifecycle, as recorded by the serving scheduler:
//!
//! ```text
//! queue track:  B queued ............ E queued            (per attempt)
//! slot track:   B request [B prefill E prefill] i first_token ... E request
//!                                                  (E arg = finish code)
//! engine track: B decode E decode                      (one per dispatch)
//! ```
//!
//! Fault handling adds instants: `requeue` (queue track, arg = attempts),
//! `prefill_fault` / `quarantine` (slot track), `decode_retry` (engine
//! track), and a `request` span that ends with arg `-1` marks an admission
//! attempt aborted by a prefill fault (the request goes back to the
//! queue and opens a fresh span pair on its next attempt).
//!
//! # Overhead contract
//!
//! A disabled handle ([`Telemetry::disabled`], the default everywhere) is
//! a `None`: every record call is a branch on an `Option` and returns —
//! **no allocation, no clock read, no locking on the hot path**. An
//! enabled handle pre-allocates its entire event buffer up front
//! ([`Telemetry::enabled`]); recording writes into that fixed-capacity
//! buffer and, once full, *counts drops* ([`Telemetry::dropped`]) instead
//! of growing. Overflow drops the NEWEST events — the buffer keeps the
//! earliest-recorded prefix of the timeline (a coherent span prefix,
//! never an End without its Begin), and the exporter stamps the drop
//! count into the trace (`telemetry_dropped`) so downstream tooling can
//! tell a truncated trace from a complete one instead of misreading the
//! missing tail as unclosed spans. Histograms are fixed arrays of `u64` buckets
//! ([`LogHistogram`]) — recording a sample is a shift and an add, and
//! percentiles come from O(buckets) memory, never from stored samples.
//! The serve bench asserts the disabled-path bound every run.
//!
//! # Trace export
//!
//! [`Telemetry::chrome_trace_json`] renders the buffer in Chrome
//! trace-event JSON (the array form), loadable in Perfetto or
//! `chrome://tracing`: `B`/`E` duration events, `i` instants, `C`
//! counters, with thread-name metadata so tracks render as "slot 3",
//! "queue", "rollout", etc. [`metrics_snapshot_json`] is the companion
//! one-shot document: it merges the runtime's per-artifact
//! [`ExecStats`](crate::runtime::ExecStats), the scheduler's
//! [`SchedStats`](crate::serving::SchedStats), per-iteration PPO
//! [`IterStats`](crate::coordinator::IterStats) aggregates, KV page
//! occupancy ([`KvOccupancy`]), and the three latency histograms into one
//! JSON object (the serve protocol's `stats` command and `dschat train
//! --metrics-out` both emit it).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

/// Track for queue-residency spans (`queued`).
pub const TID_QUEUE: u32 = 1;
/// Track for fused engine dispatches (`decode` spans, `decode_retry`).
pub const TID_ENGINE: u32 = 2;
/// RLHF pipeline-phase tracks (one per phase, so the phases render as
/// parallel timelines and the rollout/score overlap is visible).
pub const TID_ROLLOUT: u32 = 11;
pub const TID_SCORE: u32 = 12;
pub const TID_TRAIN: u32 = 13;
pub const TID_CHECKPOINT: u32 = 14;
pub const TID_GUARD: u32 = 15;
/// Per-slot request tracks start here: slot `s` records on `100 + s`.
pub const TID_SLOT0: u32 = 100;

/// The track id of batch slot `slot`.
pub fn slot_tid(slot: usize) -> u32 {
    TID_SLOT0 + slot as u32
}

/// Finish-reason codes carried in the `request` span's end arg (the
/// scheduler writes them; the exporter decodes them back to strings).
pub const FINISH_EOS: i64 = 0;
pub const FINISH_LENGTH: i64 = 1;
pub const FINISH_FAILED: i64 = 2;
pub const FINISH_DEADLINE: i64 = 3;
/// The request was preempted mid-decode (KV pool exhausted) and burned
/// through its retry budget without completing.
pub const FINISH_PREEMPTED: i64 = 4;
/// End-arg of a `request` span aborted by a prefill fault or a mid-decode
/// preemption (the request was NOT retired — it went back to the queue).
pub const FINISH_ABORTED: i64 = -1;

/// Event phase, mirroring the Chrome trace-event `ph` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ph {
    Begin,
    End,
    Instant,
    Counter,
}

/// One fixed-size telemetry event. `name` is `&'static str` by design:
/// recording never allocates or copies strings.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Microseconds since the handle was created (monotonic).
    pub ts_us: u64,
    /// Track (rendered as a Chrome trace thread) — see the `TID_*`
    /// constants and [`slot_tid`].
    pub tid: u32,
    pub ph: Ph,
    pub name: &'static str,
    /// Correlation id (request id, PPO iteration, ...); 0 when unused.
    pub id: u64,
    /// One generic payload (token count, finish code, counter value...).
    pub arg: i64,
}

/// The histograms every [`Telemetry`] handle carries. Values are recorded
/// in MICROSECONDS.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hist {
    /// Submit → first generated token, per request.
    Ttft = 0,
    /// Gap between consecutive generated tokens of one request (fused
    /// N-token chunks record the per-token amortized gap once per token
    /// they cover — tokens genuinely arrive in bursts there, and the
    /// amortized view is the one the tok/s contract speaks to; a chunk
    /// carrying the request's FIRST token records that token as TTFT and
    /// amortizes the chunk wall time over the remaining tokens).
    InterToken = 1,
    /// Submit → admission (slot acquired), per admission.
    QueueWait = 2,
}
const N_HISTS: usize = 3;

// ---------------------------------------------------------------------
// Log-bucketed histogram
// ---------------------------------------------------------------------

/// Sub-buckets per octave: 2^4 = 16 gives <= 6.25% relative bucket width.
const SUB_BITS: u32 = 4;
const SUBS: usize = 1 << SUB_BITS;
/// Octaves above the exact range; the top bucket starts at `31 << 39` us
/// (~200 days) — everything larger saturates into it.
const OCTAVES: usize = 40;
/// 16 exact buckets (values 0..16) + 40 octaves x 16 sub-buckets.
pub const N_BUCKETS: usize = SUBS + OCTAVES * SUBS;

/// HDR-style log-bucketed histogram: exact unit buckets for values below
/// 16, then 16 sub-buckets per power of two (<= 6.25% relative error),
/// saturating at the top bucket. Fixed memory, O(buckets) percentiles.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: vec![0; N_BUCKETS], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

/// Bucket index of value `v` (saturates at the last bucket).
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize;
    // First log octave (values 16..32, msb == 4) is octave 0, starting
    // right after the SUBS exact unit buckets.
    let octave = msb - SUB_BITS as usize;
    let offset = ((v >> (msb - SUB_BITS as usize)) as usize) & (SUBS - 1);
    (SUBS + octave * SUBS + offset).min(N_BUCKETS - 1)
}

/// Inclusive lower bound of bucket `idx`.
pub fn bucket_lo(idx: usize) -> u64 {
    if idx < SUBS {
        return idx as u64;
    }
    let octave = (idx - SUBS) / SUBS;
    let offset = (idx - SUBS) % SUBS;
    ((SUBS + offset) as u64) << octave
}

/// Width of bucket `idx` (its exclusive upper bound is `lo + width`).
pub fn bucket_width(idx: usize) -> u64 {
    if idx < SUBS {
        1
    } else {
        1u64 << ((idx - SUBS) / SUBS)
    }
}

impl LogHistogram {
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Samples that landed in the saturating top bucket.
    pub fn saturated(&self) -> u64 {
        self.counts[N_BUCKETS - 1]
    }

    /// The `p`-th percentile (0 < p <= 100), linearly interpolated inside
    /// the containing bucket: the k-th of n samples in a bucket `[lo, lo+w)`
    /// reads as `lo + w * k / n`. Exact-range buckets (width 1) therefore
    /// resolve to within one microsecond; log buckets to within 6.25%.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0).clamp(0.0, 1.0) * self.total as f64;
        let mut cum = 0u64;
        let mut last_nonzero = 0usize;
        for idx in 0..N_BUCKETS {
            let k = self.counts[idx];
            if k == 0 {
                continue;
            }
            last_nonzero = idx;
            if (cum + k) as f64 >= target {
                let f = ((target - cum as f64) / k as f64).clamp(0.0, 1.0);
                return bucket_lo(idx) as f64 + f * bucket_width(idx) as f64;
            }
            cum += k;
        }
        bucket_lo(last_nonzero) as f64 + bucket_width(last_nonzero) as f64
    }

    /// `{"p50_ms": ..}`-style JSON block (values converted us -> ms) for
    /// the bench emitters; `null` when no sample was recorded so a missing
    /// phase reads as absent, not as 0ms latency.
    pub fn json_ms_block(&self) -> String {
        if self.total == 0 {
            return "null".into();
        }
        format!(
            "{{\"count\": {}, \"mean_ms\": {:.3}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \
             \"p99_ms\": {:.3}, \"max_ms\": {:.3}}}",
            self.total,
            self.mean() / 1e3,
            self.percentile(50.0) / 1e3,
            self.percentile(95.0) / 1e3,
            self.percentile(99.0) / 1e3,
            self.max as f64 / 1e3,
        )
    }
}

// ---------------------------------------------------------------------
// Telemetry handle
// ---------------------------------------------------------------------

struct Inner {
    t0: Instant,
    cap: usize,
    buf: Vec<Event>,
    dropped: u64,
    hists: [LogHistogram; N_HISTS],
}

/// Shared telemetry recorder — see the module docs for the event model
/// and the overhead contract. Clone freely: all clones record into the
/// same buffer. The default handle is disabled and free.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<Rc<RefCell<Inner>>>,
}

impl Telemetry {
    /// The no-op handle: every record call is a branch-and-return.
    pub fn disabled() -> Telemetry {
        Telemetry { inner: None }
    }

    /// An enabled handle with a fixed event capacity (pre-allocated here,
    /// never grown; overflow counts into [`Telemetry::dropped`]).
    pub fn enabled(capacity: usize) -> Telemetry {
        let cap = capacity.max(1);
        Telemetry {
            inner: Some(Rc::new(RefCell::new(Inner {
                t0: Instant::now(),
                cap,
                buf: Vec::with_capacity(cap),
                dropped: 0,
                hists: Default::default(),
            }))),
        }
    }

    /// An enabled handle with the default 64Ki-event buffer (~2.5 MiB).
    pub fn enabled_default() -> Telemetry {
        Telemetry::enabled(1 << 16)
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Microseconds since this handle was created (0 when disabled).
    pub fn now_us(&self) -> u64 {
        match &self.inner {
            Some(i) => i.borrow().t0.elapsed().as_micros() as u64,
            None => 0,
        }
    }

    fn push(&self, tid: u32, ph: Ph, name: &'static str, id: u64, arg: i64) {
        let Some(inner) = &self.inner else {
            return;
        };
        let mut inner = inner.borrow_mut();
        let ts_us = inner.t0.elapsed().as_micros() as u64;
        if inner.buf.len() < inner.cap {
            inner.buf.push(Event { ts_us, tid, ph, name, id, arg });
        } else {
            inner.dropped += 1;
        }
    }

    /// Open a span on `tid`. Every begin must be matched by an
    /// [`Telemetry::end`] with the same `tid`/`name` (spans on one track
    /// nest by stack order, the Chrome trace rule).
    pub fn begin(&self, tid: u32, name: &'static str, id: u64, arg: i64) {
        self.push(tid, Ph::Begin, name, id, arg);
    }

    pub fn end(&self, tid: u32, name: &'static str, id: u64, arg: i64) {
        self.push(tid, Ph::End, name, id, arg);
    }

    pub fn instant(&self, tid: u32, name: &'static str, id: u64, arg: i64) {
        self.push(tid, Ph::Instant, name, id, arg);
    }

    /// Record a counter sample (rendered as a counter track).
    pub fn counter(&self, name: &'static str, value: i64) {
        self.push(TID_ENGINE, Ph::Counter, name, 0, value);
    }

    /// Record a latency sample (microseconds) into one of the histograms.
    pub fn record(&self, which: Hist, v_us: u64) {
        if let Some(inner) = &self.inner {
            inner.borrow_mut().hists[which as usize].record(v_us);
        }
    }

    /// Events recorded so far (0 when disabled).
    pub fn event_count(&self) -> usize {
        self.inner.as_ref().map_or(0, |i| i.borrow().buf.len())
    }

    /// Events lost to the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.borrow().dropped)
    }

    /// Snapshot of the event buffer (cheap copies of fixed-size events).
    pub fn events(&self) -> Vec<Event> {
        self.inner.as_ref().map_or_else(Vec::new, |i| i.borrow().buf.clone())
    }

    /// Snapshot of one histogram (disabled handles return an empty one).
    pub fn hist(&self, which: Hist) -> LogHistogram {
        self.inner
            .as_ref()
            .map_or_else(LogHistogram::default, |i| i.borrow().hists[which as usize].clone())
    }

    /// Render the buffer as Chrome trace-event JSON (array form) —
    /// loadable in Perfetto / `chrome://tracing`. One metadata
    /// `thread_name` record per track; `request` span ends decode their
    /// finish code into `args.finish`. If the buffer overflowed (the
    /// timeline tail was dropped), a final `telemetry_dropped` instant
    /// carries the drop count so consumers (scripts/check_trace.py) can
    /// distinguish a truncated trace from unclosed spans.
    pub fn chrome_trace_json(&self) -> String {
        let events = self.events();
        let dropped = self.dropped();
        let mut out = String::with_capacity(events.len() * 96 + 1024);
        out.push_str("[\n");
        // Track-name metadata first, one per distinct tid.
        let mut tids: Vec<u32> = events.iter().map(|e| e.tid).collect();
        tids.sort_unstable();
        tids.dedup();
        let mut first = true;
        for tid in tids {
            let name = track_name(tid);
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(&format!(
                "{{\"ph\": \"M\", \"pid\": 1, \"tid\": {tid}, \"name\": \"thread_name\", \
                 \"args\": {{\"name\": \"{name}\"}}}}"
            ));
        }
        for e in &events {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            let ph = match e.ph {
                Ph::Begin => "B",
                Ph::End => "E",
                Ph::Instant => "i",
                Ph::Counter => "C",
            };
            out.push_str(&format!(
                "{{\"ph\": \"{ph}\", \"pid\": 1, \"tid\": {}, \"ts\": {}, \"name\": \"{}\"",
                e.tid, e.ts_us, e.name
            ));
            if e.ph == Ph::Instant {
                out.push_str(", \"s\": \"t\"");
            }
            match e.ph {
                Ph::Counter => out.push_str(&format!(", \"args\": {{\"value\": {}}}}}", e.arg)),
                Ph::End if e.name == "request" => out.push_str(&format!(
                    ", \"args\": {{\"id\": {}, \"v\": {}, \"finish\": \"{}\"}}}}",
                    e.id,
                    e.arg,
                    finish_name(e.arg)
                )),
                _ => out.push_str(&format!(", \"args\": {{\"id\": {}, \"v\": {}}}}}", e.id, e.arg)),
            }
        }
        if dropped > 0 {
            let last_ts = events.last().map_or(0, |e| e.ts_us);
            if !first {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "{{\"ph\": \"i\", \"pid\": 1, \"tid\": {TID_ENGINE}, \"ts\": {last_ts}, \
                 \"name\": \"telemetry_dropped\", \"s\": \"g\", \
                 \"args\": {{\"value\": {dropped}}}}}"
            ));
        }
        out.push_str("\n]\n");
        out
    }
}

/// Human name of a track id (trace rendering).
pub fn track_name(tid: u32) -> String {
    match tid {
        TID_QUEUE => "queue".into(),
        TID_ENGINE => "engine".into(),
        TID_ROLLOUT => "rollout".into(),
        TID_SCORE => "score".into(),
        TID_TRAIN => "train".into(),
        TID_CHECKPOINT => "checkpoint".into(),
        TID_GUARD => "guard".into(),
        t if t >= TID_SLOT0 => format!("slot {}", t - TID_SLOT0),
        t => format!("track {t}"),
    }
}

/// Decode a `request` end arg back to its finish reason.
pub fn finish_name(code: i64) -> &'static str {
    match code {
        FINISH_EOS => "eos",
        FINISH_LENGTH => "length",
        FINISH_FAILED => "failed",
        FINISH_DEADLINE => "deadline",
        FINISH_PREEMPTED => "preempted",
        FINISH_ABORTED => "aborted",
        _ => "unknown",
    }
}

// ---------------------------------------------------------------------
// Unified metrics snapshot
// ---------------------------------------------------------------------

/// KV pool occupancy at snapshot time (see
/// `HybridEngine::kv_occupancy`). Arena layouts report slot occupancy
/// with `n_pages = 0`.
#[derive(Debug, Clone, Default)]
pub struct KvOccupancy {
    pub paged: bool,
    pub n_slots: usize,
    pub active_slots: usize,
    /// Valid (non-pad) cached tokens across all live slots.
    pub valid_tokens: usize,
    pub page_size: usize,
    pub n_pages: usize,
    pub free_pages: usize,
    /// Shared prefixes registered for reuse (paged only).
    pub registered_prefixes: usize,
    /// Highest allocatable page index (`limit_pages` cap); 0 for arena
    /// layouts, `n_pages - 1` for an uncapped paged pool.
    pub usable_pages: usize,
    /// High-water mark of simultaneously drawn pages (paged only).
    pub peak_used_pages: usize,
    /// Registered prefixes evicted under pool pressure (LRU order).
    pub prefix_evictions: u64,
    /// Pages reclaimed by those evictions.
    pub pages_stolen: u64,
    /// Prefix registrations refused because a different token sequence
    /// already occupied the hash bucket.
    pub hash_collisions: u64,
}

impl KvOccupancy {
    fn json(&self) -> String {
        // `used_pages` is drawn-now: allocatable extent minus the free list.
        // Legacy snapshots (no `limit_pages` support) report usable_pages 0,
        // where the full-extent derivation is the honest figure.
        let extent = if self.usable_pages > 0 { self.usable_pages } else { self.n_pages };
        format!(
            "{{\n    \"paged\": {},\n    \"n_slots\": {},\n    \"active_slots\": {},\n    \
             \"valid_tokens\": {},\n    \"page_size\": {},\n    \"n_pages\": {},\n    \
             \"usable_pages\": {},\n    \"free_pages\": {},\n    \"used_pages\": {},\n    \
             \"peak_used_pages\": {},\n    \"registered_prefixes\": {},\n    \
             \"prefix_evictions\": {},\n    \"pages_stolen\": {},\n    \
             \"hash_collisions\": {}\n  }}",
            self.paged,
            self.n_slots,
            self.active_slots,
            self.valid_tokens,
            self.page_size,
            self.n_pages,
            self.usable_pages,
            self.free_pages,
            extent.saturating_sub(self.free_pages),
            self.peak_used_pages,
            self.registered_prefixes,
            self.prefix_evictions,
            self.pages_stolen,
            self.hash_collisions,
        )
    }
}

/// Schema version stamped into every snapshot/bench document this repo
/// emits; bump when a field changes meaning so downstream trajectory
/// tooling can detect the break.
pub const SNAPSHOT_SCHEMA_VERSION: u32 = 1;

/// One JSON document merging every measurement surface: per-artifact
/// runtime [`ExecStats`](crate::runtime::ExecStats), scheduler
/// [`SchedStats`](crate::serving::SchedStats), PPO
/// [`IterStats`](crate::coordinator::IterStats) aggregates, KV occupancy,
/// and the telemetry histograms/drop counters. Any section may be absent
/// (`None` / empty) — the serve loop has no PPO iterations, a training
/// run may have no scheduler.
pub fn metrics_snapshot_json(
    exec: &BTreeMap<String, crate::runtime::ExecStats>,
    sched: Option<&crate::serving::SchedStats>,
    iters: &[crate::coordinator::IterStats],
    kv: Option<&KvOccupancy>,
    tel: &Telemetry,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str(&format!("  \"schema_version\": {SNAPSHOT_SCHEMA_VERSION},\n"));

    // Runtime: per-artifact call/byte accounting + totals.
    let (mut calls, mut up, mut down, mut fallbacks) = (0u64, 0u64, 0u64, 0u64);
    s.push_str("  \"runtime\": {\n    \"artifacts\": {");
    let mut first = true;
    for (name, st) in exec {
        calls += st.calls;
        up += st.bytes_uploaded;
        down += st.bytes_fetched;
        fallbacks += st.fallback_untuples;
        if !first {
            s.push(',');
        }
        first = false;
        s.push_str(&format!(
            "\n      \"{name}\": {{\"calls\": {}, \"exec_secs\": {:.6}, \
             \"bytes_fetched\": {}, \"bytes_uploaded\": {}, \"fallback_untuples\": {}}}",
            st.calls, st.exec_secs, st.bytes_fetched, st.bytes_uploaded, st.fallback_untuples
        ));
    }
    s.push_str(&format!(
        "\n    }},\n    \"total_calls\": {calls},\n    \"total_bytes_uploaded\": {up},\n    \
         \"total_bytes_fetched\": {down},\n    \"fallback_untuples\": {fallbacks}\n  }},\n"
    ));

    // Serving: scheduler counters + derived rates.
    match sched {
        Some(st) => s.push_str(&format!(
            "  \"serving\": {{\n    \"submitted\": {},\n    \"admitted\": {},\n    \
             \"completed\": {},\n    \"steps\": {},\n    \"decode_calls\": {},\n    \
             \"prefills\": {},\n    \"tokens_sampled\": {},\n    \"retired_eos\": {},\n    \
             \"retired_length\": {},\n    \"retired_failed\": {},\n    \
             \"retired_deadline\": {},\n    \"retired_preempted\": {},\n    \
             \"requeues\": {},\n    \"prefill_faults\": {},\n    \
             \"decode_faults\": {},\n    \"decode_retries\": {},\n    \
             \"preemptions\": {},\n    \"admission_deferrals\": {},\n    \
             \"quarantined\": {},\n    \
             \"peak_queue_depth\": {},\n    \"utilization\": {:.4},\n    \
             \"bubble_fraction\": {:.4},\n    \"pad_fraction\": {:.4},\n    \
             \"admitted_tokens\": {},\n    \"computed_tokens\": {},\n    \
             \"reused_tokens\": {},\n    \"cache_hit_rate\": {:.4},\n    \
             \"chunk_waste_tokens\": {}\n  }},\n",
            st.submitted,
            st.admitted,
            st.completed,
            st.steps,
            st.decode_calls,
            st.prefills,
            st.tokens_sampled,
            st.retired_eos,
            st.retired_length,
            st.retired_failed,
            st.retired_deadline,
            st.retired_preempted,
            st.requeues,
            st.prefill_faults,
            st.decode_faults,
            st.decode_retries,
            st.preemptions,
            st.admission_deferrals,
            st.quarantined,
            st.peak_queue_depth,
            st.utilization(),
            st.bubble_fraction(),
            st.pad_fraction(),
            st.admitted_tokens(),
            st.computed_tokens(),
            st.reused_tokens,
            st.cache_hit_rate(),
            st.chunk_waste_tokens,
        )),
        None => s.push_str("  \"serving\": null,\n"),
    }

    // Training: aggregate over the recorded PPO iterations.
    if iters.is_empty() {
        s.push_str("  \"training\": null,\n");
    } else {
        let n = iters.len() as f64;
        let mean = |f: fn(&crate::coordinator::IterStats) -> f64| -> f64 {
            iters.iter().map(f).sum::<f64>() / n
        };
        let gen_secs: f64 = iters.iter().map(|i| i.gen_secs).sum();
        let train_secs: f64 = iters.iter().map(|i| i.train_secs).sum();
        let gen_tokens: u64 = iters.iter().map(|i| i.gen_tokens).sum();
        s.push_str(&format!(
            "  \"training\": {{\n    \"iterations\": {},\n    \"gen_secs\": {:.4},\n    \
             \"train_secs\": {:.4},\n    \"gen_tokens\": {},\n    \
             \"mean_true_reward\": {:.4},\n    \"mean_rm_score\": {:.4},\n    \
             \"mean_kl_to_ref\": {:.4},\n    \"mean_actor_loss\": {:.4},\n    \
             \"mean_critic_loss\": {:.4},\n    \"mean_clipfrac\": {:.4},\n    \
             \"mean_rollout_bubble\": {:.4}\n  }},\n",
            iters.len(),
            gen_secs,
            train_secs,
            gen_tokens,
            mean(|i| i.true_reward),
            mean(|i| i.rm_score),
            mean(|i| i.kl_to_ref),
            mean(|i| i.actor_loss),
            mean(|i| i.critic_loss),
            mean(|i| i.clipfrac),
            mean(|i| i.rollout_bubble),
        ));
    }

    // KV occupancy.
    match kv {
        Some(occ) => s.push_str(&format!("  \"kv\": {},\n", occ.json())),
        None => s.push_str("  \"kv\": null,\n"),
    }

    // Telemetry: histograms + recorder health.
    s.push_str(&format!(
        "  \"telemetry\": {{\n    \"enabled\": {},\n    \"events\": {},\n    \
         \"dropped_events\": {},\n    \"ttft_ms\": {},\n    \"inter_token_ms\": {},\n    \
         \"queue_wait_ms\": {}\n  }}\n}}\n",
        tel.is_enabled(),
        tel.event_count(),
        tel.dropped(),
        tel.hist(Hist::Ttft).json_ms_block(),
        tel.hist(Hist::InterToken).json_ms_block(),
        tel.hist(Hist::QueueWait).json_ms_block(),
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    // -- histogram: bucket boundaries ---------------------------------

    #[test]
    fn bucket_boundaries_are_exact_then_log() {
        // Values below 16 get exact unit buckets.
        for v in 0..16u64 {
            assert_eq!(bucket_index(v), v as usize, "exact bucket for {v}");
            assert_eq!(bucket_lo(v as usize), v);
            assert_eq!(bucket_width(v as usize), 1);
        }
        // Octave starts: every power of two above 16 opens a bucket whose
        // lower bound is the value itself and whose width doubles.
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_lo(16), 16);
        assert_eq!(bucket_width(16), 1);
        assert_eq!(bucket_index(31), 31, "last sub-bucket of the first octave");
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_lo(32), 32);
        assert_eq!(bucket_width(32), 2);
        assert_eq!(bucket_index(33), 32, "32 and 33 share a width-2 bucket");
        assert_eq!(bucket_index(34), 33);
        // Monotone and contiguous: every bucket's end is the next's start.
        for idx in 0..N_BUCKETS - 1 {
            assert_eq!(
                bucket_lo(idx) + bucket_width(idx),
                bucket_lo(idx + 1),
                "bucket {idx} not contiguous"
            );
        }
        // Every value lands in the bucket whose range contains it, and the
        // index is monotone in the value — exhaustive over the first
        // octaves (this is exactly the sweep that catches an off-by-one
        // octave shift), then spot checks further up.
        let mut prev = 0usize;
        for v in 0..(1u64 << 16) {
            let idx = bucket_index(v);
            assert!(idx >= prev, "index not monotone at {v}");
            prev = idx;
            assert!(bucket_lo(idx) <= v, "lo({idx}) <= {v}");
            assert!(v < bucket_lo(idx) + bucket_width(idx), "{v} < hi({idx})");
        }
        for v in [123_456u64, 7_654_321, 1 << 30, (31u64 << 39) - 1] {
            let idx = bucket_index(v);
            assert!(bucket_lo(idx) <= v, "lo({idx}) <= {v}");
            assert!(v < bucket_lo(idx) + bucket_width(idx), "{v} < hi({idx})");
        }
    }

    #[test]
    fn histogram_percentile_interpolates() {
        // 100 exact-bucket samples 0..100? No — exact buckets stop at 16.
        // Use 0..10 so every sample has its own unit bucket: percentiles
        // interpolate linearly within and across them.
        let mut h = LogHistogram::default();
        for v in 0..10u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 10);
        // p50 of 10 samples: target rank 5.0 falls at the end of bucket 4
        // (cum 4 + 1 >= 5, f = 1) -> 5.0 exactly.
        assert!((h.percentile(50.0) - 5.0).abs() < 1e-9, "{}", h.percentile(50.0));
        // p10 -> bucket 0 full -> 1.0; p100 -> end of bucket 9 -> 10.0.
        assert!((h.percentile(10.0) - 1.0).abs() < 1e-9);
        assert!((h.percentile(100.0) - 10.0).abs() < 1e-9);
        // Mid-bucket interpolation: two samples in one wide bucket.
        let mut h2 = LogHistogram::default();
        h2.record(40); // bucket [40, 42)
        h2.record(40);
        let p50 = h2.percentile(50.0);
        let (lo, w) = (bucket_lo(bucket_index(40)) as f64, bucket_width(bucket_index(40)) as f64);
        assert!((p50 - (lo + 0.5 * w)).abs() < 1e-9, "half the bucket: {p50}");
        // Relative error contract: p99 of identical samples stays within
        // one sub-bucket (6.25%) of the value.
        let mut h3 = LogHistogram::default();
        for _ in 0..1000 {
            h3.record(100_000);
        }
        let p99 = h3.percentile(99.0);
        assert!((p99 - 100_000.0).abs() / 100_000.0 < 0.0625, "{p99}");
    }

    #[test]
    fn histogram_saturates_at_max_bucket() {
        let mut h = LogHistogram::default();
        h.record(u64::MAX);
        h.record(u64::MAX / 2);
        assert_eq!(h.saturated(), 2, "both land in the top bucket");
        assert_eq!(h.count(), 2);
        // Percentiles stay finite and at least the top bucket's bound.
        let top_lo = bucket_lo(N_BUCKETS - 1) as f64;
        assert!(h.percentile(50.0) >= top_lo);
        assert!(h.percentile(99.0).is_finite());
        // max() tracks the raw value even past saturation.
        assert_eq!(h.max(), u64::MAX);
    }

    #[test]
    fn histogram_mean_min_max_and_empty() {
        let mut h = LogHistogram::default();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.json_ms_block(), "null");
        h.record(10);
        h.record(14);
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 14);
        assert!((h.mean() - 12.0).abs() < 1e-9);
        let block = Json::parse(&h.json_ms_block()).unwrap();
        assert_eq!(block.get("count").and_then(Json::as_usize), Some(2usize));
    }

    // -- bounded event buffer ------------------------------------------

    #[test]
    fn event_buffer_counts_drops_instead_of_growing() {
        let tel = Telemetry::enabled(4);
        for i in 0..10u64 {
            tel.instant(TID_ENGINE, "tick", i, 0);
        }
        assert_eq!(tel.event_count(), 4, "capacity bound holds");
        assert_eq!(tel.dropped(), 6, "overflow counted, not stored");
        // Overflow drops the NEWEST events: the retained prefix is the
        // earliest four, so span Begins never outlive their Ends silently.
        let ids: Vec<u64> = tel.events().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn truncated_trace_carries_the_drop_count() {
        let tel = Telemetry::enabled(2);
        tel.begin(slot_tid(0), "request", 1, 0);
        tel.instant(slot_tid(0), "first_token", 1, 0);
        tel.end(slot_tid(0), "request", 1, FINISH_EOS); // dropped
        assert_eq!(tel.dropped(), 1);
        let doc = Json::parse(&tel.chrome_trace_json()).expect("truncated trace still parses");
        let arr = doc.as_arr().unwrap();
        let marker = arr
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("telemetry_dropped"))
            .expect("overflowed trace must stamp telemetry_dropped");
        assert_eq!(
            marker.get("args").and_then(|a| a.get("value")).and_then(Json::as_usize),
            Some(1)
        );
        // A trace that did NOT overflow carries no marker.
        let ok = Telemetry::enabled(8);
        ok.instant(TID_ENGINE, "tick", 0, 0);
        assert!(!ok.chrome_trace_json().contains("telemetry_dropped"));
    }

    #[test]
    fn disabled_handle_is_inert() {
        let tel = Telemetry::disabled();
        tel.begin(TID_QUEUE, "queued", 1, 0);
        tel.record(Hist::Ttft, 123);
        assert!(!tel.is_enabled());
        assert_eq!(tel.event_count(), 0);
        assert_eq!(tel.dropped(), 0);
        assert_eq!(tel.hist(Hist::Ttft).count(), 0);
        assert_eq!(tel.now_us(), 0);
    }

    #[test]
    fn clones_share_one_recorder() {
        let tel = Telemetry::enabled(16);
        let clone = tel.clone();
        tel.instant(TID_QUEUE, "a", 1, 0);
        clone.instant(TID_ENGINE, "b", 2, 0);
        assert_eq!(tel.event_count(), 2);
        assert_eq!(clone.event_count(), 2);
        clone.record(Hist::QueueWait, 7);
        assert_eq!(tel.hist(Hist::QueueWait).count(), 1);
    }

    // -- chrome trace export ------------------------------------------

    #[test]
    fn chrome_trace_parses_and_pairs_spans() {
        let tel = Telemetry::enabled(64);
        tel.begin(TID_QUEUE, "queued", 7, 0);
        tel.end(TID_QUEUE, "queued", 7, 0);
        tel.begin(slot_tid(0), "request", 7, 4);
        tel.instant(slot_tid(0), "first_token", 7, 0);
        tel.end(slot_tid(0), "request", 7, FINISH_EOS);
        tel.counter("queue_depth", 3);
        let json = tel.chrome_trace_json();
        let doc = Json::parse(&json).expect("trace must be valid JSON");
        let arr = doc.as_arr().expect("trace is an array");
        // Metadata rows name every track used.
        let names: Vec<&str> = arr
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str))
            .collect();
        assert!(names.contains(&"queue") && names.contains(&"slot 0"), "{names:?}");
        // Every B has a matching E on the same track/name.
        let count = |ph: &str, name: &str| {
            arr.iter()
                .filter(|e| {
                    e.get("ph").and_then(Json::as_str) == Some(ph)
                        && e.get("name").and_then(Json::as_str) == Some(name)
                })
                .count()
        };
        assert_eq!(count("B", "queued"), count("E", "queued"));
        assert_eq!(count("B", "request"), count("E", "request"));
        assert_eq!(count("i", "first_token"), 1);
        // The request end decodes its finish code.
        let fin = arr
            .iter()
            .find(|e| {
                e.get("ph").and_then(Json::as_str) == Some("E")
                    && e.get("name").and_then(Json::as_str) == Some("request")
            })
            .and_then(|e| e.get("args"))
            .and_then(|a| a.get("finish"))
            .and_then(Json::as_str);
        assert_eq!(fin, Some("eos"));
        // Timestamps are monotone non-decreasing in buffer order.
        let ts: Vec<u64> =
            arr.iter().filter_map(|e| e.get("ts").and_then(Json::as_usize)).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
    }

    // -- snapshot ------------------------------------------------------

    #[test]
    fn metrics_snapshot_merges_all_sections() {
        let mut exec = BTreeMap::new();
        exec.insert(
            "decode_slots".to_string(),
            crate::runtime::ExecStats {
                calls: 10,
                bytes_fetched: 640,
                bytes_uploaded: 320,
                ..Default::default()
            },
        );
        let sched = crate::serving::SchedStats { submitted: 6, completed: 6, ..Default::default() };
        let occ = KvOccupancy {
            paged: true,
            n_slots: 4,
            active_slots: 2,
            n_pages: 64,
            free_pages: 40,
            page_size: 4,
            ..Default::default()
        };
        let tel = Telemetry::enabled(8);
        tel.record(Hist::Ttft, 1500);
        let json = metrics_snapshot_json(&exec, Some(&sched), &[], Some(&occ), &tel);
        let doc = Json::parse(&json).expect("snapshot must parse");
        assert_eq!(doc.get("schema_version").and_then(Json::as_usize), Some(1));
        assert_eq!(
            doc.get("runtime").and_then(|r| r.get("total_calls")).and_then(Json::as_usize),
            Some(10)
        );
        assert_eq!(
            doc.get("serving").and_then(|s| s.get("submitted")).and_then(Json::as_usize),
            Some(6)
        );
        assert!(matches!(doc.at("training"), Json::Null), "no iterations -> null");
        assert_eq!(
            doc.get("serving").and_then(|s| s.get("preemptions")).and_then(Json::as_usize),
            Some(0)
        );
        assert_eq!(doc.get("kv").and_then(|k| k.get("used_pages")).and_then(Json::as_usize), Some(24));
        assert_eq!(
            doc.get("kv").and_then(|k| k.get("prefix_evictions")).and_then(Json::as_usize),
            Some(0)
        );
        let ttft = doc.get("telemetry").and_then(|t| t.get("ttft_ms")).unwrap();
        assert_eq!(ttft.get("count").and_then(Json::as_usize), Some(1));
    }
}
