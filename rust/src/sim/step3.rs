//! The Step-3 (RLHF) performance model: generation phase + training phase
//! for one PPO iteration of the paper's benchmark recipe, per system.
//!
//! Mechanisms modeled (paper §5.3):
//!  * generation is **memory-bandwidth-bound**: every decode step streams the
//!    (per-rank share of) fp16 parameters through HBM; DS-HE shards with TP
//!    inside a node (activation all-reduces on NVLink), baselines that don't
//!    fit must gather parameters per token ZeRO-3-style;
//!  * training is **compute-bound**: actor fwd+bwd + old-logp fwd + frozen
//!    ref fwd, critic fwd+bwd + frozen RM fwd, with ZeRO collectives on top;
//!  * per-GPU batch sizes are planned from the memory model (super-linear
//!    scaling, Figure 7) and capped by the global batch.

use crate::baselines::SystemModel;
use crate::config::ModelConfig;
use crate::sim::gpu::{Cluster, GIB};
use crate::tp::TpPlan;
use crate::zero::MemoryModel;

/// The paper's Step-3 benchmark recipe (footnote 1 + benchmark settings).
#[derive(Debug, Clone)]
pub struct Recipe {
    /// Query/answer pairs per PPO step (max global batch).
    pub global_batch: u64,
    pub prompt_len: u64,
    pub gen_len: u64,
    /// Total pairs in the dataset (131.9k) — one epoch.
    pub dataset_pairs: u64,
}

impl Default for Recipe {
    fn default() -> Self {
        Recipe {
            global_batch: 1024,
            prompt_len: 256,
            gen_len: 256,
            // 135M tokens/epoch at 512 tokens per pair, 0.5M-token global
            // batches (paper footnote 1): 263.8k pairs -> 258 steps/epoch.
            dataset_pairs: 263_800,
        }
    }
}

impl Recipe {
    pub fn seq_len(&self) -> u64 {
        self.prompt_len + self.gen_len
    }

    pub fn steps_per_epoch(&self) -> u64 {
        self.dataset_pairs.div_ceil(self.global_batch)
    }

    /// The §2.2 single-GPU/single-dataset recipe that Table 6 uses.
    pub fn single_dataset() -> Recipe {
        Recipe { global_batch: 256, dataset_pairs: 16_384, ..Recipe::default() }
    }

    /// Total tokens the paper's recipe touches per epoch (135M).
    pub fn epoch_tokens(&self) -> u64 {
        self.dataset_pairs * self.seq_len()
    }
}

/// Result of simulating one PPO iteration.
#[derive(Debug, Clone)]
pub struct Step3Breakdown {
    pub system: String,
    pub gen_secs: f64,
    pub train_secs: f64,
    /// Per-GPU generation microbatch the memory planner chose.
    pub gen_microbatch: u64,
    pub train_microbatch: u64,
    pub gen_waves: u64,
    /// Effective per-GPU throughput metrics (Figure 6).
    pub gen_tflops_per_gpu: f64,
    pub train_tflops_per_gpu: f64,
    pub effective_tflops_per_gpu: f64,
    /// Pairs per second end-to-end (Figures 3/4 y-axis analogue).
    pub pairs_per_sec: f64,
}

impl Step3Breakdown {
    pub fn iter_secs(&self) -> f64 {
        self.gen_secs + self.train_secs
    }
}

/// Memory budget left for one role after reserving the others (bytes).
fn other_models_bytes(
    sys: &SystemModel,
    actor: &ModelConfig,
    critic: &ModelConfig,
    world: usize,
    offload: bool,
) -> f64 {
    let shard = if sys.stage.params_sharded() { world as f64 } else { 1.0 };
    // ref actor (fp16, sharded when stage-3), frozen RM + critic fp16.
    let ref_b = actor.n_params() as f64 * 2.0 / shard;
    let rm_b = critic.n_params() as f64 * 2.0 / shard;
    let critic_train = MemoryModel::new(sys.stage, world)
        .with_offload(offload)
        .state_bytes(critic.n_params());
    // EMA shadow (fp32) follows the offload setting.
    let ema_b = if offload { 0.0 } else { actor.n_params() as f64 * 4.0 / shard };
    ref_b + rm_b + critic_train + ema_b
}

/// Framework reserve (CUDA context, fragmentation, workspace).
const OVERHEAD_BYTES: f64 = 2.0 * GIB;

/// Saturating MFU curve in the microbatch (drives Figure 7's super-linear
/// region: more memory -> bigger microbatch -> higher efficiency).
fn eff_at(mb: f64, peak_eff: f64) -> f64 {
    peak_eff * mb / (mb + 4.0)
}

/// Model-size MFU factor: small models are launch/latency-bound (low
/// arithmetic intensity per kernel), giving Figure 6 its hump — efficiency
/// climbs into the 6.7B–66B range and the 175B point stays above the 1.3B
/// one despite its batch-size squeeze.
fn size_factor(n_params: f64) -> f64 {
    n_params / (n_params + 2.0e9)
}

/// Simulate one Step-3 PPO iteration. Returns None on OOM.
pub fn simulate_step3(
    sys: &SystemModel,
    actor: &ModelConfig,
    critic: &ModelConfig,
    cluster: &Cluster,
    recipe: &Recipe,
) -> Option<Step3Breakdown> {
    let world = cluster.world();
    let p_a = actor.n_params() as f64;
    let mem = cluster.gpu.mem_bytes;

    // ---------------- training phase memory plan ----------------
    // Offload is adaptive (as in DeepSpeed): pay the PCIe penalty only when
    // the in-HBM plan does not fit. This is what produces Figure 7's
    // super-linear region — at small world sizes memory is tight, so each
    // added node both adds compute AND unlocks a larger microbatch.
    let plan = |offload: bool| -> Option<(MemoryModel, u64)> {
        let mm = MemoryModel::new(sys.stage, world).with_offload(offload);
        let others = other_models_bytes(sys, actor, critic, world, offload);
        let actor_state = mm.state_bytes(actor.n_params());
        let budget = mem - OVERHEAD_BYTES - others - actor_state;
        if budget <= 0.0 {
            return None;
        }
        let per_mb = mm.activation_bytes(actor, 1.0, recipe.seq_len() as usize);
        let mb = (budget / per_mb).floor() as u64;
        if mb == 0 {
            None
        } else {
            Some((mm, mb))
        }
    };
    let (mm, mut mb_train, used_offload) = match plan(false) {
        Some((mm, mb)) => (mm, mb, false),
        None if sys.offload => {
            let (mm, mb) = plan(true)?;
            (mm, mb, true)
        }
        None => return None,
    };
    let _ = &mm;
    let others = other_models_bytes(sys, actor, critic, world, used_offload);
    // The global batch caps the per-GPU microbatch (Figure 7's sub-linear
    // regime once memory is plentiful).
    let cap = (recipe.global_batch as f64 / world as f64).ceil() as u64;
    mb_train = mb_train.min(cap).max(1);

    // ---------------- generation phase memory plan ----------------
    // DS-HE (hybrid memory) releases training activations and runs TP; the
    // baselines keep everything resident.
    let tp_degree = if sys.gen_tp {
        let max_tp = TpPlan::best_degree(actor, cluster.gpus_per_node.min(world));
        // only shard as much as needed to fit fp16 params comfortably
        let mut d = 1;
        while d < max_tp && p_a * 2.0 / d as f64 > 0.55 * mem {
            d *= 2;
        }
        TpPlan::best_degree(actor, d.min(max_tp))
    } else {
        1
    };
    let gen_params_resident = if sys.gen_tp {
        TpPlan::new(actor, tp_degree)?.param_bytes_per_rank(actor, 2.0)
    } else if sys.stage.params_sharded() {
        // ZeRO-3 generation: shards resident + a full gathered working set.
        p_a * 2.0 / world as f64 + p_a * 2.0 * 0.1
    } else {
        p_a * 2.0
    };
    let gen_fixed = if sys.hybrid_memory {
        // training state swapped out except what ZeRO pins
        others * 0.5
    } else {
        others
            + MemoryModel::new(sys.stage, world)
                .with_offload(used_offload)
                .state_bytes(actor.n_params())
    };
    let kv_per_seq =
        actor.kv_cache_bytes(1, recipe.seq_len(), 2) as f64 / tp_degree.max(1) as f64;
    let gen_budget = mem - OVERHEAD_BYTES - gen_fixed - gen_params_resident;
    if gen_budget <= 0.0 {
        return None;
    }
    let mut mb_gen = (gen_budget / kv_per_seq).floor() as u64;
    if mb_gen == 0 {
        return None;
    }
    if !sys.kv_manager {
        // No KV-cache memory manager: fragmentation and allocator churn cap
        // the practical generation batch (paper §4's motivation for the
        // light-weight KV memory system).
        mb_gen = mb_gen.min(crate::baselines::NO_KV_MANAGER_BATCH_CAP);
    }
    // A TP group generates one (larger) batch jointly.
    let gen_groups = (world / tp_degree.max(1)).max(1) as u64;
    mb_gen = mb_gen.min((recipe.global_batch as f64 / gen_groups as f64).ceil() as u64);

    // ---------------- generation phase time ----------------
    let waves = recipe.global_batch.div_ceil(mb_gen * gen_groups);
    // Per decode step per rank: stream the param share, pay TP all-reduces
    // (two per layer) on NVLink, plus fixed framework overhead.
    let bw_time = gen_params_resident / (cluster.gpu.mem_bw * sys.gen_bw_eff);
    let tp_comm = if tp_degree > 1 {
        let plan = TpPlan::new(actor, tp_degree)?;
        let v = plan.comm_bytes_per_token(actor, mb_gen as f64, 2.0);
        v / cluster.nvlink_bw + 2.0 * actor.n_layers as f64 * cluster.latency
    } else {
        0.0
    };
    // ZeRO-3-style generation (Colossal-AI Gemini and friends): sharded
    // parameters are gathered for every forward — i.e. once per generated
    // token. This is the mechanism behind the paper's 15x generation-phase
    // gap (Figure 5): TP keeps activations on NVLink, ZeRO-3 streams the
    // whole model through the interconnect per token.
    let zero3_gather = if !sys.gen_tp && sys.stage.params_sharded() && world > 1 {
        cluster.allgather_secs(p_a * 2.0, world)
    } else {
        0.0
    };
    let per_token = bw_time + tp_comm + zero3_gather + sys.gen_overhead;
    // Prefill: compute-bound forward over the prompt tokens.
    let prefill_flops =
        actor.fwd_flops(recipe.global_batch * recipe.prompt_len, recipe.seq_len()) as f64;
    let prefill_secs = prefill_flops
        / world as f64
        / (cluster.gpu.peak_flops * eff_at(mb_gen as f64, sys.train_eff) * size_factor(p_a));
    let gen_secs = waves as f64 * recipe.gen_len as f64 * per_token + prefill_secs;

    // ---------------- training phase time ----------------
    let pairs = recipe.global_batch;
    let toks = pairs * recipe.seq_len();
    let p_c = critic.n_params() as f64;
    // actor fwd+bwd (6P) + old-logp fwd (2P) + frozen-ref fwd (2P)
    // critic fwd+bwd (6Pc) + frozen-RM fwd (2Pc)
    let train_flops = toks as f64 * (10.0 * p_a + 8.0 * p_c);
    let eff = eff_at(mb_train as f64, sys.train_eff) * size_factor(p_a);
    let compute = train_flops / world as f64 / (cluster.gpu.peak_flops * eff);
    // ZeRO collectives per optimizer step.
    let comm = match () {
        _ if sys.stage.params_sharded() => {
            // allgather params fwd + bwd, reduce-scatter grads
            3.0 * cluster.allgather_secs(p_a * 2.0, world)
        }
        _ => cluster.allreduce_secs(p_a * 2.0, world),
    };
    let offload_penalty = if used_offload {
        // PCIe traffic for optimizer state (12 bytes/param over ~12 GB/s due
        // to the paper-era PCIe gen4 x16 shared per node)
        12.0 * p_a / world as f64 / 12e9
    } else {
        0.0
    };
    let train_secs = compute + comm + offload_penalty;

    // ---------------- throughput metrics ----------------
    let gen_flops = actor.fwd_flops(recipe.global_batch * recipe.gen_len, recipe.seq_len()) as f64
        + prefill_flops;
    let total_flops = gen_flops + train_flops;
    let iter = gen_secs + train_secs;
    Some(Step3Breakdown {
        system: sys.name.clone(),
        gen_secs,
        train_secs,
        gen_microbatch: mb_gen,
        train_microbatch: mb_train,
        gen_waves: waves,
        gen_tflops_per_gpu: gen_flops / gen_secs / world as f64 / 1e12,
        train_tflops_per_gpu: train_flops / train_secs / world as f64 / 1e12,
        effective_tflops_per_gpu: total_flops / iter / world as f64 / 1e12,
        pairs_per_sec: pairs as f64 / iter,
    })
}

/// Single-GPU / single-system max trainable model (§5.2 scalability claims
/// and Table 3): the largest OPT whose Step-3 working set fits.
pub fn max_model<'a>(
    sys: &SystemModel,
    candidates: &'a [ModelConfig],
    critic: &ModelConfig,
    cluster: &Cluster,
    recipe: &Recipe,
) -> Option<&'a ModelConfig> {
    candidates
        .iter()
        .filter(|m| simulate_step3(sys, m, critic, cluster, recipe).is_some())
        .max_by_key(|m| m.n_params())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{colossal_ai, ds_he, hf_ddp};
    use crate::config::model;
    use crate::sim::gpu::{a100_40g, a100_80g};

    fn recipe() -> Recipe {
        Recipe::default()
    }

    #[test]
    fn recipe_matches_paper_footnote() {
        let r = recipe();
        assert_eq!(r.seq_len(), 512);
        assert_eq!(r.steps_per_epoch(), 258);
        // 135M total tokens (67.5M query + 67.5M generated)
        assert!((r.epoch_tokens() as f64 - 135e6).abs() / 135e6 < 0.01);
    }

    #[test]
    fn ds_he_beats_baselines_on_13b_node() {
        let cluster = Cluster::dgx(a100_40g(), 1);
        let a = model("opt-1.3b");
        let c = model("opt-350m");
        let ds = simulate_step3(&ds_he(), &a, &c, &cluster, &recipe()).unwrap();
        let hf = simulate_step3(&hf_ddp(), &a, &c, &cluster, &recipe()).unwrap();
        let cai = simulate_step3(&colossal_ai(), &a, &c, &cluster, &recipe()).unwrap();
        assert!(ds.pairs_per_sec > hf.pairs_per_sec);
        assert!(ds.pairs_per_sec > cai.pairs_per_sec);
        // Figure 5 shape: generation dominates the baselines' iteration.
        assert!(hf.gen_secs > hf.train_secs);
    }

    #[test]
    fn generation_phase_dominated_by_bandwidth_model() {
        let cluster = Cluster::dgx(a100_80g(), 1);
        let a = model("opt-13b");
        let c = model("opt-350m");
        let out = simulate_step3(&ds_he(), &a, &c, &cluster, &recipe()).unwrap();
        // 13B fp16 = 26GB; at 65% of 2039GB/s -> ~20ms/token lower bound
        // per wave; sanity: gen phase is seconds-to-minutes, not hours.
        assert!(out.gen_secs > 1.0 && out.gen_secs < 3600.0, "{}", out.gen_secs);
    }

    #[test]
    fn oom_for_unshardable_giant() {
        // 175B on a single 40G GPU must OOM for every system.
        let cluster = Cluster::single(a100_40g());
        let a = model("opt-175b");
        let c = model("opt-350m");
        for sys in crate::baselines::all_systems() {
            assert!(simulate_step3(&sys, &a, &c, &cluster, &recipe()).is_none(), "{}", sys.name);
        }
    }

    #[test]
    fn max_model_ordering_matches_section_5_2() {
        // Single A100-40G: DS-HE >= 6.7B-ish, HF/CAI stuck at ~1.3B.
        let zoo = crate::config::model_zoo();
        let opts: Vec<_> = zoo.into_iter().filter(|m| m.name.starts_with("opt-")).collect();
        let c = model("opt-350m");
        let cluster = Cluster::single(a100_40g());
        let r = recipe();
        let ds = max_model(&ds_he(), &opts, &c, &cluster, &r).unwrap();
        let hf = max_model(&hf_ddp(), &opts, &c, &cluster, &r).unwrap();
        let cai = max_model(&colossal_ai(), &opts, &c, &cluster, &r).unwrap();
        assert!(ds.n_params() > 4 * hf.n_params(), "ds {} hf {}", ds.name, hf.name);
        assert!(ds.n_params() > 4 * cai.n_params());
    }

    #[test]
    fn scaling_13b_superlinear_then_sublinear() {
        // Figure 7 (left): 13B actor on 1..8 DGX A100-40 nodes.
        let a = model("opt-13b");
        let c = model("opt-350m");
        let r = recipe();
        let mut per_gpu: Vec<f64> = Vec::new();
        for nodes in [1usize, 2, 4, 8] {
            let cluster = Cluster::dgx(a100_40g(), nodes);
            let out = simulate_step3(&ds_he(), &a, &c, &cluster, &r).unwrap();
            per_gpu.push(out.pairs_per_sec / cluster.world() as f64);
        }
        // super-linear early: per-GPU throughput rises from 1 to 2 nodes
        assert!(per_gpu[1] > per_gpu[0] * 1.02, "{per_gpu:?}");
        // sub-linear late: per-GPU throughput stops rising by 8 nodes
        assert!(per_gpu[3] < per_gpu[1] * 1.3, "{per_gpu:?}");
    }
}
