//! The cluster performance simulator: the substitution for the paper's
//! A100/V100 testbeds (DESIGN.md §1). Every paper table/figure is
//! regenerated from these models by `examples/paper_tables.rs` and
//! `examples/paper_figures.rs`.

pub mod e2e;
pub mod gpu;
pub mod step3;

pub use e2e::{finetune_secs, simulate_e2e, E2eReport, PipelineDatasets};
pub use gpu::{a100_40g, a100_80g, a6000_48g, v100_32g, Cluster, GpuSpec, GIB};
pub use step3::{max_model, simulate_step3, Recipe, Step3Breakdown};

use crate::config::ModelConfig;

/// Table 3: max model size supported by DeepSpeed-HE on a single GPU.
///
/// Mechanism: with Hybrid Engine + ZeRO-Offload, the GPU must hold the fp16
/// parameters and gradients plus generation/training working state while
/// optimizer states live in host memory — empirically ~5.5 bytes/param plus
/// a fixed ~2 GiB framework reserve. The answer is discretized to the OPT
/// family exactly as the paper reports it.
pub fn max_model_single_gpu(gpu: &GpuSpec, zoo: &[ModelConfig]) -> Option<ModelConfig> {
    let budget = gpu.mem_bytes - 2.0 * GIB;
    let max_params = budget / 5.5;
    zoo.iter()
        .filter(|m| m.name.starts_with("opt-") && (m.n_params() as f64) <= max_params)
        .max_by_key(|m| m.n_params())
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model_zoo;

    #[test]
    fn table3_exact_reproduction() {
        // Paper Table 3: V100-32G -> 2.7B, A6000-48G -> 6.7B,
        //                A100-40G -> 6.7B, A100-80G -> 13B.
        let zoo = model_zoo();
        for (gpu, expect) in [
            (v100_32g(), "opt-2.7b"),
            (a6000_48g(), "opt-6.7b"),
            (a100_40g(), "opt-6.7b"),
            (a100_80g(), "opt-13b"),
        ] {
            let got = max_model_single_gpu(&gpu, &zoo).unwrap();
            assert_eq!(got.name, expect, "{}", gpu.name);
        }
    }
}
