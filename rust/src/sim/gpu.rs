//! GPU + cluster hardware models at the paper's scales.

/// One GPU SKU (fp16 tensor peak, HBM).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// fp16 tensor-core peak, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub mem_bytes: f64,
    /// Azure-ish price, $/GPU-hour (paper's cost basis).
    pub dollars_per_hour: f64,
}

pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

pub fn v100_32g() -> GpuSpec {
    GpuSpec {
        name: "V100 32G".into(),
        peak_flops: 112e12,
        mem_bw: 900e9,
        mem_bytes: 32.0 * GIB,
        dollars_per_hour: 3.06,
    }
}

pub fn a6000_48g() -> GpuSpec {
    GpuSpec {
        name: "A6000 48G".into(),
        peak_flops: 155e12,
        mem_bw: 768e9,
        mem_bytes: 48.0 * GIB,
        dollars_per_hour: 2.25,
    }
}

pub fn a100_40g() -> GpuSpec {
    GpuSpec {
        name: "A100-40GB".into(),
        peak_flops: 312e12,
        mem_bw: 1555e9,
        mem_bytes: 40.0 * GIB,
        dollars_per_hour: 3.40,
    }
}

pub fn a100_80g() -> GpuSpec {
    GpuSpec {
        name: "A100-80GB".into(),
        peak_flops: 312e12,
        mem_bw: 2039e9,
        mem_bytes: 80.0 * GIB,
        // Table 1: 4.1h on 8 GPUs = $132 -> $4.02/GPU-h.
        dollars_per_hour: 4.02,
    }
}

/// A multi-node cluster of identical GPUs (DGX-style topology).
#[derive(Debug, Clone)]
pub struct Cluster {
    pub gpu: GpuSpec,
    pub gpus_per_node: usize,
    pub nodes: usize,
    /// NVLink/NVSwitch per-GPU bandwidth within a node, bytes/s.
    pub nvlink_bw: f64,
    /// Inter-node (InfiniBand) per-GPU bandwidth, bytes/s.
    pub ib_bw: f64,
    /// Collective latency per hop, seconds (the alpha term).
    pub latency: f64,
}

impl Cluster {
    pub fn dgx(gpu: GpuSpec, nodes: usize) -> Cluster {
        Cluster {
            gpu,
            gpus_per_node: 8,
            nodes,
            nvlink_bw: 300e9,
            ib_bw: 25e9,
            latency: 5e-6,
        }
    }

    pub fn single(gpu: GpuSpec) -> Cluster {
        Cluster { gpus_per_node: 1, nodes: 1, ..Cluster::dgx(gpu, 1) }
    }

    pub fn world(&self) -> usize {
        self.gpus_per_node * self.nodes
    }

    /// Effective per-GPU link bandwidth for a collective spanning `n` GPUs:
    /// NVLink while within one node, bottlenecked by IB across nodes.
    pub fn link_bw(&self, n: usize) -> f64 {
        if n <= self.gpus_per_node {
            self.nvlink_bw
        } else {
            self.ib_bw
        }
    }

    /// Ring all-reduce time for `bytes` over `n` GPUs (alpha-beta model).
    pub fn allreduce_secs(&self, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2.0 * (n as f64 - 1.0);
        steps * self.latency + (2.0 * (n as f64 - 1.0) / n as f64) * bytes / self.link_bw(n)
    }

    /// Ring all-gather of `bytes` total (each rank ends with everything).
    pub fn allgather_secs(&self, bytes: f64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        (n as f64 - 1.0) * self.latency
            + ((n as f64 - 1.0) / n as f64) * bytes / self.link_bw(n)
    }

    pub fn dollars(&self, secs: f64) -> f64 {
        self.world() as f64 * self.gpu.dollars_per_hour * secs / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_sanity() {
        assert!(a100_80g().mem_bw > a100_40g().mem_bw);
        assert_eq!(a100_80g().peak_flops, a100_40g().peak_flops);
        assert!(v100_32g().peak_flops < a100_40g().peak_flops);
    }

    #[test]
    fn allreduce_scales_with_bytes_and_world() {
        let c = Cluster::dgx(a100_40g(), 1);
        let t1 = c.allreduce_secs(1e9, 8);
        let t2 = c.allreduce_secs(2e9, 8);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
        assert_eq!(c.allreduce_secs(1e9, 1), 0.0);
    }

    #[test]
    fn cross_node_collectives_slower() {
        let c1 = Cluster::dgx(a100_80g(), 1);
        let c8 = Cluster::dgx(a100_80g(), 8);
        // same total bytes, more GPUs, but IB-bound
        assert!(c8.allreduce_secs(1e9, 64) > c1.allreduce_secs(1e9, 8));
    }

    #[test]
    fn cost_arithmetic() {
        let c = Cluster::dgx(a100_80g(), 1);
        // 8 GPUs * $4.02 * 1h
        assert!((c.dollars(3600.0) - 8.0 * 4.02).abs() < 1e-9);
    }
}
