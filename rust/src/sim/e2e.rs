//! End-to-end pipeline time/cost model: Tables 1, 2, 4, 5, 6.
//!
//! Step 1 (SFT) and Step 2 (RM) are ordinary fine-tuning — compute-bound
//! passes over their datasets with ZeRO collectives. Step 3 composes the
//! per-iteration model from [`super::step3`] over one epoch of the paper's
//! recipe.

use crate::baselines::SystemModel;
use crate::config::ModelConfig;
use crate::sim::gpu::Cluster;
use crate::sim::step3::{simulate_step3, Recipe, Step3Breakdown};
use crate::zero::MemoryModel;

/// Dataset sizes for steps 1/2 (tokens), calibrated to the paper's Table 4
/// breakdown for OPT-13B on 8x A100-40G (2.5h / 0.25h / 10.8h).
#[derive(Debug, Clone)]
pub struct PipelineDatasets {
    pub sft_tokens: u64,
    pub sft_epochs: u64,
    pub rm_tokens: u64,
    pub rm_epochs: u64,
}

impl Default for PipelineDatasets {
    fn default() -> Self {
        // DeepSpeed-Chat's curated blend: Dahoas/rm-static etc. — ~80M
        // tokens of SFT data (~2 epochs effective) and ~50M pair tokens.
        PipelineDatasets {
            sft_tokens: 80_000_000,
            sft_epochs: 2,
            rm_tokens: 50_000_000,
            rm_epochs: 1,
        }
    }
}

impl PipelineDatasets {
    /// The paper's §2.2 "coffee-break" configuration (Table 6): a single
    /// small dataset so a 1.3B model trains on one commodity GPU in ~2h.
    pub fn single_dataset() -> Self {
        PipelineDatasets {
            sft_tokens: 8_000_000,
            sft_epochs: 1,
            rm_tokens: 2_500_000,
            rm_epochs: 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct E2eReport {
    pub step1_secs: f64,
    pub step2_secs: f64,
    pub step3_secs: f64,
    pub step3: Step3Breakdown,
    pub dollars: f64,
}

impl E2eReport {
    pub fn total_secs(&self) -> f64 {
        self.step1_secs + self.step2_secs + self.step3_secs
    }
}

/// Plain fine-tuning time for `tokens` tokens of model `cfg` (steps 1/2).
pub fn finetune_secs(
    sys: &SystemModel,
    cfg: &ModelConfig,
    cluster: &Cluster,
    tokens: u64,
    seq: u64,
) -> Option<f64> {
    let world = cluster.world();
    let mm = MemoryModel::new(sys.stage, world).with_offload(sys.offload);
    let budget = cluster.gpu.mem_bytes - 2.0 * crate::sim::gpu::GIB;
    let mb = mm.max_microbatch(cfg, seq as usize, budget)?;
    let size_f = cfg.n_params() as f64 / (cfg.n_params() as f64 + 2.0e9);
    let eff = sys.train_eff * (mb as f64 / (mb as f64 + 4.0)) * size_f;
    let flops = cfg.fwd_bwd_flops(tokens, seq) as f64;
    let compute = flops / world as f64 / (cluster.gpu.peak_flops * eff);
    // one optimizer sync per global batch of (mb * world) sequences
    let steps = (tokens / seq).div_ceil(mb * world as u64);
    let comm = steps as f64
        * if sys.stage.params_sharded() {
            3.0 * cluster.allgather_secs(cfg.n_params() as f64 * 2.0, world)
        } else {
            cluster.allreduce_secs(cfg.n_params() as f64 * 2.0, world)
        };
    Some(compute + comm)
}

/// Full three-step pipeline for (actor, critic) on a cluster.
pub fn simulate_e2e(
    sys: &SystemModel,
    actor: &ModelConfig,
    critic: &ModelConfig,
    cluster: &Cluster,
    recipe: &Recipe,
    data: &PipelineDatasets,
) -> Option<E2eReport> {
    let step1_secs = finetune_secs(
        sys,
        actor,
        cluster,
        data.sft_tokens * data.sft_epochs,
        recipe.seq_len(),
    )?;
    // RM training runs 2 forward+backward (chosen & rejected): 2x tokens.
    let step2_secs = finetune_secs(
        sys,
        critic,
        cluster,
        2 * data.rm_tokens * data.rm_epochs,
        recipe.seq_len(),
    )?;
    let step3 = simulate_step3(sys, actor, critic, cluster, recipe)?;
    let step3_secs = step3.iter_secs() * recipe.steps_per_epoch() as f64;
    let total = step1_secs + step2_secs + step3_secs;
    Some(E2eReport {
        step1_secs,
        step2_secs,
        step3_secs,
        step3,
        dollars: cluster.dollars(total),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::ds_he;
    use crate::config::model;
    use crate::sim::gpu::{a100_40g, a100_80g};

    #[test]
    fn table1_shape_13b_single_node() {
        // Paper Table 1: OPT-13B step-3 on 8x A100-80G = 9h; on 40G = 10.8h.
        let a = model("opt-13b");
        let c = model("opt-350m");
        let r = Recipe::default();
        let d = PipelineDatasets::default();
        let e80 =
            simulate_e2e(&ds_he(), &a, &c, &Cluster::dgx(a100_80g(), 1), &r, &d).unwrap();
        let hours80 = e80.step3_secs / 3600.0;
        assert!(
            (3.0..27.0).contains(&hours80),
            "13B step3 on 8xA100-80G: {hours80}h (paper: 9h)"
        );
        let e40 =
            simulate_e2e(&ds_he(), &a, &c, &Cluster::dgx(a100_40g(), 1), &r, &d).unwrap();
        assert!(
            e40.step3_secs > e80.step3_secs,
            "40G must be slower than 80G"
        );
    }

    #[test]
    fn table1_ordering_by_model_size() {
        let c = model("opt-350m");
        let r = Recipe::default();
        let d = PipelineDatasets::default();
        let cluster = Cluster::dgx(a100_80g(), 1);
        let mut last = 0.0;
        for name in ["opt-6.7b", "opt-13b", "opt-30b", "opt-66b"] {
            let e = simulate_e2e(&ds_he(), &model(name), &c, &cluster, &r, &d).unwrap();
            assert!(e.total_secs() > last, "{name} not slower than predecessor");
            last = e.total_secs();
        }
    }

    #[test]
    fn table4_shape_step_breakdown() {
        // Paper Table 4 (13B on 8x A100-40G): 2.5h / 0.25h / 10.8h — step 3
        // dominates, step 2 is the cheapest.
        let a = model("opt-13b");
        let c = model("opt-350m");
        let e = simulate_e2e(
            &ds_he(),
            &a,
            &c,
            &Cluster::dgx(a100_40g(), 1),
            &Recipe::default(),
            &PipelineDatasets::default(),
        )
        .unwrap();
        assert!(e.step3_secs > e.step1_secs);
        assert!(e.step1_secs > e.step2_secs);
        let ratio = e.step3_secs / e.total_secs();
        assert!((0.5..0.98).contains(&ratio), "step3 share {ratio}");
    }

    #[test]
    fn multi_node_faster_than_single_node_for_66b() {
        let a = model("opt-66b");
        let c = model("opt-350m");
        let r = Recipe::default();
        let d = PipelineDatasets::default();
        let e1 = simulate_e2e(&ds_he(), &a, &c, &Cluster::dgx(a100_80g(), 1), &r, &d);
        let e8 = simulate_e2e(&ds_he(), &a, &c, &Cluster::dgx(a100_80g(), 8), &r, &d).unwrap();
        if let Some(e1) = e1 {
            assert!(e8.total_secs() < e1.total_secs());
        }
        // Paper Table 5: 66B total ~9h on 64 GPUs; assert same order of magnitude.
        let hours = e8.total_secs() / 3600.0;
        assert!((2.0..40.0).contains(&hours), "66B on 64 GPUs: {hours}h");
    }

    #[test]
    fn cost_scales_with_gpu_count_and_time() {
        let a = model("opt-13b");
        let c = model("opt-350m");
        let r = Recipe::default();
        let d = PipelineDatasets::default();
        let e = simulate_e2e(&ds_he(), &a, &c, &Cluster::dgx(a100_80g(), 1), &r, &d).unwrap();
        let expect = 8.0 * 4.02 * e.total_secs() / 3600.0;
        assert!((e.dollars - expect).abs() < 1e-6);
    }
}
