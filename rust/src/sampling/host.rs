//! Host full-row sampling: the artifact returns raw `[b, vocab]` logits and
//! every filter runs in rust per token. This is the reference backend — the
//! only one that can honor a repetition penalty (the penalty may promote
//! tokens from outside any device candidate set) — and the fallback for
//! artifact sets that predate the `_sampled` family.

use anyhow::Result;

use super::{argmax, RowRef, SamplerConfig, SamplingBackend, TrafficClass};
use crate::util::rng::Rng;

/// The full-row sampling machine. Ordering follows the HF convention the
/// paper's examples rely on: repetition penalty → temperature → top-k →
/// top-p → categorical.
pub struct Sampler {
    pub cfg: SamplerConfig,
    /// Logits rows with zero finite entries survived by falling back to
    /// token 0 instead of panicking (warned once, counted here) — a
    /// diverged model or corrupt row must degrade a completion, not kill
    /// the serve loop.
    pub degenerate_rows: u64,
    rng: Rng,
    scratch: Vec<(f32, usize)>,
    /// Reusable working copy of one logits row: `sample` is called b×gen_len
    /// times per generate, and must not allocate in that loop.
    row: Vec<f32>,
}

impl Sampler {
    pub fn new(cfg: SamplerConfig, seed: u64) -> Self {
        Sampler {
            cfg,
            degenerate_rows: 0,
            rng: Rng::new(seed),
            scratch: Vec::new(),
            row: Vec::new(),
        }
    }

    /// Sample one token id from a logits row. `history` drives the
    /// repetition penalty (pass `&[]` to disable). Randomness comes from
    /// the sampler's own seeded stream; see [`Sampler::sample_with`] for
    /// the per-request-stream variant.
    pub fn sample(&mut self, logits: &[f32], history: &[i32]) -> i32 {
        // Route through the stream path with the internal rng (cloned out
        // and written back so the stream advances exactly as before).
        let mut rng = self.rng.clone();
        let tok = self.sample_with(logits, history, &mut rng);
        self.rng = rng;
        tok
    }

    /// [`Sampler::sample`] with the categorical draw taken from an explicit
    /// `rng` stream — the rollout path hands each request its own derived
    /// stream so sampling stays reproducible under admission-order
    /// nondeterminism. Filters and scratch reuse are identical to `sample`.
    pub fn sample_with(&mut self, logits: &[f32], history: &[i32], rng: &mut Rng) -> i32 {
        debug_assert!(!logits.is_empty());
        if !logits.iter().any(|x| x.is_finite()) {
            // Degenerate row (all NaN/±inf): the candidate set would be
            // empty, which used to panic inside top-p. Fall back to token
            // 0 so the request degrades instead of crashing the batch.
            self.degenerate_rows += 1;
            if self.degenerate_rows == 1 {
                eprintln!(
                    "[sampler] warning: logits row with zero finite entries — falling back \
                     to token 0 (further occurrences counted in degenerate_rows)"
                );
            }
            return 0;
        }
        if self.cfg.greedy && self.cfg.repetition_penalty == 1.0 {
            return argmax(logits) as i32;
        }
        // Take the scratch row out of self so the filter passes (which also
        // borrow self mutably) can operate on it; put it back when done.
        let mut l = std::mem::take(&mut self.row);
        l.clear();
        l.extend_from_slice(logits);
        self.apply_repetition_penalty(&mut l, history);
        let tok = if self.cfg.greedy {
            argmax(&l) as i32
        } else {
            let t = self.cfg.temperature.max(1e-4);
            for x in l.iter_mut() {
                *x /= t;
            }
            self.filter_top_k(&mut l);
            self.filter_top_p(&mut l);
            Self::categorical(&l, rng)
        };
        self.row = l;
        tok
    }

    fn apply_repetition_penalty(&self, l: &mut [f32], history: &[i32]) {
        let p = self.cfg.repetition_penalty;
        if p == 1.0 {
            return;
        }
        for &tok in history {
            let x = &mut l[tok as usize];
            // HF semantics: shrink positive logits, amplify negative ones.
            *x = if *x > 0.0 { *x / p } else { *x * p };
        }
    }

    fn filter_top_k(&mut self, l: &mut [f32]) {
        let k = self.cfg.top_k;
        if k == 0 || k >= l.len() {
            return;
        }
        self.scratch.clear();
        self.scratch.extend(l.iter().copied().zip(0..));
        // Partial selection: kth largest is the cutoff. total_cmp, not
        // partial_cmp: a stray NaN must not panic the comparator (it sorts
        // above +inf and the finite-filtering top-p pass drops it).
        self.scratch
            .select_nth_unstable_by(k - 1, |a, b| b.0.total_cmp(&a.0));
        let cutoff = self.scratch[k - 1].0;
        let mut kept = 0usize;
        for x in l.iter_mut() {
            if *x >= cutoff && kept < k {
                kept += 1;
            } else {
                *x = f32::NEG_INFINITY;
            }
        }
    }

    fn filter_top_p(&mut self, l: &mut [f32]) {
        let p = self.cfg.top_p;
        if p >= 1.0 {
            return;
        }
        self.scratch.clear();
        self.scratch
            .extend(l.iter().copied().zip(0..).filter(|(x, _)| x.is_finite()));
        if self.scratch.is_empty() {
            // No finite candidate survived the earlier filters; leave the
            // row untouched and let the categorical fallback handle it
            // (the all-degenerate case was already caught in sample_with).
            return;
        }
        self.scratch.sort_unstable_by(|a, b| b.0.total_cmp(&a.0));
        // Softmax over the sorted candidates, keep the smallest prefix with
        // cumulative mass >= p (always at least one).
        let max = self.scratch[0].0;
        let z: f32 = self.scratch.iter().map(|(x, _)| (x - max).exp()).sum();
        let mut cum = 0.0f32;
        let mut cut = self.scratch.len();
        for (i, (x, _)) in self.scratch.iter().enumerate() {
            cum += (x - max).exp() / z;
            if cum >= p {
                cut = i + 1;
                break;
            }
        }
        for (_, idx) in &self.scratch[cut..] {
            l[*idx] = f32::NEG_INFINITY;
        }
    }

    fn categorical(l: &[f32], rng: &mut Rng) -> i32 {
        let max = l.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = l.iter().map(|x| (x - max).exp()).sum();
        let u = rng.f32() * z;
        let mut cum = 0.0f32;
        for (i, x) in l.iter().enumerate() {
            cum += (x - max).exp();
            if cum >= u {
                return i as i32;
            }
        }
        argmax(l) as i32 // numerical fallback
    }
}

/// [`SamplingBackend`] over the full-row [`Sampler`]: O(b·vocab) fetched
/// per step, every filter available. Bit-identical to the pre-refactor
/// monolithic path (pinned by the PR 1 generate golden and the PR 2
/// serving golden).
pub struct HostFullRow {
    pub sampler: Sampler,
}

impl HostFullRow {
    pub fn new(cfg: SamplerConfig, seed: u64) -> Self {
        HostFullRow { sampler: Sampler::new(cfg, seed) }
    }

    pub fn from_sampler(sampler: Sampler) -> Self {
        HostFullRow { sampler }
    }
}

impl SamplingBackend for HostFullRow {
    fn traffic(&self) -> TrafficClass {
        TrafficClass::FullRow
    }

    fn sample(&mut self, row: RowRef<'_>, history: &[i32]) -> Result<i32> {
        match row {
            RowRef::Logits(l) => Ok(self.sampler.sample(l, history)),
            other => Err(super::wrong_row("HostFullRow", &other)),
        }
    }

    fn sample_stream(&mut self, row: RowRef<'_>, history: &[i32], rng: &mut Rng) -> Result<i32> {
        match row {
            RowRef::Logits(l) => Ok(self.sampler.sample_with(l, history, rng)),
            other => Err(super::wrong_row("HostFullRow", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler(cfg: SamplerConfig) -> Sampler {
        Sampler::new(cfg, 42)
    }

    #[test]
    fn greedy_takes_argmax() {
        let mut s = sampler(SamplerConfig { greedy: true, ..Default::default() });
        assert_eq!(s.sample(&[0.1, 3.0, -1.0, 2.9], &[]), 1);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut s = sampler(SamplerConfig { top_k: 2, ..Default::default() });
        let logits = vec![5.0, 4.9, -10.0, -10.0, -10.0];
        for _ in 0..200 {
            let t = s.sample(&logits, &[]);
            assert!(t == 0 || t == 1, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        let mut s = sampler(SamplerConfig { top_p: 0.5, ..Default::default() });
        // p(0) ≈ 0.84 alone exceeds 0.5 -> only token 0 may be drawn.
        let logits = vec![3.0, 1.0, 0.0, -1.0];
        for _ in 0..200 {
            assert_eq!(s.sample(&logits, &[]), 0);
        }
    }

    #[test]
    fn temperature_zero_approaches_greedy() {
        let mut s = sampler(SamplerConfig { temperature: 1e-6, ..Default::default() });
        for _ in 0..50 {
            assert_eq!(s.sample(&[0.0, 0.5, 0.2], &[]), 1);
        }
    }

    #[test]
    fn repetition_penalty_discourages_history() {
        let logits = vec![2.0, 2.0];
        let mut s = sampler(SamplerConfig {
            greedy: true,
            repetition_penalty: 2.0,
            ..Default::default()
        });
        // token 0 in history -> its logit halves -> argmax flips to 1
        assert_eq!(s.sample(&logits, &[0]), 1);
    }

    #[test]
    fn categorical_matches_distribution() {
        let mut s = sampler(SamplerConfig::default());
        let logits = vec![1.0f32.ln(), 3.0f32.ln()]; // p = [0.25, 0.75]
        let n = 20_000;
        let mut ones = 0;
        for _ in 0..n {
            if s.sample(&logits, &[]) == 1 {
                ones += 1;
            }
        }
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn scratch_reuse_does_not_leak_state_across_rows() {
        // The reused row buffer must be truncated to each call's logits
        // exactly: sampling a small row right after a much larger one gives
        // the same answer as a fresh sampler. Greedy + repetition penalty
        // exercises the scratch path without consuming rng state.
        let cfg = SamplerConfig {
            greedy: true,
            repetition_penalty: 1.5,
            ..Default::default()
        };
        let big: Vec<f32> = (0..64).map(|i| ((i * 37) % 19) as f32 / 3.0).collect();
        let small = vec![0.1f32, 2.0, -1.0, 0.5];
        let mut reused = sampler(cfg.clone());
        let _ = reused.sample(&big, &[5, 9]);
        let mut fresh = sampler(cfg);
        assert_eq!(reused.sample(&small, &[1]), fresh.sample(&small, &[1]));
    }

    #[test]
    fn scratch_reuse_is_deterministic_across_mixed_rows() {
        // Two identically seeded samplers fed the same mixed-size stream
        // must agree call for call (sampling results unchanged by reuse).
        let cfg = SamplerConfig {
            temperature: 0.8,
            top_k: 5,
            top_p: 0.9,
            repetition_penalty: 1.2,
            ..Default::default()
        };
        let rows: Vec<Vec<f32>> = vec![
            (0..32).map(|i| (i as f32 * 0.37).sin() * 3.0).collect(),
            (0..8).map(|i| (i as f32 * 1.1).cos()).collect(),
            (0..128).map(|i| ((i * 13) % 31) as f32 / 7.0).collect(),
        ];
        let mut a = Sampler::new(cfg.clone(), 99);
        let mut b = Sampler::new(cfg, 99);
        for _ in 0..50 {
            for row in &rows {
                assert_eq!(a.sample(row, &[0, 1]), b.sample(row, &[0, 1]));
            }
        }
    }

    #[test]
    fn degenerate_rows_fall_back_to_token_zero_and_count() {
        // All-NaN and all-(-inf) rows used to panic inside top-p (empty
        // candidate set); they must now degrade to token 0 with a count.
        let mut s = sampler(SamplerConfig { temperature: 0.8, top_p: 0.9, ..Default::default() });
        let nan_row = vec![f32::NAN; 8];
        let ninf_row = vec![f32::NEG_INFINITY; 8];
        assert_eq!(s.sample(&nan_row, &[]), 0);
        assert_eq!(s.sample(&ninf_row, &[]), 0);
        assert_eq!(s.degenerate_rows, 2);
        // The greedy path counts too.
        let mut g = sampler(SamplerConfig { greedy: true, ..Default::default() });
        assert_eq!(g.sample(&nan_row, &[2]), 0);
        assert_eq!(g.degenerate_rows, 1);
        // A healthy row afterwards samples normally (scratch state intact).
        let t = s.sample(&[0.0, 5.0, 0.0, 0.0], &[]);
        assert_eq!(t, 1);
        assert_eq!(s.degenerate_rows, 2, "healthy rows are not counted");
    }

    #[test]
    fn partially_nan_rows_do_not_panic() {
        // A stray NaN among finite logits exercises the total_cmp
        // comparators in top-k/top-p; the draw must come from the finite
        // support.
        let mut s = sampler(SamplerConfig {
            temperature: 0.7,
            top_k: 3,
            top_p: 0.9,
            ..Default::default()
        });
        let row = vec![1.0, f32::NAN, 3.0, f32::NAN, 2.0, f32::NEG_INFINITY];
        for _ in 0..100 {
            let t = s.sample(&row, &[]);
            assert!([0, 2, 4].contains(&t), "sampled {t} from non-finite support");
        }
        assert_eq!(s.degenerate_rows, 0);
    }

    #[test]
    fn backend_samples_logits_rows_and_rejects_device_rows() {
        let mut b = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0);
        assert_eq!(b.traffic(), TrafficClass::FullRow);
        assert_eq!(b.sample(RowRef::Logits(&[0.0, 2.0, 1.0]), &[]).unwrap(), 1);
        assert!(b.sample(RowRef::Id(3), &[]).is_err());
        assert!(b.sample(RowRef::TopK { vals: &[1.0], ids: &[0] }, &[]).is_err());
    }

    #[test]
    fn explicit_stream_reproduces_internal_stream() {
        // sample() is sample_with() over the internal rng: a backend seeded
        // with s and an external Rng::new(s) stream must produce identical
        // tokens call for call — the contract the rollout path's derived
        // per-request streams rely on.
        let cfg = SamplerConfig {
            temperature: 0.8,
            top_k: 6,
            top_p: 0.9,
            ..Default::default()
        };
        let mut internal = HostFullRow::new(cfg.clone(), 13);
        let mut external = HostFullRow::new(cfg, 999); // its own rng never consulted
        let mut stream = crate::util::rng::Rng::new(13);
        let rows: Vec<Vec<f32>> = (0..30)
            .map(|r| (0..24).map(|i| ((i * 5 + r * 3) % 17) as f32 / 4.0).collect())
            .collect();
        for row in &rows {
            assert_eq!(
                internal.sample(RowRef::Logits(row), &[]).unwrap(),
                external.sample_stream(RowRef::Logits(row), &[], &mut stream).unwrap()
            );
        }
    }

    #[test]
    fn stream_isolation_across_interleaved_requests() {
        // Two per-request streams interleaved in any order give each
        // request the same tokens it would get alone — admission-order
        // independence in miniature.
        let cfg = SamplerConfig { temperature: 1.0, ..Default::default() };
        let row: Vec<f32> = (0..16).map(|i| (i as f32 * 0.7).sin()).collect();
        let solo = |seed: u64| -> Vec<i32> {
            let mut b = HostFullRow::new(cfg.clone(), 0);
            let mut rng = crate::util::rng::Rng::new(seed);
            (0..10)
                .map(|_| b.sample_stream(RowRef::Logits(&row), &[], &mut rng).unwrap())
                .collect()
        };
        let (a_solo, b_solo) = (solo(1), solo(2));
        let mut backend = HostFullRow::new(cfg, 0);
        let mut ra = crate::util::rng::Rng::new(1);
        let mut rb = crate::util::rng::Rng::new(2);
        let mut a_mix = Vec::new();
        let mut b_mix = Vec::new();
        for i in 0..10 {
            // Alternate which request samples first each step.
            if i % 2 == 0 {
                a_mix.push(backend.sample_stream(RowRef::Logits(&row), &[], &mut ra).unwrap());
                b_mix.push(backend.sample_stream(RowRef::Logits(&row), &[], &mut rb).unwrap());
            } else {
                b_mix.push(backend.sample_stream(RowRef::Logits(&row), &[], &mut rb).unwrap());
                a_mix.push(backend.sample_stream(RowRef::Logits(&row), &[], &mut ra).unwrap());
            }
        }
        assert_eq!(a_mix, a_solo);
        assert_eq!(b_mix, b_solo);
    }

    #[test]
    fn backend_matches_bare_sampler_stream() {
        // HostFullRow is a transparent wrapper: same seed, same rows, same
        // token stream as the bare Sampler (the refactor cannot perturb the
        // PR 1 / PR 2 goldens).
        let cfg = SamplerConfig {
            temperature: 0.9,
            top_k: 8,
            top_p: 0.95,
            repetition_penalty: 1.1,
            ..Default::default()
        };
        let mut bare = Sampler::new(cfg.clone(), 7);
        let mut wrapped = HostFullRow::new(cfg, 7);
        let rows: Vec<Vec<f32>> = (0..20)
            .map(|r| (0..32).map(|i| ((i * 7 + r * 13) % 23) as f32 / 5.0).collect())
            .collect();
        for row in &rows {
            assert_eq!(
                bare.sample(row, &[1, 2]),
                wrapped.sample(RowRef::Logits(row), &[1, 2]).unwrap()
            );
        }
    }
}
