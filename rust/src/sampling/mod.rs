//! Token sampling: the L3 half of the generation hot loop, split across a
//! [`SamplingBackend`] trait so the per-step host↔device traffic is a
//! property of the chosen backend, not of the engine.
//!
//! # Traffic contract (what crosses the PCIe boundary per decode step)
//!
//! | backend                     | artifact family | fetched per step      |
//! |-----------------------------|-----------------|-----------------------|
//! | [`HostFullRow`]             | `decode_*`      | `[b, vocab]` logits   |
//! | [`DeviceTopK`] (greedy)     | `decode_*_sampled` | `[b]` token ids    |
//! | [`DeviceTopK`] (stochastic) | `decode_*_sampled` | `[b, k]` logits+ids|
//! | [`DeviceCategorical`]       | `decode_*_rng`  | `[b]` token ids       |
//!
//! [`HostFullRow`] wraps the original [`Sampler`]: the artifact returns raw
//! logits and everything after that — temperature, repetition penalty,
//! top-k / top-p filtering, categorical draw — happens here in rust, per
//! token (HF filter ordering: repetition penalty → temperature → top-k →
//! top-p). It is the only backend that can honor a repetition penalty,
//! because the penalty may promote tokens from outside any candidate set.
//!
//! [`DeviceTopK`] moves the heavy half of sampling into the AOT artifacts:
//! a fused Pallas tail (`python/compile/kernels/sampling.py`) computes the
//! row argmax and the top-`k` candidates on device, and the host finishes
//! temperature / top-p / the categorical draw over those k candidates with
//! the same seeded [`crate::util::rng::Rng`] — generation stays
//! bit-deterministic for a fixed seed, and EOS/length retirement stays
//! host-side (the scheduler sees every sampled id). Greedy device decoding
//! is bit-identical to [`HostFullRow`] argmax (both tie-break toward the
//! lower token id; pinned by the integration goldens).
//!
//! [`DeviceCategorical`] finishes the ENTIRE draw on device: the `_rng`
//! artifacts carry a counter-based Threefry-2x32 generator keyed by
//! `(request_seed, step)` plus the temperature / top-k / top-p filter
//! ([`SamplingBackend::device_params`]), so stochastic decode fetches `[b]`
//! sampled ids — the same O(b) bytes/step as greedy — and the host-side
//! `sample` is pass-through. Because the stream is a pure function of the
//! request key and its own step counter (not of a shared mutable host RNG),
//! per-request determinism survives continuous-batching admission reorder
//! AND fused N-step chunking for free. The draw support is the device
//! top-`k` candidates, the same truncation contract as [`DeviceTopK`];
//! repetition penalties stay [`HostFullRow`]-only.
//!
//! The engine consumes backends through [`SamplingBackend::traffic`] (which
//! artifact family to execute and which outputs to fetch) and hands results
//! back as a [`SampleOut`]; [`SamplingBackend::sample`] finishes one row.

pub mod device;
pub mod host;

pub use device::{seed_words, threefry2x32, DeviceCategorical, DeviceTopK};
pub use host::{HostFullRow, Sampler};

use anyhow::{bail, Result};

#[derive(Debug, Clone)]
pub struct SamplerConfig {
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub repetition_penalty: f32,
    /// Greedy decoding (argmax) if true — used by eval and the chat example.
    pub greedy: bool,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            greedy: false,
        }
    }
}

/// Which artifact family the engine must execute for a backend, and which
/// outputs it must fetch — the per-step host-traffic class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Plain artifacts; fetch the full `[b, vocab]` logits rows.
    FullRow,
    /// `_sampled` artifacts; fetch the `[b]` device-argmax ids only.
    DeviceIds,
    /// `_sampled` artifacts; fetch the `[b, k]` candidate logits + ids.
    DeviceTopK,
    /// `_rng` artifacts (device counter-RNG categorical draw); fetch the
    /// `[b]` device-sampled ids only.
    DeviceCategorical,
}

/// What one generation step handed back to the host — the engine fetches
/// exactly the variant the backend's [`TrafficClass`] asks for.
#[derive(Debug, Clone)]
pub enum SampleOut {
    /// Full logits rows, row-major `[b, vocab]`.
    Logits { data: Vec<f32>, vocab: usize },
    /// Device-argmax token ids `[b]` (greedy decoding).
    Ids(Vec<i32>),
    /// Device top-k candidates, row-major `[b, k]`, sorted by descending
    /// logit within each row.
    TopK { vals: Vec<f32>, ids: Vec<i32>, k: usize },
}

impl SampleOut {
    pub fn n_rows(&self) -> usize {
        match self {
            SampleOut::Logits { data, vocab } => data.len() / (*vocab).max(1),
            SampleOut::Ids(ids) => ids.len(),
            SampleOut::TopK { ids, k, .. } => ids.len() / (*k).max(1),
        }
    }

    /// Borrow one row (slot) of the step's output.
    pub fn row(&self, i: usize) -> RowRef<'_> {
        match self {
            SampleOut::Logits { data, vocab } => {
                let v = *vocab;
                RowRef::Logits(&data[i * v..(i + 1) * v])
            }
            SampleOut::Ids(ids) => RowRef::Id(ids[i]),
            SampleOut::TopK { vals, ids, k } => {
                let k = *k;
                RowRef::TopK { vals: &vals[i * k..(i + 1) * k], ids: &ids[i * k..(i + 1) * k] }
            }
        }
    }
}

/// One borrowed row of a [`SampleOut`] — what a backend finishes into a
/// token id.
#[derive(Debug, Clone, Copy)]
pub enum RowRef<'a> {
    Logits(&'a [f32]),
    Id(i32),
    TopK { vals: &'a [f32], ids: &'a [i32] },
}

/// Owned per-slot pending row — the serving scheduler keeps one per live
/// slot between the fused decode call and the next tick's sample.
#[derive(Debug, Clone)]
pub enum PendingRow {
    Logits(Vec<f32>),
    Id(i32),
    TopK { vals: Vec<f32>, ids: Vec<i32> },
}

impl PendingRow {
    pub fn from_row(r: RowRef<'_>) -> PendingRow {
        match r {
            RowRef::Logits(l) => PendingRow::Logits(l.to_vec()),
            RowRef::Id(t) => PendingRow::Id(t),
            RowRef::TopK { vals, ids } => {
                PendingRow::TopK { vals: vals.to_vec(), ids: ids.to_vec() }
            }
        }
    }

    pub fn as_row(&self) -> RowRef<'_> {
        match self {
            PendingRow::Logits(l) => RowRef::Logits(l),
            PendingRow::Id(t) => RowRef::Id(*t),
            PendingRow::TopK { vals, ids } => RowRef::TopK { vals, ids },
        }
    }

    /// Overwrite from a fresh row, reusing the existing allocations when
    /// the variant matches (the per-step serving path must not allocate).
    pub fn copy_from(&mut self, r: RowRef<'_>) {
        match (&mut *self, r) {
            (PendingRow::Logits(buf), RowRef::Logits(src)) => {
                buf.clear();
                buf.extend_from_slice(src);
            }
            (PendingRow::Id(t), RowRef::Id(s)) => *t = s,
            (PendingRow::TopK { vals, ids }, RowRef::TopK { vals: sv, ids: si }) => {
                vals.clear();
                vals.extend_from_slice(sv);
                ids.clear();
                ids.extend_from_slice(si);
            }
            (slot, r) => *slot = PendingRow::from_row(r),
        }
    }
}

/// A sampling strategy plus its host-side finishing state (RNG, scratch).
///
/// The engine asks [`SamplingBackend::traffic`] which artifact family to
/// run and hands each fetched row back through [`SamplingBackend::sample`];
/// `history` is the sequence so far (repetition penalty — only meaningful
/// for backends whose construction admits one).
///
/// [`SamplingBackend::sample`] consumes the backend's own seeded RNG — one
/// global stream, which is reproducible only when every call happens in a
/// fixed order. Continuous-batching rollout retires and admits sequences at
/// data-dependent steps, so the interleaving of sample calls across
/// requests is NOT fixed; [`SamplingBackend::sample_stream`] exists for
/// that caller: the randomness comes from an explicit per-request
/// [`Rng`] stream (derived from seed ⊕ request id by `crate::rollout`), so
/// each request's token sequence is a pure function of its own stream no
/// matter which other requests share the batch. Backends that consume no
/// randomness (greedy) inherit the default, which forwards to `sample`.
pub trait SamplingBackend {
    fn traffic(&self) -> TrafficClass;

    fn sample(&mut self, row: RowRef<'_>, history: &[i32]) -> Result<i32>;

    /// Finish one row drawing randomness from the caller's `rng` stream
    /// instead of the backend's global one (scratch buffers and filter
    /// config are still the backend's). Stochastic backends must override
    /// this to honor `rng`; the default forwards to
    /// [`SamplingBackend::sample`] and is only correct for backends whose
    /// `sample` consumes no randomness.
    fn sample_stream(
        &mut self,
        row: RowRef<'_>,
        history: &[i32],
        rng: &mut crate::util::rng::Rng,
    ) -> Result<i32> {
        let _ = rng;
        self.sample(row, history)
    }

    /// `[temperature, top_k, top_p]` to upload as the `_rng` artifacts'
    /// `sparams` input. `Some` only for backends whose draw runs on device
    /// ([`TrafficClass::DeviceCategorical`]); the engine refuses to run the
    /// `_rng` family for a backend that returns `None`.
    fn device_params(&self) -> Option<[f32; 3]> {
        None
    }
}

/// First-max argmax (ties toward the lower index — the convention shared
/// with the device sampling tail, which is what makes device-greedy
/// generation bit-identical to the host path).
pub fn argmax(l: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in l.iter().enumerate() {
        if *x > l[best] {
            best = i;
        }
    }
    best
}

/// Softmax probabilities (used by tests and the chat example's display).
pub fn softmax(l: &[f32]) -> Vec<f32> {
    let max = l.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = l.iter().map(|x| (x - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|e| e / z).collect()
}

/// Validate a backend/row pairing mismatch into a actionable error.
pub(crate) fn wrong_row(backend: &str, row: &RowRef<'_>) -> anyhow::Error {
    let got = match row {
        RowRef::Logits(_) => "a full logits row",
        RowRef::Id(_) => "a device-argmax id",
        RowRef::TopK { .. } => "device top-k candidates",
    };
    anyhow::anyhow!("{backend} backend was fed {got} (engine ran the wrong artifact family)")
}

/// Convenience: bail unless the candidate row is non-empty.
pub(crate) fn check_nonempty(vals: &[f32], ids: &[i32]) -> Result<()> {
    if vals.is_empty() || vals.len() != ids.len() {
        bail!("malformed top-k candidate row: {} vals / {} ids", vals.len(), ids.len());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_out_rows_and_refs() {
        let out = SampleOut::Logits { data: vec![0.0, 1.0, 2.0, 3.0], vocab: 2 };
        assert_eq!(out.n_rows(), 2);
        match out.row(1) {
            RowRef::Logits(l) => assert_eq!(l, &[2.0, 3.0]),
            _ => panic!("wrong row kind"),
        }
        let out = SampleOut::Ids(vec![5, 6]);
        assert_eq!(out.n_rows(), 2);
        match out.row(0) {
            RowRef::Id(t) => assert_eq!(t, 5),
            _ => panic!("wrong row kind"),
        }
        let out = SampleOut::TopK { vals: vec![1.0, 0.5, 2.0, 1.5], ids: vec![3, 9, 4, 8], k: 2 };
        assert_eq!(out.n_rows(), 2);
        match out.row(1) {
            RowRef::TopK { vals, ids } => {
                assert_eq!(vals, &[2.0, 1.5]);
                assert_eq!(ids, &[4, 8]);
            }
            _ => panic!("wrong row kind"),
        }
    }

    #[test]
    fn pending_row_copy_reuses_and_switches_variants() {
        let mut p = PendingRow::Logits(vec![1.0, 2.0]);
        p.copy_from(RowRef::Logits(&[3.0, 4.0, 5.0]));
        match &p {
            PendingRow::Logits(l) => assert_eq!(l.as_slice(), &[3.0, 4.0, 5.0]),
            _ => panic!(),
        }
        // Variant switch (backend change between serving sessions) works too.
        p.copy_from(RowRef::Id(7));
        match p.as_row() {
            RowRef::Id(t) => assert_eq!(t, 7),
            _ => panic!(),
        }
        p.copy_from(RowRef::TopK { vals: &[0.5], ids: &[2] });
        match p.as_row() {
            RowRef::TopK { vals, ids } => {
                assert_eq!(vals, &[0.5]);
                assert_eq!(ids, &[2]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }
}
