//! Device top-k sampling: the heavy half of the sampling tail (row argmax
//! and top-k selection over the vocabulary) runs inside the `_sampled` AOT
//! artifacts; the host finishes temperature, top-p, and the categorical
//! draw over the k fetched candidates with the seeded [`Rng`], so
//! generation stays bit-deterministic and EOS/length retirement stays
//! host-side. Per-step fetch: `[b]` ids (greedy) or `[b, k]` logits+ids
//! (stochastic) instead of the `[b, vocab]` row.

use anyhow::{bail, Result};

use super::{check_nonempty, RowRef, SamplerConfig, SamplingBackend, TrafficClass};
use crate::util::rng::Rng;

/// Device top-k backend. Truncation contract: for stochastic configs the
/// artifact's k candidates ARE the support — with `top_k == 0` (host
/// semantics: unrestricted) the draw is implicitly truncated to the k
/// largest logits, the standard fidelity/traffic trade of device top-k
/// sampling. A config naming a SPECIFIC support wider than k
/// (`top_k > k`) is rejected at construction, as is any repetition
/// penalty (this backend never applies one — `HostFullRow` is the
/// penalized path).
pub struct DeviceTopK {
    pub cfg: SamplerConfig,
    /// Candidate count baked into the `_sampled` artifacts
    /// (`manifest.sample_k`).
    pub k: usize,
    rng: Rng,
    /// Reused working copy of one candidate row (temperature-scaled
    /// logits); the per-token path must not allocate.
    scratch: Vec<f32>,
}

impl DeviceTopK {
    /// Build a device-sampling backend, validating the config against what
    /// k candidates can express — a clear error here instead of a silently
    /// wrong distribution at decode time.
    pub fn new(cfg: SamplerConfig, seed: u64, k: usize, vocab: usize) -> Result<Self> {
        if k == 0 {
            bail!(
                "device sampling unavailable: the artifact set has no sampling tail \
                 (manifest sample_k = 0) — re-run `make artifacts`"
            );
        }
        if cfg.repetition_penalty != 1.0 {
            bail!(
                "DeviceTopK never applies a repetition penalty (requested {}): with \
                 k={k} of {vocab} candidates the penalty could promote tokens from \
                 outside the candidate set, and this backend implements no penalty \
                 path at all — honoring the config silently would be a wrong answer. \
                 Use the HostFullRow backend for penalized sampling",
                cfg.repetition_penalty
            );
        }
        if !cfg.greedy && cfg.top_k > k {
            bail!(
                "DeviceTopK: config asks for top_k {} but the artifacts return only \
                 {k} candidates (manifest sample_k) — lower top_k, or rebuild \
                 artifacts with a larger sample_k",
                cfg.top_k
            );
        }
        Ok(DeviceTopK { cfg, k, rng: Rng::new(seed), scratch: Vec::new() })
    }

    /// Convenience: validate against a manifest's `sample_k` / vocab.
    pub fn for_manifest(
        cfg: SamplerConfig,
        seed: u64,
        m: &crate::runtime::Manifest,
    ) -> Result<Self> {
        Self::new(cfg, seed, m.sample_k, m.actor.vocab)
    }

    /// Host finish over one candidate row (sorted by descending logit):
    /// temperature → config top-k prefix → top-p prefix → categorical.
    /// Mirrors the full-row filter semantics restricted to the candidates;
    /// consumes exactly one uniform draw from `rng` (the backend's own
    /// stream via `sample`, or a per-request rollout stream via
    /// `sample_stream`), like the full-row categorical.
    fn draw_with(&mut self, vals: &[f32], ids: &[i32], rng: &mut Rng) -> Result<i32> {
        check_nonempty(vals, ids)?;
        let take = if self.cfg.top_k == 0 { vals.len() } else { self.cfg.top_k.min(vals.len()) };
        let t = self.cfg.temperature.max(1e-4);
        self.scratch.clear();
        self.scratch.extend(vals[..take].iter().map(|x| x / t));
        // Top-p: smallest prefix of the (already sorted) candidates with
        // cumulative softmax mass >= p — always at least one.
        let keep = if self.cfg.top_p < 1.0 {
            let max = self.scratch[0];
            let z: f32 = self.scratch.iter().map(|x| (x - max).exp()).sum();
            let mut cut = self.scratch.len();
            let mut cum = 0.0f32;
            for (i, x) in self.scratch.iter().enumerate() {
                cum += (x - max).exp() / z;
                if cum >= self.cfg.top_p {
                    cut = i + 1;
                    break;
                }
            }
            cut
        } else {
            self.scratch.len()
        };
        let kept = &self.scratch[..keep];
        let max = kept.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = kept.iter().map(|x| (x - max).exp()).sum();
        let u = rng.f32() * z;
        let mut cum = 0.0f32;
        for (j, x) in kept.iter().enumerate() {
            cum += (x - max).exp();
            if cum >= u {
                return Ok(ids[j]);
            }
        }
        Ok(ids[0]) // numerical fallback (ids sorted: 0 is the argmax)
    }
}

impl SamplingBackend for DeviceTopK {
    fn traffic(&self) -> TrafficClass {
        if self.cfg.greedy {
            TrafficClass::DeviceIds
        } else {
            TrafficClass::DeviceTopK
        }
    }

    fn sample(&mut self, row: RowRef<'_>, history: &[i32]) -> Result<i32> {
        // One copy of the dispatch: route the internal stream through the
        // stream path (cloned out and written back, like Sampler::sample).
        let mut rng = self.rng.clone();
        let tok = self.sample_stream(row, history, &mut rng);
        self.rng = rng;
        tok
    }

    fn sample_stream(&mut self, row: RowRef<'_>, _history: &[i32], rng: &mut Rng) -> Result<i32> {
        match row {
            // Greedy: the device already took the argmax; the id IS the token.
            RowRef::Id(t) => Ok(t),
            RowRef::TopK { vals, ids } => {
                if self.cfg.greedy {
                    // Candidates are sorted descending: first is the argmax.
                    check_nonempty(vals, ids)?;
                    return Ok(ids[0]);
                }
                self.draw_with(vals, ids, rng)
            }
            other @ RowRef::Logits(_) => Err(super::wrong_row("DeviceTopK", &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn greedy_cfg() -> SamplerConfig {
        SamplerConfig { greedy: true, ..Default::default() }
    }

    #[test]
    fn greedy_traffic_is_ids_stochastic_is_topk() {
        let g = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        assert_eq!(g.traffic(), TrafficClass::DeviceIds);
        let s = DeviceTopK::new(SamplerConfig::default(), 0, 8, 256).unwrap();
        assert_eq!(s.traffic(), TrafficClass::DeviceTopK);
    }

    #[test]
    fn greedy_returns_device_id_verbatim() {
        let mut b = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        assert_eq!(b.sample(RowRef::Id(42), &[]).unwrap(), 42);
        // Greedy over a candidate row takes the first (sorted) candidate.
        let t = b
            .sample(RowRef::TopK { vals: &[3.0, 2.0, 1.0], ids: &[9, 5, 7] }, &[])
            .unwrap();
        assert_eq!(t, 9);
    }

    #[test]
    fn rejects_full_logits_rows() {
        let mut b = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        let err = b.sample(RowRef::Logits(&[1.0, 2.0]), &[]).unwrap_err();
        assert!(format!("{err:#}").contains("wrong artifact"));
    }

    #[test]
    fn repetition_penalty_is_a_config_error_not_a_wrong_answer() {
        let cfg = SamplerConfig { repetition_penalty: 1.2, ..Default::default() };
        let err = DeviceTopK::new(cfg.clone(), 0, 8, 256).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("repetition penalty"), "{msg}");
        assert!(msg.contains("HostFullRow"), "{msg}");
        // Rejected even at k == vocab: the backend has no penalty path, so
        // accepting the config would silently sample the wrong distribution.
        assert!(DeviceTopK::new(cfg, 0, 256, 256).is_err());
        // Greedy is no exception (greedy + penalty can flip the argmax).
        let greedy_pen = SamplerConfig {
            greedy: true,
            repetition_penalty: 2.0,
            ..Default::default()
        };
        assert!(DeviceTopK::new(greedy_pen, 0, 8, 256).is_err());
    }

    #[test]
    fn top_k_wider_than_candidates_is_rejected() {
        let cfg = SamplerConfig { top_k: 50, ..Default::default() };
        let err = DeviceTopK::new(cfg, 0, 8, 256).unwrap_err();
        assert!(format!("{err:#}").contains("sample_k"));
    }

    #[test]
    fn missing_sampling_tail_is_actionable() {
        let err = DeviceTopK::new(greedy_cfg(), 0, 0, 256).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn stochastic_draw_matches_candidate_distribution() {
        // Two candidates with p = [0.25, 0.75] after softmax.
        let vals = [1.0f32.ln(), 3.0f32.ln()];
        // Sorted-descending contract: re-order so vals[0] is the max.
        let vals = [vals[1], vals[0]];
        let ids = [11, 22];
        let mut b = DeviceTopK::new(SamplerConfig::default(), 42, 2, 256).unwrap();
        let n = 20_000;
        let mut hi = 0;
        for _ in 0..n {
            match b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap() {
                11 => hi += 1,
                22 => {}
                other => panic!("sampled {other} outside the candidate set"),
            }
        }
        let frac = hi as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn top_p_restricts_candidate_support() {
        // First candidate alone carries ~0.84 mass > 0.5 -> always chosen.
        let vals = [3.0, 1.0, 0.0, -1.0];
        let ids = [4, 5, 6, 7];
        let cfg = SamplerConfig { top_p: 0.5, ..Default::default() };
        let mut b = DeviceTopK::new(cfg, 1, 4, 256).unwrap();
        for _ in 0..200 {
            assert_eq!(b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(), 4);
        }
    }

    #[test]
    fn config_top_k_narrows_candidates() {
        let vals = [5.0, 4.9, -10.0, -10.0];
        let ids = [1, 2, 3, 4];
        let cfg = SamplerConfig { top_k: 2, ..Default::default() };
        let mut b = DeviceTopK::new(cfg, 3, 4, 256).unwrap();
        for _ in 0..200 {
            let t = b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap();
            assert!(t == 1 || t == 2, "sampled {t} outside config top-2");
        }
    }

    #[test]
    fn seeded_draws_are_deterministic() {
        let vals = [2.0, 1.5, 1.0, 0.5];
        let ids = [3, 1, 4, 1];
        let cfg = SamplerConfig { temperature: 0.8, top_p: 0.9, ..Default::default() };
        let mut a = DeviceTopK::new(cfg.clone(), 9, 4, 256).unwrap();
        let mut b = DeviceTopK::new(cfg, 9, 4, 256).unwrap();
        for _ in 0..100 {
            assert_eq!(
                a.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(),
                b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap()
            );
        }
    }

    #[test]
    fn explicit_stream_reproduces_internal_stream() {
        // The rollout contract on the device backend: an external stream
        // seeded like the backend's internal one draws the same tokens.
        let vals = [2.0, 1.5, 1.0, 0.5];
        let ids = [3, 1, 4, 1];
        let cfg = SamplerConfig { temperature: 0.8, top_p: 0.9, ..Default::default() };
        let mut internal = DeviceTopK::new(cfg.clone(), 21, 4, 256).unwrap();
        let mut external = DeviceTopK::new(cfg, 777, 4, 256).unwrap();
        let mut stream = crate::util::rng::Rng::new(21);
        for _ in 0..100 {
            assert_eq!(
                internal.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(),
                external
                    .sample_stream(RowRef::TopK { vals: &vals, ids: &ids }, &[], &mut stream)
                    .unwrap()
            );
        }
    }

    #[test]
    fn malformed_candidate_rows_error() {
        let mut b = DeviceTopK::new(SamplerConfig::default(), 0, 4, 256).unwrap();
        assert!(b.sample(RowRef::TopK { vals: &[], ids: &[] }, &[]).is_err());
        assert!(b.sample(RowRef::TopK { vals: &[1.0], ids: &[1, 2] }, &[]).is_err());
    }
}
