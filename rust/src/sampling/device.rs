//! Device-side sampling backends.
//!
//! [`DeviceTopK`]: the heavy half of the sampling tail (row argmax and
//! top-k selection over the vocabulary) runs inside the `_sampled` AOT
//! artifacts; the host finishes temperature, top-p, and the categorical
//! draw over the k fetched candidates with the seeded [`Rng`], so
//! generation stays bit-deterministic and EOS/length retirement stays
//! host-side. Per-step fetch: `[b]` ids (greedy) or `[b, k]` logits+ids
//! (stochastic) instead of the `[b, vocab]` row.
//!
//! [`DeviceCategorical`]: the ENTIRE draw runs inside the `_rng` AOT
//! artifacts. The device derives each row's uniform from a counter-based
//! Threefry-2x32 hash of `(request_seed, step)` — [`threefry2x32`] here is
//! the bit-exact host mirror, pinned against the Random123 known-answer
//! vectors so Rust tests and mock engines can predict device draws — and
//! finishes temperature → top-k → top-p → categorical over the device
//! top-k candidates. The host fetches `[b]` sampled ids (O(b) bytes/step,
//! same as greedy) and `sample` is pass-through. Per-request streams are
//! pure functions of `(seed, step)`, so reproducibility survives admission
//! reordering and fused N-step decode chunks with no host RNG bookkeeping.

use anyhow::{bail, Result};

use super::{check_nonempty, RowRef, SamplerConfig, SamplingBackend, TrafficClass};
use crate::util::rng::Rng;

/// Threefry-2x32 rotation schedule (Random123): groups alternate between
/// the first and last four constants.
const THREEFRY_ROT: [u32; 8] = [13, 15, 26, 6, 17, 29, 16, 24];

/// Bit-exact host mirror of the device counter RNG (20-round
/// Threefry-2x32, the same block cipher jax's PRNG is built on). The
/// `_rng` artifacts hash `(k0, k1) = request seed words` with the counter
/// `(x0, x1) = (step, 0)`; this function lets host tests and the serving
/// MockEngine reproduce device draws bit-for-bit.
pub fn threefry2x32(k0: u32, k1: u32, x0: u32, x1: u32) -> (u32, u32) {
    let ks = [k0, k1, k0 ^ k1 ^ 0x1BD1_1BDA];
    let mut x0 = x0.wrapping_add(ks[0]);
    let mut x1 = x1.wrapping_add(ks[1]);
    for j in 0..5u32 {
        for r in 0..4 {
            x0 = x0.wrapping_add(x1);
            x1 = x1.rotate_left(THREEFRY_ROT[(j as usize % 2) * 4 + r]);
            x1 ^= x0;
        }
        x0 = x0.wrapping_add(ks[(j as usize + 1) % 3]);
        x1 = x1.wrapping_add(ks[(j as usize + 2) % 3]).wrapping_add(j + 1);
    }
    (x0, x1)
}

/// Split a 64-bit request seed into the `[hi, lo]` int32 key words the
/// `_rng` artifacts take as their per-row `seeds` input.
pub fn seed_words(seed: u64) -> [i32; 2] {
    [(seed >> 32) as u32 as i32, seed as u32 as i32]
}

/// The uniform in [0, 1) the device draws for `(key, step)` — 24-bit
/// mantissa grid, the same `(x >> 8) * 2^-24` mapping as [`Rng::f32`].
pub fn counter_uniform(seed: u64, step: u32) -> f32 {
    let [k0, k1] = seed_words(seed);
    let (x0, _) = threefry2x32(k0 as u32, k1 as u32, step, 0);
    (x0 >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Device top-k backend. Truncation contract: for stochastic configs the
/// artifact's k candidates ARE the support — with `top_k == 0` (host
/// semantics: unrestricted) the draw is implicitly truncated to the k
/// largest logits, the standard fidelity/traffic trade of device top-k
/// sampling. A config naming a SPECIFIC support wider than k
/// (`top_k > k`) is rejected at construction, as is any repetition
/// penalty (this backend never applies one — `HostFullRow` is the
/// penalized path).
pub struct DeviceTopK {
    pub cfg: SamplerConfig,
    /// Candidate count baked into the `_sampled` artifacts
    /// (`manifest.sample_k`).
    pub k: usize,
    rng: Rng,
    /// Reused working copy of one candidate row (temperature-scaled
    /// logits); the per-token path must not allocate.
    scratch: Vec<f32>,
}

impl DeviceTopK {
    /// Build a device-sampling backend, validating the config against what
    /// k candidates can express — a clear error here instead of a silently
    /// wrong distribution at decode time.
    pub fn new(cfg: SamplerConfig, seed: u64, k: usize, vocab: usize) -> Result<Self> {
        if k == 0 {
            bail!(
                "device sampling unavailable: the artifact set has no sampling tail \
                 (manifest sample_k = 0) — re-run `make artifacts`"
            );
        }
        if cfg.repetition_penalty != 1.0 {
            bail!(
                "DeviceTopK never applies a repetition penalty (requested {}): with \
                 k={k} of {vocab} candidates the penalty could promote tokens from \
                 outside the candidate set, and this backend implements no penalty \
                 path at all — honoring the config silently would be a wrong answer. \
                 Use the HostFullRow backend for penalized sampling",
                cfg.repetition_penalty
            );
        }
        if !cfg.greedy && cfg.top_k > k {
            bail!(
                "DeviceTopK: config asks for top_k {} but the artifacts return only \
                 {k} candidates (manifest sample_k) — lower top_k, or rebuild \
                 artifacts with a larger sample_k",
                cfg.top_k
            );
        }
        Ok(DeviceTopK { cfg, k, rng: Rng::new(seed), scratch: Vec::new() })
    }

    /// Convenience: validate against a manifest's `sample_k` / vocab.
    pub fn for_manifest(
        cfg: SamplerConfig,
        seed: u64,
        m: &crate::runtime::Manifest,
    ) -> Result<Self> {
        Self::new(cfg, seed, m.sample_k, m.actor.vocab)
    }

    /// Host finish over one candidate row (sorted by descending logit):
    /// temperature → config top-k prefix → top-p prefix → categorical.
    /// Mirrors the full-row filter semantics restricted to the candidates;
    /// consumes exactly one uniform draw from `rng` (the backend's own
    /// stream via `sample`, or a per-request rollout stream via
    /// `sample_stream`), like the full-row categorical.
    fn draw_with(&mut self, vals: &[f32], ids: &[i32], rng: &mut Rng) -> Result<i32> {
        check_nonempty(vals, ids)?;
        let take = if self.cfg.top_k == 0 { vals.len() } else { self.cfg.top_k.min(vals.len()) };
        let t = self.cfg.temperature.max(1e-4);
        self.scratch.clear();
        self.scratch.extend(vals[..take].iter().map(|x| x / t));
        // Top-p: smallest prefix of the (already sorted) candidates with
        // cumulative softmax mass >= p — always at least one.
        let keep = if self.cfg.top_p < 1.0 {
            let max = self.scratch[0];
            let z: f32 = self.scratch.iter().map(|x| (x - max).exp()).sum();
            let mut cut = self.scratch.len();
            let mut cum = 0.0f32;
            for (i, x) in self.scratch.iter().enumerate() {
                cum += (x - max).exp() / z;
                if cum >= self.cfg.top_p {
                    cut = i + 1;
                    break;
                }
            }
            cut
        } else {
            self.scratch.len()
        };
        let kept = &self.scratch[..keep];
        let max = kept.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = kept.iter().map(|x| (x - max).exp()).sum();
        let u = rng.f32() * z;
        let mut cum = 0.0f32;
        for (j, x) in kept.iter().enumerate() {
            cum += (x - max).exp();
            if cum >= u {
                return Ok(ids[j]);
            }
        }
        Ok(ids[0]) // numerical fallback (ids sorted: 0 is the argmax)
    }
}

impl SamplingBackend for DeviceTopK {
    fn traffic(&self) -> TrafficClass {
        if self.cfg.greedy {
            TrafficClass::DeviceIds
        } else {
            TrafficClass::DeviceTopK
        }
    }

    fn sample(&mut self, row: RowRef<'_>, history: &[i32]) -> Result<i32> {
        // One copy of the dispatch: route the internal stream through the
        // stream path (cloned out and written back, like Sampler::sample).
        let mut rng = self.rng.clone();
        let tok = self.sample_stream(row, history, &mut rng);
        self.rng = rng;
        tok
    }

    fn sample_stream(&mut self, row: RowRef<'_>, _history: &[i32], rng: &mut Rng) -> Result<i32> {
        match row {
            // Greedy: the device already took the argmax; the id IS the token.
            RowRef::Id(t) => Ok(t),
            RowRef::TopK { vals, ids } => {
                if self.cfg.greedy {
                    // Candidates are sorted descending: first is the argmax.
                    check_nonempty(vals, ids)?;
                    return Ok(ids[0]);
                }
                self.draw_with(vals, ids, rng)
            }
            other @ RowRef::Logits(_) => Err(super::wrong_row("DeviceTopK", &other)),
        }
    }
}

/// Host mirror of the device draw over ONE candidate row (ref.py's
/// `draw_index_ref` semantics): temperature <= 0 selects index 0 (argmax);
/// `top_k <= 0` disables the count cutoff; top-p keeps the smallest prefix
/// whose mass reaches `top_p` (the first candidate is always kept); the
/// categorical inverts the kept-mass CDF at `u * total`. Returns the index
/// into the candidate row. Used by tests and the serving MockEngine to
/// predict device draws.
pub fn draw_index(vals: &[f32], u: f32, temp: f32, top_k: f32, top_p: f32) -> usize {
    if temp <= 0.0 {
        return 0;
    }
    let k = vals.len();
    let kk = if top_k > 0.0 { top_k } else { k as f32 };
    let t = temp.max(1e-6);
    let scaled: Vec<f32> = vals
        .iter()
        .enumerate()
        .map(|(j, v)| if (j as f32) < kk { v / t } else { f32::NEG_INFINITY })
        .collect();
    let s0 = scaled[0];
    let e: Vec<f32> = scaled.iter().map(|x| (x - s0).exp()).collect();
    let z: f32 = e.iter().sum();
    // Kept mass: candidate j survives top-p iff the mass STRICTLY BEFORE it
    // is < top_p (so the first candidate always survives).
    let mut cum = 0.0f32;
    let mut cw = Vec::with_capacity(k);
    let mut total = 0.0f32;
    for x in &e {
        let p = x / z;
        if cum < top_p {
            total += p;
        }
        cum += p;
        cw.push(total);
    }
    let thr = u * total;
    cw.iter().position(|c| *c > thr).unwrap_or(0)
}

/// Host mirror of one full device draw: `(seed, step)`-keyed uniform, then
/// [`draw_index`] over the candidate row. `sp = [temperature, top_k,
/// top_p]` exactly as uploaded to the `_rng` artifacts.
pub fn device_draw(vals: &[f32], ids: &[i32], seed: u64, step: u32, sp: [f32; 3]) -> i32 {
    let u = counter_uniform(seed, step);
    ids[draw_index(vals, u, sp[0], sp[1], sp[2])]
}

/// Fully device-resident sampling: the `_rng` artifacts draw the token on
/// device from the `(request_seed, step)` counter stream, so the host sees
/// only `[b]` sampled ids and [`SamplingBackend::sample`] is pass-through.
/// Same truncation contract as [`DeviceTopK`] (the k candidates ARE the
/// support; `top_k > k` and any repetition penalty are construction
/// errors). Holds no RNG: randomness is keyed per request by the engine's
/// seeds/steps upload, which is what makes each request's stream
/// independent of batch composition and chunking.
pub struct DeviceCategorical {
    pub cfg: SamplerConfig,
    /// Candidate count baked into the `_rng` artifacts (`manifest.sample_k`).
    pub k: usize,
}

impl DeviceCategorical {
    pub fn new(cfg: SamplerConfig, k: usize, vocab: usize) -> Result<Self> {
        if k == 0 {
            bail!(
                "device sampling unavailable: the artifact set has no sampling tail \
                 (manifest sample_k = 0) — re-run `make artifacts`"
            );
        }
        if cfg.repetition_penalty != 1.0 {
            bail!(
                "DeviceCategorical never applies a repetition penalty (requested {}): \
                 with k={k} of {vocab} candidates the penalty could promote tokens \
                 from outside the candidate set, and the device draw implements no \
                 penalty path — use the HostFullRow backend for penalized sampling",
                cfg.repetition_penalty
            );
        }
        if !cfg.greedy && cfg.top_k > k {
            bail!(
                "DeviceCategorical: config asks for top_k {} but the artifacts return \
                 only {k} candidates (manifest sample_k) — lower top_k, or rebuild \
                 artifacts with a larger sample_k",
                cfg.top_k
            );
        }
        Ok(DeviceCategorical { cfg, k })
    }

    /// Validate against a manifest: needs the `device_rng` capability and a
    /// sampling tail.
    pub fn for_manifest(cfg: SamplerConfig, m: &crate::runtime::Manifest) -> Result<Self> {
        m.require_device_rng()?;
        Self::new(cfg, m.sample_k, m.actor.vocab)
    }
}

impl SamplingBackend for DeviceCategorical {
    fn traffic(&self) -> TrafficClass {
        TrafficClass::DeviceCategorical
    }

    fn sample(&mut self, row: RowRef<'_>, _history: &[i32]) -> Result<i32> {
        match row {
            // The device already drew the token; the id IS the token.
            RowRef::Id(t) => Ok(t),
            other => Err(super::wrong_row("DeviceCategorical", &other)),
        }
    }

    fn device_params(&self) -> Option<[f32; 3]> {
        // Greedy rides the same artifacts with temperature 0 (the device
        // draw degrades to argmax, bit-equal by the shared tie-break).
        Some(if self.cfg.greedy {
            [0.0, self.k as f32, 1.0]
        } else {
            [self.cfg.temperature, self.cfg.top_k as f32, self.cfg.top_p]
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn greedy_cfg() -> SamplerConfig {
        SamplerConfig { greedy: true, ..Default::default() }
    }

    #[test]
    fn greedy_traffic_is_ids_stochastic_is_topk() {
        let g = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        assert_eq!(g.traffic(), TrafficClass::DeviceIds);
        let s = DeviceTopK::new(SamplerConfig::default(), 0, 8, 256).unwrap();
        assert_eq!(s.traffic(), TrafficClass::DeviceTopK);
    }

    #[test]
    fn greedy_returns_device_id_verbatim() {
        let mut b = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        assert_eq!(b.sample(RowRef::Id(42), &[]).unwrap(), 42);
        // Greedy over a candidate row takes the first (sorted) candidate.
        let t = b
            .sample(RowRef::TopK { vals: &[3.0, 2.0, 1.0], ids: &[9, 5, 7] }, &[])
            .unwrap();
        assert_eq!(t, 9);
    }

    #[test]
    fn rejects_full_logits_rows() {
        let mut b = DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap();
        let err = b.sample(RowRef::Logits(&[1.0, 2.0]), &[]).unwrap_err();
        assert!(format!("{err:#}").contains("wrong artifact"));
    }

    #[test]
    fn repetition_penalty_is_a_config_error_not_a_wrong_answer() {
        let cfg = SamplerConfig { repetition_penalty: 1.2, ..Default::default() };
        let err = DeviceTopK::new(cfg.clone(), 0, 8, 256).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("repetition penalty"), "{msg}");
        assert!(msg.contains("HostFullRow"), "{msg}");
        // Rejected even at k == vocab: the backend has no penalty path, so
        // accepting the config would silently sample the wrong distribution.
        assert!(DeviceTopK::new(cfg, 0, 256, 256).is_err());
        // Greedy is no exception (greedy + penalty can flip the argmax).
        let greedy_pen = SamplerConfig {
            greedy: true,
            repetition_penalty: 2.0,
            ..Default::default()
        };
        assert!(DeviceTopK::new(greedy_pen, 0, 8, 256).is_err());
    }

    #[test]
    fn top_k_wider_than_candidates_is_rejected() {
        let cfg = SamplerConfig { top_k: 50, ..Default::default() };
        let err = DeviceTopK::new(cfg, 0, 8, 256).unwrap_err();
        assert!(format!("{err:#}").contains("sample_k"));
    }

    #[test]
    fn missing_sampling_tail_is_actionable() {
        let err = DeviceTopK::new(greedy_cfg(), 0, 0, 256).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn stochastic_draw_matches_candidate_distribution() {
        // Two candidates with p = [0.25, 0.75] after softmax.
        let vals = [1.0f32.ln(), 3.0f32.ln()];
        // Sorted-descending contract: re-order so vals[0] is the max.
        let vals = [vals[1], vals[0]];
        let ids = [11, 22];
        let mut b = DeviceTopK::new(SamplerConfig::default(), 42, 2, 256).unwrap();
        let n = 20_000;
        let mut hi = 0;
        for _ in 0..n {
            match b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap() {
                11 => hi += 1,
                22 => {}
                other => panic!("sampled {other} outside the candidate set"),
            }
        }
        let frac = hi as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn top_p_restricts_candidate_support() {
        // First candidate alone carries ~0.84 mass > 0.5 -> always chosen.
        let vals = [3.0, 1.0, 0.0, -1.0];
        let ids = [4, 5, 6, 7];
        let cfg = SamplerConfig { top_p: 0.5, ..Default::default() };
        let mut b = DeviceTopK::new(cfg, 1, 4, 256).unwrap();
        for _ in 0..200 {
            assert_eq!(b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(), 4);
        }
    }

    #[test]
    fn config_top_k_narrows_candidates() {
        let vals = [5.0, 4.9, -10.0, -10.0];
        let ids = [1, 2, 3, 4];
        let cfg = SamplerConfig { top_k: 2, ..Default::default() };
        let mut b = DeviceTopK::new(cfg, 3, 4, 256).unwrap();
        for _ in 0..200 {
            let t = b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap();
            assert!(t == 1 || t == 2, "sampled {t} outside config top-2");
        }
    }

    #[test]
    fn seeded_draws_are_deterministic() {
        let vals = [2.0, 1.5, 1.0, 0.5];
        let ids = [3, 1, 4, 1];
        let cfg = SamplerConfig { temperature: 0.8, top_p: 0.9, ..Default::default() };
        let mut a = DeviceTopK::new(cfg.clone(), 9, 4, 256).unwrap();
        let mut b = DeviceTopK::new(cfg, 9, 4, 256).unwrap();
        for _ in 0..100 {
            assert_eq!(
                a.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(),
                b.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap()
            );
        }
    }

    #[test]
    fn explicit_stream_reproduces_internal_stream() {
        // The rollout contract on the device backend: an external stream
        // seeded like the backend's internal one draws the same tokens.
        let vals = [2.0, 1.5, 1.0, 0.5];
        let ids = [3, 1, 4, 1];
        let cfg = SamplerConfig { temperature: 0.8, top_p: 0.9, ..Default::default() };
        let mut internal = DeviceTopK::new(cfg.clone(), 21, 4, 256).unwrap();
        let mut external = DeviceTopK::new(cfg, 777, 4, 256).unwrap();
        let mut stream = crate::util::rng::Rng::new(21);
        for _ in 0..100 {
            assert_eq!(
                internal.sample(RowRef::TopK { vals: &vals, ids: &ids }, &[]).unwrap(),
                external
                    .sample_stream(RowRef::TopK { vals: &vals, ids: &ids }, &[], &mut stream)
                    .unwrap()
            );
        }
    }

    #[test]
    fn malformed_candidate_rows_error() {
        let mut b = DeviceTopK::new(SamplerConfig::default(), 0, 4, 256).unwrap();
        assert!(b.sample(RowRef::TopK { vals: &[], ids: &[] }, &[]).is_err());
        assert!(b.sample(RowRef::TopK { vals: &[1.0], ids: &[1, 2] }, &[]).is_err());
    }

    #[test]
    fn threefry_known_answer_vectors() {
        // Random123's published Threefry-2x32x20 KATs — the same vectors
        // python/tests/test_fused_decode.py pins the device kernel against,
        // so host mirror and device stream agree bit-for-bit by transitivity.
        assert_eq!(threefry2x32(0, 0, 0, 0), (0x6B20_0159, 0x99BA_4EFE));
        assert_eq!(
            threefry2x32(0xFFFF_FFFF, 0xFFFF_FFFF, 0xFFFF_FFFF, 0xFFFF_FFFF),
            (0x1CB9_96FC, 0xBB00_2BE7)
        );
        assert_eq!(
            threefry2x32(0x1319_8A2E, 0x0370_7344, 0x243F_6A88, 0x85A3_08D3),
            (0xC492_3A9C, 0x483D_F7A0)
        );
    }

    #[test]
    fn counter_uniform_matches_pinned_device_words() {
        // Cross-language pinned x0 words (same table in test_fused_decode.py):
        // u = (x0 >> 8) * 2^-24 on the same grid as Rng::f32.
        let grid = |x0: u32| (x0 >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
        assert_eq!(counter_uniform(0, 0), grid(0x6B20_0159));
        let seed_12 = (1u64 << 32) | 2;
        assert_eq!(counter_uniform(seed_12, 3), grid(0x8E9A_2EAB));
        let seed_neg = 0xFFFF_FFFF_FFFF_FFFEu64; // key words (-1, -2)
        assert_eq!(counter_uniform(seed_neg, 7), grid(0x6D06_F4B6));
        let seed_big = (0x0123_4567u64 << 32) | 0x0089_ABCD;
        assert_eq!(counter_uniform(seed_big, 41), grid(0x388D_5AF7));
        assert_eq!(seed_words(seed_big), [0x0123_4567, 0x0089_ABCD]);
        assert_eq!(seed_words(seed_neg), [-1, -2]);
    }

    #[test]
    fn counter_stream_is_a_pure_function_of_key_and_step() {
        // Distinct steps and distinct seeds decorrelate; same (seed, step)
        // always reproduces — the property that makes device streams immune
        // to admission reordering and chunking.
        let a: Vec<f32> = (0..16).map(|s| counter_uniform(99, s)).collect();
        let b: Vec<f32> = (0..16).map(|s| counter_uniform(99, s)).collect();
        assert_eq!(a, b);
        let c: Vec<f32> = (0..16).map(|s| counter_uniform(100, s)).collect();
        assert_ne!(a, c);
        for u in a.iter().chain(&c) {
            assert!((0.0..1.0).contains(u), "{u}");
        }
    }

    #[test]
    fn draw_index_mirrors_device_semantics() {
        let vals = [3.0, 2.0, 1.0, 0.0];
        // temp <= 0: argmax (index 0) regardless of u.
        assert_eq!(draw_index(&vals, 0.999, 0.0, 0.0, 1.0), 0);
        // u = 0 lands in the first candidate's mass.
        assert_eq!(draw_index(&vals, 0.0, 1.0, 0.0, 1.0), 0);
        // u -> 1 lands in the last kept candidate.
        assert_eq!(draw_index(&vals, 0.999_999, 1.0, 0.0, 1.0), 3);
        // top_k = 2 masks candidates 2/3 even at u -> 1.
        assert_eq!(draw_index(&vals, 0.999_999, 1.0, 2.0, 1.0), 1);
        // top_p small enough keeps only the first (~0.64 mass at temp 1).
        assert_eq!(draw_index(&vals, 0.999_999, 1.0, 0.0, 0.5), 0);
    }

    #[test]
    fn device_categorical_is_pass_through_ids() {
        let mut b = DeviceCategorical::new(SamplerConfig::default(), 8, 256).unwrap();
        assert_eq!(b.traffic(), TrafficClass::DeviceCategorical);
        assert_eq!(b.sample(RowRef::Id(42), &[]).unwrap(), 42);
        // Any other row kind means the engine ran the wrong artifact family.
        let err = b.sample(RowRef::Logits(&[1.0, 2.0]), &[]).unwrap_err();
        assert!(format!("{err:#}").contains("wrong artifact"));
        let err = b.sample(RowRef::TopK { vals: &[1.0], ids: &[1] }, &[]).unwrap_err();
        assert!(format!("{err:#}").contains("wrong artifact"));
    }

    #[test]
    fn device_categorical_params_and_validation() {
        let cfg = SamplerConfig { temperature: 0.7, top_k: 5, top_p: 0.9, ..Default::default() };
        let b = DeviceCategorical::new(cfg, 8, 256).unwrap();
        assert_eq!(b.device_params(), Some([0.7, 5.0, 0.9]));
        // Greedy maps to temperature 0 on the same artifacts.
        let g = DeviceCategorical::new(greedy_cfg(), 8, 256).unwrap();
        assert_eq!(g.device_params(), Some([0.0, 8.0, 1.0]));
        // Same construction guards as DeviceTopK.
        let pen = SamplerConfig { repetition_penalty: 1.2, ..Default::default() };
        let msg = format!("{:#}", DeviceCategorical::new(pen, 8, 256).unwrap_err());
        assert!(msg.contains("HostFullRow"), "{msg}");
        let wide = SamplerConfig { top_k: 50, ..Default::default() };
        let msg = format!("{:#}", DeviceCategorical::new(wide, 8, 256).unwrap_err());
        assert!(msg.contains("sample_k"), "{msg}");
        let msg = format!("{:#}", DeviceCategorical::new(SamplerConfig::default(), 0, 256)
            .unwrap_err());
        assert!(msg.contains("make artifacts"), "{msg}");
        // Other backends advertise no device params: the engine must refuse
        // to run the _rng family for them.
        assert_eq!(DeviceTopK::new(greedy_cfg(), 0, 8, 256).unwrap().device_params(), None);
    }
}
