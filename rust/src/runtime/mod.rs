//! L3 runtime: loads the AOT HLO-text artifacts and executes them on the
//! PJRT CPU client (`xla` crate). This is the only module that touches XLA;
//! everything above it (hybrid engine, coordinator, pipeline) works in terms
//! of [`HostTensor`]s and named artifacts.
//!
//! Buffer strategy (the zero-copy contract):
//!
//! * Model/optimizer state is uploaded once and kept as device-resident
//!   `PjRtBuffer`s; the hot paths call `execute_b` so inputs are never
//!   re-copied.
//! * Outputs stay on device too: [`Artifact::call_to_buffers`] hands back
//!   one `PjRtBuffer` per tuple element, and callers fetch to host only the
//!   elements the host actually consumes — the sampled token ids (O(b),
//!   greedy) or top-k candidates (O(b·k), stochastic) of a `_sampled`
//!   decode step, the `[b, vocab]` logits row of a full-row decode step,
//!   the scalar losses of a train step. Everything else (K/V caches,
//!   updated parameters, optimizer state) is re-fed to the next call
//!   as-is, so per-decode-step host traffic never scales with the KV-cache
//!   size and train steps move only scalars.
//! * If the PJRT wrapper hands tuple outputs back as a single fused tuple
//!   buffer (wrappers without `untuple_result`), `call_to_buffers` degrades
//!   to one fetch→decompose→re-upload round trip and counts the event in
//!   [`ExecStats::fallback_untuples`] — correctness is identical, only the
//!   zero-copy property is lost for that call.
//! * K/V cache inputs of the decode entry points (`decode_step`,
//!   `decode_slots`, and their `_sampled` variants) are compiled WITH
//!   `donate_argnums` — the HLO carries `input_output_alias` and XLA may
//!   write the new K/V rows into the input buffers instead of allocating a
//!   fresh pair each step. Contract: a donated input must be treated as
//!   CONSUMED by the call — never re-fed, never fetched afterwards. The
//!   hybrid engine honors this by construction: the decode outputs replace
//!   the live cache handles every step (`KvCache::update`) and the old
//!   handles are dropped. Non-donated inputs (params, pre-staged per-step
//!   positions, prompts) remain safely reusable across calls; the
//!   manifest's per-artifact `donates` list records which positions are
//!   donated.
//! * When the manifest carries the `padded_prompts` capability, every
//!   prompt-taking generation entry (`prefill`, `prefill_slot`,
//!   `decode_slots`, and their `_sampled` variants) takes one extra
//!   trailing int32 input: the per-row **valid-start** vector. Prompts
//!   shorter than the fixed `prompt_len` window are LEFT-PADDED and the
//!   valid start (= pad width) makes the artifact mask the padding out of
//!   attention and shift position embeddings, so the padded computation
//!   is bit-identical to the exact-length prompt. The hybrid engine
//!   appends the start buffers only when the capability is present, so
//!   pre-capability artifact sets keep their original input lists (and
//!   can only admit exact-length prompts).
//! * With the `device_rng` capability, the serving generation entries gain
//!   a `_rng` variant (`prefill_slot_rng`, `decode_slots_rng`, and their
//!   `_paged` twins): the categorical draw itself runs on device from a
//!   counter-based Threefry stream, keyed per row by `(seed, step)` —
//!   three extra trailing inputs (`[b,2]` seed words, `[b]` draw indices,
//!   `[3]` temperature/top-k/top-p) and a `[b]` sampled-ids output, so
//!   stochastic decode fetches O(b) ids per step instead of O(b·k)
//!   candidates, and a request's token stream is a pure function of its
//!   seed and draw index — independent of batch composition, slot
//!   placement, and chunking.
//! * With the `decode_chunk_sizes` capability, the paged serving path
//!   additionally carries fused `decode_chunk{N}` entries: one call runs N
//!   device-RNG decode steps (per-row EOS latch freezes finished rows
//!   mid-chunk; a `[b]` quota input caps each row's budget) and returns a
//!   `[N·b]` token block, so decode dispatches and host bytes per token
//!   both drop ~N×. Like the stepwise entries, K/V inputs are donated.
//! * [`ExecStats`] tracks seconds and bytes moved in each direction per
//!   artifact; `cargo bench --bench runtime_e2e` prints the ledger and the
//!   decode bench emits it as `BENCH_decode.json`.
//!
//! The literal-returning paths ([`Artifact::call_literals`] /
//! [`Artifact::call_buffers`]) remain for cold calls and for callers that
//! consume every output on host (full-batch forwards, tests).

pub mod manifest;
pub mod tensor;

pub use manifest::{ArtifactSpec, IoSpec, Manifest, TensorSpec};
pub use tensor::HostTensor;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Cumulative executor statistics (per artifact), for the perf pass.
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub fetch_secs: f64,
    pub upload_secs: f64,
    /// Host bytes moved device→host (output fetches) on behalf of this key.
    pub bytes_fetched: u64,
    /// Host bytes moved host→device (input uploads) on behalf of this key.
    pub bytes_uploaded: u64,
    /// Times a fused tuple output had to be decomposed through host memory
    /// because the PJRT wrapper did not untuple (degraded, non-zero-copy).
    pub fallback_untuples: u64,
}

/// The PJRT engine: compiles artifacts, owns buffers, tracks stats.
pub struct Engine {
    client: PjRtClient,
    stats: RefCell<BTreeMap<String, ExecStats>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, stats: RefCell::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact.
    pub fn load_artifact(self: &Rc<Self>, spec: &ArtifactSpec) -> Result<Artifact> {
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {:?}", spec.name))?;
        Ok(Artifact {
            engine: Rc::clone(self),
            name: spec.name.clone(),
            exe,
            compile_secs: t0.elapsed().as_secs_f64(),
            n_inputs: spec.inputs.len(),
        })
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        match t {
            HostTensor::F32(d, s) => self.upload_f32(d, s),
            HostTensor::I32(d, s) => self.upload_i32(d, s),
        }
    }

    /// Upload a raw f32 slice (no `HostTensor` allocation on the hot path).
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        self.note_upload(t0, 4 * data.len() as u64);
        Ok(buf)
    }

    /// Upload a raw i32 slice (token/pos staging in the decode loop).
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        let t0 = Instant::now();
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        self.note_upload(t0, 4 * data.len() as u64);
        Ok(buf)
    }

    /// Single accounting site for every upload path (both dtypes are 4-byte).
    fn note_upload(&self, t0: Instant, bytes: u64) {
        self.note("upload", |st| {
            st.calls += 1;
            st.upload_secs += t0.elapsed().as_secs_f64();
            st.bytes_uploaded += bytes;
        });
    }

    pub fn upload_all(&self, ts: &[HostTensor]) -> Result<Vec<PjRtBuffer>> {
        ts.iter().map(|t| self.upload(t)).collect()
    }

    /// Download one device buffer to host, attributing time and bytes to
    /// `key` (normally the artifact name). A 1-element tuple buffer is
    /// unwrapped transparently (single-output programs whose root is a
    /// tuple, executed through a non-untupling wrapper).
    pub fn fetch(&self, key: &str, buf: &PjRtBuffer) -> Result<HostTensor> {
        let t0 = Instant::now();
        let mut lit = buf.to_literal_sync()?;
        if lit.shape()?.is_tuple() {
            let mut parts = lit.decompose_tuple()?;
            if parts.len() != 1 {
                bail!(
                    "fetch of a {}-element tuple buffer (fetch elements individually \
                     via call_to_buffers, or use call_buffers)",
                    parts.len()
                );
            }
            lit = parts.pop().unwrap();
        }
        let t = HostTensor::from_literal(&lit)?;
        self.note(key, |st| {
            st.fetch_secs += t0.elapsed().as_secs_f64();
            st.bytes_fetched += 4 * t.len() as u64;
        });
        Ok(t)
    }

    fn note(&self, key: &str, f: impl FnOnce(&mut ExecStats)) {
        let mut stats = self.stats.borrow_mut();
        f(stats.entry(key.to_string()).or_default());
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    /// Sum of host↔device traffic across all keys: (uploaded, fetched).
    pub fn bytes_moved(&self) -> (u64, u64) {
        let stats = self.stats.borrow();
        let up = stats.values().map(|s| s.bytes_uploaded).sum();
        let down = stats.values().map(|s| s.bytes_fetched).sum();
        (up, down)
    }

    /// Total fused-tuple fallbacks across all artifacts (0 = fully
    /// zero-copy; see [`ExecStats::fallback_untuples`]).
    pub fn fallback_untuples(&self) -> u64 {
        self.stats.borrow().values().map(|s| s.fallback_untuples).sum()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}

/// A compiled artifact bound to its engine.
pub struct Artifact {
    engine: Rc<Engine>,
    pub name: String,
    exe: PjRtLoadedExecutable,
    pub compile_secs: f64,
    pub n_inputs: usize,
}

impl Artifact {
    fn record(&self, exec: f64, fetch: f64, fetched_bytes: u64) {
        self.engine.note(&self.name, |st| {
            st.calls += 1;
            st.exec_secs += exec;
            st.fetch_secs += fetch;
            st.bytes_fetched += fetched_bytes;
        });
    }

    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.n_inputs {
            bail!(
                "artifact {:?} expects {} inputs, got {}",
                self.name,
                self.n_inputs,
                got
            );
        }
        Ok(())
    }

    /// Execute with host literals (cold path / one-shot calls).
    pub fn call_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.check_arity(inputs.len())?;
        let t0 = Instant::now();
        let out = self.exe.execute::<Literal>(inputs)?;
        let t1 = Instant::now();
        let (result, bytes) = fetch_outputs(&out[0])?;
        self.record(t1.duration_since(t0).as_secs_f64(), t1.elapsed().as_secs_f64(), bytes);
        Ok(result)
    }

    /// Execute with device-resident buffers, fetching every output to host.
    /// Use when the host consumes all outputs (full-batch forwards, tests);
    /// prefer [`Artifact::call_to_buffers`] when outputs feed the next call.
    pub fn call_buffers(&self, inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        self.check_arity(inputs.len())?;
        let t0 = Instant::now();
        let out = self.exe.execute_b::<&PjRtBuffer>(inputs)?;
        let t1 = Instant::now();
        let (result, bytes) = fetch_outputs(&out[0])?;
        self.record(t1.duration_since(t0).as_secs_f64(), t1.elapsed().as_secs_f64(), bytes);
        Ok(result)
    }

    /// Execute with device-resident buffers and KEEP the outputs on device:
    /// returns one `PjRtBuffer` per tuple element. Nothing is copied to
    /// host; fetch the elements the host needs via [`Engine::fetch`] and
    /// re-feed the rest as inputs to later calls.
    ///
    /// `n_outputs` is the tuple-element count the caller expects (the
    /// manifest's output names are GROUP names, so the runtime cannot
    /// derive it) — it disambiguates "one single-element output" from "one
    /// fused tuple buffer" without touching device data.
    pub fn call_to_buffers(
        &self,
        inputs: &[&PjRtBuffer],
        n_outputs: usize,
    ) -> Result<Vec<PjRtBuffer>> {
        self.check_arity(inputs.len())?;
        if n_outputs == 0 {
            bail!("artifact {:?}: n_outputs must be >= 1", self.name);
        }
        let t0 = Instant::now();
        let out = self.exe.execute_b::<&PjRtBuffer>(inputs)?;
        let exec = t0.elapsed().as_secs_f64();
        let bufs = out
            .into_iter()
            .next()
            .with_context(|| format!("artifact {:?} returned no device outputs", self.name))?;
        self.untuple_outputs(bufs, n_outputs, exec)
    }

    /// Normalize raw PJRT outputs to one buffer per tuple element. Wrappers
    /// that set `untuple_result` already hand elements back individually
    /// (zero-copy); a wrapper that returns one fused tuple buffer forces a
    /// fetch→decompose→re-upload round trip, counted in
    /// [`ExecStats::fallback_untuples`]. (A single-output program may come
    /// back as a 1-tuple buffer; it is returned as-is — [`Engine::fetch`]
    /// unwraps 1-tuples transparently.)
    fn untuple_outputs(
        &self,
        bufs: Vec<PjRtBuffer>,
        n_outputs: usize,
        exec: f64,
    ) -> Result<Vec<PjRtBuffer>> {
        if bufs.len() == n_outputs {
            self.record(exec, 0.0, 0);
            return Ok(bufs);
        }
        if bufs.len() != 1 {
            bail!(
                "artifact {:?}: caller expects {} outputs, PJRT returned {} buffers",
                self.name,
                n_outputs,
                bufs.len()
            );
        }
        let t0 = Instant::now();
        let (lits, bytes) = fetch_outputs(&bufs)?;
        if lits.len() != n_outputs {
            bail!(
                "artifact {:?}: caller expects {} outputs, tuple has {} elements",
                self.name,
                n_outputs,
                lits.len()
            );
        }
        let mut out = Vec::with_capacity(lits.len());
        for l in &lits {
            out.push(self.engine.upload(&HostTensor::from_literal(l)?)?);
        }
        let fetch = t0.elapsed().as_secs_f64();
        self.engine.note(&self.name, |st| {
            st.calls += 1;
            st.exec_secs += exec;
            st.fetch_secs += fetch;
            st.bytes_fetched += bytes;
            st.fallback_untuples += 1;
        });
        Ok(out)
    }

    /// Convenience: host tensors in, host tensors out.
    pub fn call(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.call_literals(&lits)?;
        out.iter().map(HostTensor::from_literal).collect()
    }
}

/// Fetch one device's outputs as decomposed literals plus the host bytes
/// moved (elements are f32/i32, the only artifact dtypes). Handles both
/// wrapper behaviors: per-element buffers (untupled) and one fused tuple.
fn fetch_outputs(bufs: &[PjRtBuffer]) -> Result<(Vec<Literal>, u64)> {
    if bufs.is_empty() {
        bail!("execution returned no output buffers");
    }
    let mut lits = Vec::with_capacity(bufs.len());
    for b in bufs {
        lits.push(b.to_literal_sync()?);
    }
    if lits.len() == 1 && lits[0].shape()?.is_tuple() {
        lits = lits.pop().unwrap().decompose_tuple()?;
    }
    let mut bytes = 0u64;
    for l in &lits {
        if let Ok(s) = l.array_shape().context("output element shape") {
            bytes += 4 * s.dims().iter().map(|&d| d as u64).product::<u64>();
        }
    }
    Ok((lits, bytes))
}

/// A named set of device-resident tensors (model params / optimizer state).
/// The hybrid engine holds one per model role (actor, ref, critic, rm, ema).
pub struct ParamStore {
    pub specs: Vec<TensorSpec>,
    pub buffers: Vec<PjRtBuffer>,
}

impl ParamStore {
    /// NOTE: uploads go through `Engine::upload` (`buffer_from_host_buffer`,
    /// `kImmutableOnlyDuringCall` — synchronous copy). `BufferFromHostLiteral`
    /// must NOT be used here: its transfer is async and segfaults once the
    /// source literal is dropped (observed as a SIGSEGV inside
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral` on a worker thread).
    pub fn from_literals(
        engine: &Engine,
        specs: &[TensorSpec],
        lits: &[Literal],
    ) -> Result<ParamStore> {
        if lits.len() != specs.len() {
            bail!("param store arity: {} literals vs {} specs", lits.len(), specs.len());
        }
        let buffers = lits
            .iter()
            .map(|l| engine.upload(&HostTensor::from_literal(l)?))
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamStore { specs: specs.to_vec(), buffers })
    }

    pub fn from_host(
        engine: &Engine,
        specs: &[TensorSpec],
        ts: &[HostTensor],
    ) -> Result<ParamStore> {
        let lits: Vec<Literal> = ts.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        Self::from_literals(engine, specs, &lits)
    }

    /// Replace the stored buffers with host literals — the COLD path
    /// (checkpoint restore, EMA promotion). Train steps must use
    /// [`ParamStore::replace_buffers`], which never transits host memory.
    pub fn replace(&mut self, engine: &Engine, lits: &[Literal]) -> Result<()> {
        if lits.len() != self.specs.len() {
            bail!("replace arity: {} vs {}", lits.len(), self.specs.len());
        }
        for (slot, l) in self.buffers.iter_mut().zip(lits) {
            // Sync upload (see from_literals note re: BufferFromHostLiteral).
            *slot = engine.upload(&HostTensor::from_literal(l)?)?;
        }
        Ok(())
    }

    /// Adopt freshly computed device buffers (train-step outputs) in place
    /// of the stored ones — zero-copy: parameters never touch host memory
    /// between steps. Count must match; shapes are trusted because the
    /// buffers come from the same artifact contract that produced the
    /// previous generation.
    pub fn replace_buffers(&mut self, bufs: Vec<PjRtBuffer>) -> Result<()> {
        if bufs.len() != self.specs.len() {
            bail!("replace_buffers arity: {} vs {}", bufs.len(), self.specs.len());
        }
        self.buffers = bufs;
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }

    /// Download everything to host (checkpointing).
    pub fn to_host(&self) -> Result<Vec<HostTensor>> {
        self.buffers
            .iter()
            .map(|b| HostTensor::from_literal(&b.to_literal_sync()?))
            .collect()
    }

    /// Total parameter bytes held on device.
    pub fn bytes(&self) -> usize {
        self.specs.iter().map(|s| s.numel() * 4).sum()
    }
}

/// Load every artifact of a manifest (used by the pipeline drivers).
pub struct ArtifactSet {
    pub manifest: Manifest,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl ArtifactSet {
    pub fn load(engine: &Rc<Engine>, dir: impl AsRef<Path>, names: &[&str]) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let mut artifacts = BTreeMap::new();
        for name in names {
            let spec = manifest.artifact(name)?;
            artifacts.insert(name.to_string(), engine.load_artifact(spec)?);
        }
        Ok(ArtifactSet { manifest, artifacts })
    }

    pub fn load_all(engine: &Rc<Engine>, dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let names: Vec<String> = manifest.artifacts.keys().cloned().collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        Self::load(engine, dir, &refs)
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))
    }
}
