//! L3 runtime: loads the AOT HLO-text artifacts and executes them on the
//! PJRT CPU client (`xla` crate). This is the only module that touches XLA;
//! everything above it (hybrid engine, coordinator, pipeline) works in terms
//! of [`HostTensor`]s and named artifacts.
//!
//! Buffer strategy: model/optimizer state is uploaded once and kept as
//! device-resident `PjRtBuffer`s; the hot path calls `execute_b` so inputs
//! are never re-copied. Outputs arrive as a single tuple buffer (the C
//! wrapper does not set `untuple_result`), so results are fetched via one
//! literal and decomposed — on the CPU plugin this is a plain memcpy, and
//! the cost is measured in `rust/benches/hot_paths.rs`.

pub mod manifest;
pub mod tensor;

pub use manifest::{ArtifactSpec, IoSpec, Manifest, TensorSpec};
pub use tensor::HostTensor;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Cumulative executor statistics (per artifact), for the perf pass.
#[derive(Debug, Default, Clone)]
pub struct ExecStats {
    pub calls: u64,
    pub exec_secs: f64,
    pub fetch_secs: f64,
    pub upload_secs: f64,
}

/// The PJRT engine: compiles artifacts, owns buffers, tracks stats.
pub struct Engine {
    client: PjRtClient,
    stats: RefCell<BTreeMap<String, ExecStats>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, stats: RefCell::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one HLO-text artifact.
    pub fn load_artifact(self: &Rc<Self>, spec: &ArtifactSpec) -> Result<Artifact> {
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            spec.file.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {:?}", spec.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling {:?}", spec.name))?;
        Ok(Artifact {
            engine: Rc::clone(self),
            name: spec.name.clone(),
            exe,
            compile_secs: t0.elapsed().as_secs_f64(),
            n_inputs: spec.inputs.len(),
        })
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<PjRtBuffer> {
        let t0 = Instant::now();
        let buf = match t {
            HostTensor::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
            HostTensor::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
        };
        self.note("upload", |st| st.upload_secs += t0.elapsed().as_secs_f64());
        Ok(buf)
    }

    pub fn upload_all(&self, ts: &[HostTensor]) -> Result<Vec<PjRtBuffer>> {
        ts.iter().map(|t| self.upload(t)).collect()
    }

    fn note(&self, key: &str, f: impl FnOnce(&mut ExecStats)) {
        let mut stats = self.stats.borrow_mut();
        f(stats.entry(key.to_string()).or_default());
    }

    pub fn stats(&self) -> BTreeMap<String, ExecStats> {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}

/// A compiled artifact bound to its engine.
pub struct Artifact {
    engine: Rc<Engine>,
    pub name: String,
    exe: PjRtLoadedExecutable,
    pub compile_secs: f64,
    pub n_inputs: usize,
}

impl Artifact {
    fn record(&self, exec: f64, fetch: f64) {
        self.engine.note(&self.name, |st| {
            st.calls += 1;
            st.exec_secs += exec;
            st.fetch_secs += fetch;
        });
    }

    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.n_inputs {
            bail!(
                "artifact {:?} expects {} inputs, got {}",
                self.name,
                self.n_inputs,
                got
            );
        }
        Ok(())
    }

    /// Execute with host literals (cold path / one-shot calls).
    pub fn call_literals(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        self.check_arity(inputs.len())?;
        let t0 = Instant::now();
        let out = self.exe.execute::<Literal>(inputs)?;
        let t1 = Instant::now();
        let result = fetch_tuple(&out[0][0])?;
        self.record(t1.duration_since(t0).as_secs_f64(), t1.elapsed().as_secs_f64());
        Ok(result)
    }

    /// Execute with device-resident buffers (hot path: params stay put).
    pub fn call_buffers(&self, inputs: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        self.check_arity(inputs.len())?;
        let t0 = Instant::now();
        let out = self.exe.execute_b::<&PjRtBuffer>(inputs)?;
        let t1 = Instant::now();
        let result = fetch_tuple(&out[0][0])?;
        self.record(t1.duration_since(t0).as_secs_f64(), t1.elapsed().as_secs_f64());
        Ok(result)
    }

    /// Convenience: host tensors in, host tensors out.
    pub fn call(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let lits: Vec<Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self.call_literals(&lits)?;
        out.iter().map(HostTensor::from_literal).collect()
    }
}

/// Fetch a (possibly tuple) output buffer as decomposed literals.
fn fetch_tuple(buf: &PjRtBuffer) -> Result<Vec<Literal>> {
    let mut lit = buf.to_literal_sync()?;
    let shape = lit.shape()?;
    if shape.is_tuple() {
        Ok(lit.decompose_tuple()?)
    } else {
        Ok(vec![lit])
    }
}

/// A named set of device-resident tensors (model params / optimizer state).
/// The hybrid engine holds one per model role (actor, ref, critic, rm, ema).
pub struct ParamStore {
    pub specs: Vec<TensorSpec>,
    pub buffers: Vec<PjRtBuffer>,
}

impl ParamStore {
    /// NOTE: uploads go through `Engine::upload` (`buffer_from_host_buffer`,
    /// `kImmutableOnlyDuringCall` — synchronous copy). `BufferFromHostLiteral`
    /// must NOT be used here: its transfer is async and segfaults once the
    /// source literal is dropped (observed as a SIGSEGV inside
    /// `AbstractTfrtCpuBuffer::CopyFromLiteral` on a worker thread).
    pub fn from_literals(
        engine: &Engine,
        specs: &[TensorSpec],
        lits: &[Literal],
    ) -> Result<ParamStore> {
        if lits.len() != specs.len() {
            bail!("param store arity: {} literals vs {} specs", lits.len(), specs.len());
        }
        let buffers = lits
            .iter()
            .map(|l| engine.upload(&HostTensor::from_literal(l)?))
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamStore { specs: specs.to_vec(), buffers })
    }

    pub fn from_host(
        engine: &Engine,
        specs: &[TensorSpec],
        ts: &[HostTensor],
    ) -> Result<ParamStore> {
        let lits: Vec<Literal> = ts.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        Self::from_literals(engine, specs, &lits)
    }

    /// Replace the stored buffers with freshly computed literals (after a
    /// train step the artifact returns the new params as tuple elements).
    pub fn replace(&mut self, engine: &Engine, lits: &[Literal]) -> Result<()> {
        if lits.len() != self.specs.len() {
            bail!("replace arity: {} vs {}", lits.len(), self.specs.len());
        }
        for (slot, l) in self.buffers.iter_mut().zip(lits) {
            // Sync upload (see from_literals note re: BufferFromHostLiteral).
            *slot = engine.upload(&HostTensor::from_literal(l)?)?;
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }

    /// Download everything to host (checkpointing).
    pub fn to_host(&self) -> Result<Vec<HostTensor>> {
        self.buffers
            .iter()
            .map(|b| HostTensor::from_literal(&b.to_literal_sync()?))
            .collect()
    }

    /// Total parameter bytes held on device.
    pub fn bytes(&self) -> usize {
        self.specs.iter().map(|s| s.numel() * 4).sum()
    }
}

/// Load every artifact of a manifest (used by the pipeline drivers).
pub struct ArtifactSet {
    pub manifest: Manifest,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl ArtifactSet {
    pub fn load(engine: &Rc<Engine>, dir: impl AsRef<Path>, names: &[&str]) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;
        let mut artifacts = BTreeMap::new();
        for name in names {
            let spec = manifest.artifact(name)?;
            artifacts.insert(name.to_string(), engine.load_artifact(spec)?);
        }
        Ok(ArtifactSet { manifest, artifacts })
    }

    pub fn load_all(engine: &Rc<Engine>, dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let names: Vec<String> = manifest.artifacts.keys().cloned().collect();
        let refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        Self::load(engine, dir, &refs)
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not loaded"))
    }
}
