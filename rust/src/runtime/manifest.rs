//! The AOT manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed with the in-tree JSON parser.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
    /// Input positions compiled with `donate_argnums` (the K/V caches of
    /// the decode entry points): the runtime must treat those inputs as
    /// consumed by the call — XLA may have updated them in place.
    pub donates: Vec<usize>,
    pub hlo_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub run: String,
    pub dir: PathBuf,
    pub batch: usize,
    pub prompt_len: usize,
    pub gen_len: usize,
    pub seq_len: usize,
    /// Candidate count of the device-side sampling tail (`_sampled`
    /// artifacts return `[batch, sample_k]` top-k logits+ids). 0 when the
    /// artifact set predates device-side sampling.
    pub sample_k: usize,
    /// True when the prompt-taking generation entries accept per-row
    /// valid-start vectors (left-padded variable-length prompts): prompts
    /// of true length `L <= prompt_len` are admitted left-padded with
    /// `start = prompt_len - L`, attention masks keys before `start`, and
    /// position embeddings are shifted so the computation is bit-identical
    /// to the unpadded exact-length prompt. False for artifact sets built
    /// before the capability existed — those can only admit exact-length
    /// prompts.
    pub padded_prompts: bool,
    /// True when the artifact set carries the block-paged serving entries
    /// (`prefill_slot_paged` / `decode_slots_paged` families): the KV cache
    /// is a physical page pool `[n_layers, n_heads, kv_pages * page_size,
    /// d_head]` addressed through per-slot block tables, so retired pages
    /// return to a free list and pages holding a shared prompt prefix are
    /// mapped into several tables at once. False for artifact sets built
    /// before the capability existed — those only support the arena cache.
    pub paged_kv: bool,
    /// True when the paged artifacts were compiled against the LAZY
    /// block-table contract: every paged kernel masks gathered rows by the
    /// live length (`idx <= pos`), so a table whose tail still points at
    /// garbage page 0 reads bit-identically to a fully-populated one. The
    /// runtime may then draw pages on demand (prompt coverage at admission,
    /// one page per boundary crossing during decode) and oversubscribe the
    /// pool via `limit_kv_pages`. False for artifact sets built before the
    /// capability was stamped — their kernels carry the same mask, but the
    /// contract was never parity-tested, so oversubscription stays gated.
    pub lazy_kv: bool,
    /// Tokens per KV page of the paged serving path (0 when `paged_kv` is
    /// false).
    pub page_size: usize,
    /// Physical pages in the paged pool (0 when `paged_kv` is false).
    pub kv_pages: usize,
    /// True when the artifact set carries the `_rng` generation entries:
    /// the categorical draw runs ON DEVICE from a counter-based
    /// Threefry-2x32 stream keyed by `(request_seed, step)`, so stochastic
    /// decode returns `[batch]` sampled ids (O(b) bytes/step) instead of
    /// the `[batch, sample_k]` candidate rows the host-draw path fetches.
    /// False for artifact sets built before the capability existed.
    pub device_rng: bool,
    /// Fused decode chunk sizes carried by the artifact set: for each `N`
    /// here a `decode_chunk{N}` entry runs N decode+sample steps in ONE
    /// dispatch (per-row EOS/quota latch freezing retired rows mid-chunk).
    /// Empty for artifact sets built before the capability existed.
    pub decode_chunk_sizes: Vec<usize>,
    pub actor: ModelConfig,
    pub critic: ModelConfig,
    pub actor_params: Vec<TensorSpec>,
    pub critic_params: Vec<TensorSpec>,
    pub actor_opt: Vec<TensorSpec>,
    pub critic_opt: Vec<TensorSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_specs(j: &Json) -> Result<Vec<TensorSpec>> {
    j.as_arr()
        .context("expected array of tensor specs")?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: e.at("name").as_str().context("name")?.to_string(),
                shape: e
                    .at("shape")
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

fn model_config(j: &Json) -> Result<ModelConfig> {
    Ok(ModelConfig {
        name: j.at("name").as_str().context("name")?.to_string(),
        vocab: j.at("vocab").as_usize().context("vocab")?,
        d_model: j.at("d_model").as_usize().context("d_model")?,
        n_layers: j.at("n_layers").as_usize().context("n_layers")?,
        n_heads: j.at("n_heads").as_usize().context("n_heads")?,
        d_ff: j.at("d_ff").as_usize().context("d_ff")?,
        max_seq: j.at("max_seq").as_usize().context("max_seq")?,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let cfg = j.at("config");
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.at("artifacts").as_obj().context("artifacts")? {
            let inputs = a
                .at("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|i| {
                    Ok(IoSpec {
                        shape: i
                            .at("shape")
                            .as_arr()
                            .context("shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<_>>()?,
                        dtype: i.at("dtype").as_str().context("dtype")?.to_string(),
                    })
                })
                .collect::<Result<_>>()?;
            let outputs = a
                .at("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(|o| Ok(o.as_str().context("output name")?.to_string()))
                .collect::<Result<_>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.at("file").as_str().context("file")?),
                    inputs,
                    outputs,
                    donates: a
                        .get("donates")
                        .and_then(|d| d.as_arr())
                        .map(|arr| arr.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default(),
                    hlo_bytes: a.get("hlo_bytes").and_then(|b| b.as_usize()).unwrap_or(0),
                },
            );
        }

        Ok(Manifest {
            run: j.at("run").as_str().context("run")?.to_string(),
            dir,
            batch: cfg.at("batch").as_usize().context("batch")?,
            prompt_len: cfg.at("prompt_len").as_usize().context("prompt_len")?,
            gen_len: cfg.at("gen_len").as_usize().context("gen_len")?,
            seq_len: cfg.at("seq_len").as_usize().context("seq_len")?,
            sample_k: cfg.get("sample_k").and_then(|v| v.as_usize()).unwrap_or(0),
            padded_prompts: cfg
                .get("padded_prompts")
                .and_then(|v| v.as_bool())
                .unwrap_or(false),
            paged_kv: cfg.get("paged_kv").and_then(|v| v.as_bool()).unwrap_or(false),
            lazy_kv: cfg.get("lazy_kv").and_then(|v| v.as_bool()).unwrap_or(false),
            page_size: cfg.get("page_size").and_then(|v| v.as_usize()).unwrap_or(0),
            kv_pages: cfg.get("kv_pages").and_then(|v| v.as_usize()).unwrap_or(0),
            device_rng: cfg.get("device_rng").and_then(|v| v.as_bool()).unwrap_or(false),
            decode_chunk_sizes: cfg
                .get("decode_chunk_sizes")
                .and_then(|v| v.as_arr())
                .map(|arr| arr.iter().filter_map(|x| x.as_usize()).collect())
                .unwrap_or_default(),
            actor: model_config(cfg.at("actor"))?,
            critic: model_config(cfg.at("critic"))?,
            actor_params: tensor_specs(j.at("actor_params"))?,
            critic_params: tensor_specs(j.at("critic_params"))?,
            actor_opt: tensor_specs(j.at("actor_opt"))?,
            critic_opt: tensor_specs(j.at("critic_opt"))?,
            artifacts,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        match self.artifacts.get(name) {
            Some(a) => Ok(a),
            None => bail!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            ),
        }
    }

    /// True when the artifact set carries the continuous-batching serving
    /// entry points — everything the serve scheduler and the rollout
    /// subsystem need. The single predicate all artifact-gated serving /
    /// rollout benches, ablations, and tests share (so a future required
    /// serving artifact is added in ONE place).
    pub fn has_serving(&self) -> bool {
        self.artifacts.contains_key("prefill_slot") && self.artifacts.contains_key("decode_slots")
    }

    /// True when the artifact set carries the BLOCK-PAGED serving entry
    /// points alongside the `paged_kv` capability flag — the gate for the
    /// paged serving path, its goldens, and the prefix-reuse bench phase.
    pub fn has_paged_serving(&self) -> bool {
        self.paged_kv
            && self.artifacts.contains_key("prefill_slot_paged")
            && self.artifacts.contains_key("decode_slots_paged")
    }

    /// Bail with a rebuild hint unless the artifact set supports the
    /// block-paged KV cache. Arena-era artifacts have no block-table
    /// inputs, so paged serving (and shared-prefix reuse) cannot run
    /// against them.
    pub fn require_paged_kv(&self) -> Result<()> {
        if !self.has_paged_serving() {
            bail!(
                "artifacts ({}) predate the block-paged KV cache: the manifest lacks the \
                 `paged_kv` capability (or the `*_paged` serving entries), so paged serving \
                 and shared-prefix reuse are unavailable — re-run `make artifacts`",
                self.run,
            );
        }
        Ok(())
    }

    /// True when the paged artifacts are stamped with the lazy block-table
    /// contract (`lazy_kv` capability on top of paged serving) — the gate
    /// for on-demand page growth and pool oversubscription.
    pub fn has_lazy_kv(&self) -> bool {
        self.lazy_kv && self.has_paged_serving()
    }

    /// Bail with a rebuild hint unless the artifact set is stamped with the
    /// lazy block-table contract. Pre-lazy paged artifacts carry the same
    /// live-length mask but were never parity-tested against garbage-tail
    /// tables, so oversubscription (`limit_kv_pages`) stays gated on the
    /// stamp.
    pub fn require_lazy_kv(&self) -> Result<()> {
        if !self.has_lazy_kv() {
            bail!(
                "artifacts ({}) predate the lazy KV block-table contract: the manifest lacks \
                 the `lazy_kv` capability, so on-demand page growth and pool oversubscription \
                 are unavailable — re-run `make artifacts`",
                self.run,
            );
        }
        Ok(())
    }

    /// True when the artifact set carries the device-RNG sampling entries
    /// alongside the `device_rng` capability flag — the gate for the
    /// `DeviceCategorical` backend (paged serving is the only consumer, so
    /// only the paged `_rng` entries are required).
    pub fn has_device_rng(&self) -> bool {
        self.device_rng
            && self.artifacts.contains_key("prefill_slot_paged_rng")
            && self.artifacts.contains_key("decode_slots_paged_rng")
    }

    /// True when the artifact set carries the fused N-step decode entry for
    /// chunk size `n` (N=1 is the legacy stepwise path and always available
    /// wherever paged serving is).
    pub fn has_decode_chunk(&self, n: usize) -> bool {
        n == 1
            || (self.decode_chunk_sizes.contains(&n)
                && self.artifacts.contains_key(&format!("decode_chunk{n}")))
    }

    /// Bail with a rebuild hint unless the artifact set supports the
    /// device-side categorical draw. Host-draw artifacts have no
    /// seed/step/sparams inputs on the generation entries, so the
    /// DeviceCategorical backend cannot run against them.
    pub fn require_device_rng(&self) -> Result<()> {
        if !self.has_device_rng() {
            bail!(
                "artifacts ({}) predate device-side RNG sampling: the manifest lacks the \
                 `device_rng` capability (or the `*_rng` generation entries), so the \
                 DeviceCategorical backend is unavailable — re-run `make artifacts`",
                self.run,
            );
        }
        Ok(())
    }

    /// Bail with a rebuild hint unless the artifact set carries the fused
    /// `decode_chunk{n}` entry. Pre-capability artifacts can only decode one
    /// token per dispatch.
    pub fn require_decode_chunk(&self, n: usize) -> Result<()> {
        if !self.has_decode_chunk(n) {
            bail!(
                "artifacts ({}) lack the fused decode_chunk{n} entry: the manifest's \
                 `decode_chunk_sizes` is {:?}, so --decode-chunk {n} cannot run — \
                 re-run `make artifacts`",
                self.run,
                self.decode_chunk_sizes,
            );
        }
        Ok(())
    }

    /// Bail with a rebuild hint unless the artifact set can admit prompts
    /// shorter than `prompt_len`. Pre-capability artifacts have no
    /// valid-start inputs on the prefill/decode entries, so a left-padded
    /// short prompt would attend its own padding — a silent wrong answer;
    /// refusing admission with the rebuild command is the only safe move.
    pub fn require_padded_prompts(&self) -> Result<()> {
        if !self.padded_prompts {
            bail!(
                "artifacts ({}) predate variable-length prompts: the manifest lacks the \
                 `padded_prompts` capability, so prompts shorter than prompt_len ({}) \
                 cannot be admitted — re-run `make artifacts`",
                self.run,
                self.prompt_len
            );
        }
        Ok(())
    }

    /// Sanity checks tying the manifest to the architecture configs.
    pub fn validate(&self) -> Result<()> {
        if self.seq_len != self.prompt_len + self.gen_len {
            bail!("seq_len != prompt_len + gen_len");
        }
        if self.sample_k > self.actor.vocab {
            bail!(
                "sample_k {} exceeds actor vocab {} (top-k tail wider than the row)",
                self.sample_k,
                self.actor.vocab
            );
        }
        if self.paged_kv {
            if self.page_size == 0 || self.seq_len % self.page_size != 0 {
                bail!(
                    "paged_kv: page_size {} must be nonzero and divide seq_len {}",
                    self.page_size,
                    self.seq_len
                );
            }
            // Every slot's full window, plus one spare slot's worth for warm
            // prefixes, plus the reserved garbage page 0 (configs.py).
            let want = (self.batch + 1) * (self.seq_len / self.page_size) + 1;
            if self.kv_pages < want {
                bail!(
                    "paged_kv: kv_pages {} cannot hold {} slots of {} blocks (+spare +garbage; \
                     need >= {want})",
                    self.kv_pages,
                    self.batch,
                    self.seq_len / self.page_size
                );
            }
        }
        let actor_numel: usize = self.actor_params.iter().map(|t| t.numel()).sum();
        if actor_numel as u64 != self.actor.n_params() {
            bail!(
                "actor param numel {} != config n_params {}",
                actor_numel,
                self.actor.n_params()
            );
        }
        if self.actor_opt.len() != 2 * self.actor_params.len() + 1 {
            bail!("actor opt layout is not [t] + m + v");
        }
        if self.critic_opt.len() != 2 * self.critic_params.len() + 1 {
            bail!("critic opt layout is not [t] + m + v");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature manifest exercising the parser without artifacts on disk.
    pub fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let text = r#"{
          "run": "fake",
          "config": {
            "batch": 2, "prompt_len": 4, "gen_len": 4, "seq_len": 8,
            "actor": {"name":"a","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8,"d_head":4,"n_params":100},
            "critic": {"name":"c","vocab":16,"d_model":8,"n_layers":1,"n_heads":2,"d_ff":16,"max_seq":8,"d_head":4,"n_params":100}
          },
          "actor_params": [{"name": "embed", "shape": [16, 8]}],
          "critic_params": [{"name": "embed", "shape": [16, 8]}],
          "actor_opt": [{"name":"t","shape":[1]},{"name":"m.embed","shape":[16,8]},{"name":"v.embed","shape":[16,8]}],
          "critic_opt": [{"name":"t","shape":[1]},{"name":"m.embed","shape":[16,8]},{"name":"v.embed","shape":[16,8]}],
          "artifacts": {
            "sft_step": {"file": "sft_step.hlo.txt",
                         "inputs": [{"shape": [2, 8], "dtype": "int32"}],
                         "outputs": ["actor_params", "loss"], "hlo_bytes": 10}
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parse_fake_manifest() {
        let dir = std::env::temp_dir().join("dschat_manifest_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.run, "fake");
        assert_eq!(m.batch, 2);
        assert_eq!(m.actor.vocab, 16);
        assert_eq!(m.actor_params[0].numel(), 128);
        let a = m.artifact("sft_step").unwrap();
        assert_eq!(a.inputs[0].dtype, "int32");
        assert_eq!(a.outputs, vec!["actor_params", "loss"]);
        // Pre-device-sampling manifests parse with the tail disabled and no
        // donated inputs; pre-padding manifests parse with variable-length
        // prompts unavailable.
        assert_eq!(m.sample_k, 0);
        assert!(a.donates.is_empty());
        assert!(!m.padded_prompts);
        // Pre-paging manifests parse with the block-paged path unavailable.
        assert!(!m.paged_kv);
        assert_eq!(m.page_size, 0);
        assert_eq!(m.kv_pages, 0);
        assert!(!m.has_paged_serving());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn short_prompts_need_the_padded_capability() {
        // A pre-capability manifest must refuse short-prompt admission with
        // a config error naming the rebuild command; a manifest carrying
        // the flag passes.
        let dir = std::env::temp_dir().join("dschat_manifest_padded_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let err = m.require_padded_prompts().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
        assert!(msg.contains("padded_prompts"), "{msg}");

        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let text = text.replacen("\"batch\": 2,", "\"batch\": 2, \"padded_prompts\": true,", 1);
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.padded_prompts);
        m.require_padded_prompts().unwrap();
    }

    #[test]
    fn paged_serving_needs_capability_flag_and_entries() {
        // Arena-era manifests refuse paged serving with the rebuild
        // command; the capability needs BOTH the flag and the `*_paged`
        // entries (a flag without entries is a broken build).
        let dir = std::env::temp_dir().join("dschat_manifest_paged_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let msg = format!("{:#}", m.require_paged_kv().unwrap_err());
        assert!(msg.contains("make artifacts"), "{msg}");
        assert!(msg.contains("paged_kv"), "{msg}");

        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        // Flag + geometry, but no *_paged artifacts yet: still refused.
        let flagged = text.replacen(
            "\"batch\": 2,",
            "\"batch\": 2, \"paged_kv\": true, \"page_size\": 4, \"kv_pages\": 7,",
            1,
        );
        std::fs::write(dir.join("manifest.json"), &flagged).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.paged_kv);
        assert_eq!((m.page_size, m.kv_pages), (4, 7));
        assert!(!m.has_paged_serving());
        assert!(m.require_paged_kv().is_err());

        // Flag + entries: the paged path is available.
        let with_entries = flagged.replacen(
            "\"sft_step\": {",
            r#""prefill_slot_paged": {"file": "p.hlo.txt", "inputs": [], "outputs": [], "hlo_bytes": 1},
               "decode_slots_paged": {"file": "d.hlo.txt", "inputs": [], "outputs": [], "hlo_bytes": 1},
               "sft_step": {"#,
            1,
        );
        std::fs::write(dir.join("manifest.json"), &with_entries).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has_paged_serving());
        m.require_paged_kv().unwrap();
    }

    #[test]
    fn paged_geometry_is_validated() {
        // page_size must divide seq_len and kv_pages must cover every slot
        // plus the spare and the garbage page.
        let dir = std::env::temp_dir().join("dschat_manifest_paged_geom_test");
        write_fake_manifest(&dir);
        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        // seq_len 8, batch 2: page_size 4 -> 2 blocks/slot, need (2+1)*2+1 = 7.
        let bad_div = text.replacen(
            "\"batch\": 2,",
            "\"batch\": 2, \"paged_kv\": true, \"page_size\": 3, \"kv_pages\": 7,",
            1,
        );
        std::fs::write(dir.join("manifest.json"), &bad_div).unwrap();
        let msg = format!("{:#}", Manifest::load(&dir).unwrap().validate().unwrap_err());
        assert!(msg.contains("divide seq_len"), "{msg}");

        let too_few = text.replacen(
            "\"batch\": 2,",
            "\"batch\": 2, \"paged_kv\": true, \"page_size\": 4, \"kv_pages\": 6,",
            1,
        );
        std::fs::write(dir.join("manifest.json"), &too_few).unwrap();
        let msg = format!("{:#}", Manifest::load(&dir).unwrap().validate().unwrap_err());
        assert!(msg.contains("kv_pages"), "{msg}");
    }

    #[test]
    fn device_rng_needs_capability_flag_and_entries() {
        // Host-draw-era manifests refuse the DeviceCategorical backend with
        // the rebuild command; the capability needs BOTH the flag and the
        // paged `_rng` entries (a flag without entries is a broken build).
        let dir = std::env::temp_dir().join("dschat_manifest_rng_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.device_rng);
        assert!(m.decode_chunk_sizes.is_empty());
        let msg = format!("{:#}", m.require_device_rng().unwrap_err());
        assert!(msg.contains("make artifacts"), "{msg}");
        assert!(msg.contains("device_rng"), "{msg}");

        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let flagged = text.replacen("\"batch\": 2,", "\"batch\": 2, \"device_rng\": true,", 1);
        std::fs::write(dir.join("manifest.json"), &flagged).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.device_rng);
        assert!(!m.has_device_rng());
        assert!(m.require_device_rng().is_err());

        let with_entries = flagged.replacen(
            "\"sft_step\": {",
            r#""prefill_slot_paged_rng": {"file": "p.hlo.txt", "inputs": [], "outputs": [], "hlo_bytes": 1},
               "decode_slots_paged_rng": {"file": "d.hlo.txt", "inputs": [], "outputs": [], "hlo_bytes": 1},
               "sft_step": {"#,
            1,
        );
        std::fs::write(dir.join("manifest.json"), &with_entries).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has_device_rng());
        m.require_device_rng().unwrap();
    }

    #[test]
    fn decode_chunks_need_size_list_and_entry() {
        // N=1 is the legacy stepwise path: always available. Fused sizes
        // need the size in `decode_chunk_sizes` AND the matching entry; the
        // refusal names the rebuild command and the requested size.
        let dir = std::env::temp_dir().join("dschat_manifest_chunk_test");
        write_fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has_decode_chunk(1));
        m.require_decode_chunk(1).unwrap();
        assert!(!m.has_decode_chunk(4));
        let msg = format!("{:#}", m.require_decode_chunk(4).unwrap_err());
        assert!(msg.contains("make artifacts"), "{msg}");
        assert!(msg.contains("decode_chunk4"), "{msg}");

        let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let flagged = text.replacen(
            "\"batch\": 2,",
            "\"batch\": 2, \"decode_chunk_sizes\": [2, 4, 8],",
            1,
        );
        std::fs::write(dir.join("manifest.json"), &flagged).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.decode_chunk_sizes, vec![2, 4, 8]);
        // Listed but the entry is missing: still refused (broken build).
        assert!(!m.has_decode_chunk(4));

        let with_entry = flagged.replacen(
            "\"sft_step\": {",
            r#""decode_chunk4": {"file": "c4.hlo.txt", "inputs": [], "outputs": [], "hlo_bytes": 1},
               "sft_step": {"#,
            1,
        );
        std::fs::write(dir.join("manifest.json"), &with_entry).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.has_decode_chunk(4));
        m.require_decode_chunk(4).unwrap();
        // Sizes not in the manifest stay unavailable.
        assert!(!m.has_decode_chunk(8));
    }

    #[test]
    fn missing_dir_is_helpful() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
