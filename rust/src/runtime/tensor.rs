//! Host-side tensor type bridging rust data and XLA literals.

use anyhow::{bail, Context, Result};
use xla::{ElementType, Literal};

/// A host tensor: flat data + shape. Only the two dtypes the artifacts use.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor::F32(vec![0.0; shape.iter().product()], shape.to_vec())
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32(vec![v], vec![])
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar convenience (loss values etc).
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got shape {:?}", self.shape());
        }
        Ok(d[0])
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let lit = match self {
            HostTensor::F32(d, s) => {
                let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
                Literal::vec1(d).reshape(&dims)?
            }
            HostTensor::I32(d, s) => {
                let dims: Vec<i64> = s.iter().map(|&x| x as i64).collect();
                Literal::vec1(d).reshape(&dims)?
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostTensor::F32(lit.to_vec::<f32>()?, dims)),
            ElementType::S32 => Ok(HostTensor::I32(lit.to_vec::<i32>()?, dims)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![2, 3]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::I32(vec![7, -8, 9, 0], vec![4]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn scalar_item() {
        let t = HostTensor::scalar_f32(2.5);
        assert_eq!(t.item_f32().unwrap(), 2.5);
        assert!(HostTensor::zeros_f32(&[2, 2]).item_f32().is_err());
    }
}
