//! Tensor-parallel planning: the inference-mode sharding the hybrid engine
//! swaps in for generation (paper §5.3: "using TP in the generation phase
//! instead of ZeRO ... reduces the inter-GPU communication and maintains
//! high GPU memory bandwidth utilization").
//!
//! Megatron-style column/row splits: attention is split by heads, the MLP by
//! its hidden dimension; each transformer layer then needs two all-reduces
//! of the activations per token.

use crate::config::ModelConfig;

/// A tensor-parallel plan for one model over `degree` GPUs.
#[derive(Debug, Clone)]
pub struct TpPlan {
    pub degree: usize,
    /// heads assigned to each rank (contiguous ranges).
    pub head_ranges: Vec<(usize, usize)>,
    /// d_ff columns assigned to each rank.
    pub ff_ranges: Vec<(usize, usize)>,
}

impl TpPlan {
    /// Plan a split; degree must divide heads (the usual constraint) or be 1.
    pub fn new(cfg: &ModelConfig, degree: usize) -> Option<TpPlan> {
        if degree == 0 || cfg.n_heads % degree != 0 || cfg.d_ff % degree != 0 {
            return None;
        }
        let hp = cfg.n_heads / degree;
        let fp = cfg.d_ff / degree;
        Some(TpPlan {
            degree,
            head_ranges: (0..degree).map(|r| (r * hp, (r + 1) * hp)).collect(),
            ff_ranges: (0..degree).map(|r| (r * fp, (r + 1) * fp)).collect(),
        })
    }

    /// Largest valid degree <= limit (for "TP within a node" planning).
    pub fn best_degree(cfg: &ModelConfig, limit: usize) -> usize {
        (1..=limit.max(1))
            .rev()
            .find(|&d| TpPlan::new(cfg, d).is_some())
            .unwrap_or(1)
    }

    /// Parameter bytes resident per rank (fp16): attention + MLP weights are
    /// split; embeddings/LN replicated.
    pub fn param_bytes_per_rank(&self, cfg: &ModelConfig, dtype_bytes: f64) -> f64 {
        let d = cfg.d_model as f64;
        let ff = cfg.d_ff as f64;
        let l = cfg.n_layers as f64;
        let split = (4.0 * d * d + 2.0 * d * ff) * l / self.degree as f64;
        let replicated =
            (cfg.vocab as f64 + cfg.max_seq as f64) * d + l * (ff + 5.0 * d) + 2.0 * d;
        (split + replicated) * dtype_bytes
    }

    /// Communication bytes per generated token per rank: two all-reduces of
    /// the [mb, d] activations per layer (attention output + MLP output).
    pub fn comm_bytes_per_token(&self, cfg: &ModelConfig, microbatch: f64, dtype_bytes: f64) -> f64 {
        if self.degree == 1 {
            return 0.0;
        }
        let n = self.degree as f64;
        let v = microbatch * cfg.d_model as f64 * dtype_bytes;
        // ring all-reduce moves 2*(n-1)/n * v per rank, twice per layer
        2.0 * cfg.n_layers as f64 * (2.0 * (n - 1.0) / n) * v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn plan_requires_divisibility() {
        let cfg = model("opt-13b"); // 40 heads
        assert!(TpPlan::new(&cfg, 8).is_some());
        assert!(TpPlan::new(&cfg, 16).is_none()); // 40 % 16 != 0
        assert!(TpPlan::new(&cfg, 0).is_none());
    }

    #[test]
    fn head_ranges_cover_disjointly() {
        Prop::new(64).check("tp heads disjoint cover", |rng| {
            let cfg = model(["opt-1.3b", "opt-6.7b", "opt-13b", "opt-66b"][rng.below(4) as usize]);
            let degrees: Vec<usize> =
                (1..=8).filter(|d| cfg.n_heads % d == 0 && cfg.d_ff % d == 0).collect();
            let degree = *rng.choose(&degrees);
            let plan = TpPlan::new(&cfg, degree).unwrap();
            let mut covered = vec![false; cfg.n_heads];
            for (lo, hi) in &plan.head_ranges {
                for h in *lo..*hi {
                    prop_assert!(!covered[h], "head {h} covered twice");
                    covered[h] = true;
                }
            }
            prop_assert!(covered.iter().all(|c| *c), "heads uncovered");
            // Balanced ranges.
            let sizes: Vec<usize> = plan.head_ranges.iter().map(|(a, b)| b - a).collect();
            prop_assert!(
                sizes.iter().all(|&s| s == sizes[0]),
                "unbalanced head split {sizes:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn param_bytes_shrink_with_degree() {
        let cfg = model("opt-13b");
        let p1 = TpPlan::new(&cfg, 1).unwrap().param_bytes_per_rank(&cfg, 2.0);
        let p8 = TpPlan::new(&cfg, 8).unwrap().param_bytes_per_rank(&cfg, 2.0);
        assert!(p8 < p1 / 4.0, "{p8} vs {p1}");
        // p1 approximates the full fp16 model.
        let full = cfg.n_params() as f64 * 2.0;
        assert!((p1 - full).abs() / full < 0.01);
    }

    #[test]
    fn comm_zero_at_degree_one() {
        let cfg = model("opt-1.3b");
        let plan = TpPlan::new(&cfg, 1).unwrap();
        assert_eq!(plan.comm_bytes_per_token(&cfg, 8.0, 2.0), 0.0);
    }

    #[test]
    fn best_degree_respects_limit() {
        let cfg = model("opt-13b"); // 40 heads: divisors within 8 -> 8? 40%8=0 yes
        assert_eq!(TpPlan::best_degree(&cfg, 8), 8);
        let cfg66 = model("opt-66b"); // 72 heads: 8 divides 72, d_ff 36864 % 8 == 0
        assert_eq!(TpPlan::best_degree(&cfg66, 8), 8);
        assert_eq!(TpPlan::best_degree(&cfg, 1), 1);
    }
}
