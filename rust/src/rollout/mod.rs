//! The rollout subsystem: RLHF experience generation streamed through the
//! continuous-batching slot scheduler (the OpenRLHF-style decoupling of
//! experience generation from training, arXiv 2405.11143, brought in-tree).
//!
//! The paper's own profiling says generation dominates Step-3 cost, which
//! is why the Hybrid Engine runs it on the inference-optimized path. The
//! fixed-batch `HybridEngine::generate` still pays two scheduling taxes,
//! though: one straggler row keeps all `b` slots decoding to `gen_len`
//! (early-EOS rows burn capacity as dead rows), and the PPO rollout size is
//! hard-locked to the artifact batch `b`. [`RolloutEngine`] removes both by
//! feeding an oversubscribed prompt queue — any `n` that is a multiple of
//! `b` — through the serving `crate::serving::Scheduler`: EOS-retired rows
//! free their KV slot for the next queued prompt at the following step
//! boundary, and completions stream into an
//! [`ExperienceBuffer`] that regroups them into fixed-`b` batches for
//! scoring (`HybridEngine::score_experience`) and training
//! (`PpoTrainer::train_rlhf` stages each batch once via
//! `stage_experience`). The PPO rollout size becomes the
//! `PpoConfig::rollout_batch` knob instead of an artifact constant.
//!
//! # Reproducibility under admission-order nondeterminism
//!
//! Which slot a request lands in, and when, depends on when other
//! sequences hit EOS — so the order sampling calls interleave across
//! requests is data-dependent. A single backend RNG stream would make the
//! sampled tokens depend on that interleaving. Instead every request gets
//! its **own derived stream**: [`request_seed`] mixes the rollout's base
//! seed with the request id (seed ⊕ splitmix-scrambled id), the scheduler
//! stores the stream per slot, and the backend finishes that request's
//! rows through `SamplingBackend::sample_stream`. A request's tokens are
//! therefore a pure function of `(params, prompt, base seed, id)` — the
//! greedy golden in `rust/tests/integration_pipeline.rs` pins the stronger
//! property that a scheduler rollout of `b` equal-length prompts is
//! bit-identical to fixed-batch `generate`.
//!
//! # Flush/seed-derivation contract (what callers may rely on)
//!
//! * Groups are **static**: group `g` is request ids `[g·b, (g+1)·b)` in
//!   submission order; flushes arrive strictly in group order (see
//!   `buffer` module docs). Generation never blocks on a flush.
//! * The group callback runs mid-rollout with other sequences still
//!   holding KV slots. It may run inference-mode work (scoring forwards
//!   upload their own inputs and flip no mode), but it must NOT trigger a
//!   train-mode flip — that would free the serving KV cache under the
//!   scheduler. Training happens after [`RolloutEngine::run`] returns.
//! * Per-request streams derive as `request_seed(base, id)`; re-running a
//!   rollout with the same base seed, prompts, and ids reproduces every
//!   sequence bit for bit regardless of retirement order. Callers running
//!   MANY rollouts (one per PPO iteration) must vary the base per round —
//!   [`round_seed`] is that derivation; the coordinator uses it so
//!   iteration t+1 never replays iteration t's draws.
//!
//! Slot-occupancy accounting (`SchedStats::bubble_fraction`) is returned to
//! the caller; `cargo bench --bench runtime_e2e` emits it to
//! `BENCH_rollout.json` against the fixed-batch baseline.

pub mod buffer;

pub use buffer::{flatten_group, ExperienceBuffer, ReadyGroup};

use anyhow::{bail, Result};

use crate::sampling::SamplingBackend;
use crate::serving::{Request, SchedStats, Scheduler, SlotEngine};
use crate::telemetry;

/// Derive one request's RNG-stream seed from the rollout base seed and the
/// request id (splitmix-style odd-multiplier scramble so consecutive ids
/// land in unrelated streams, then XOR with the base).
pub fn request_seed(base: u64, id: u64) -> u64 {
    base ^ id.wrapping_mul(0x9e3779b97f4a7c15).rotate_left(31)
}

/// Derive one rollout round's base seed from a training-level seed and the
/// round (PPO iteration) index. Request ids restart at 0 every rollout, so
/// without this a trainer would replay the exact same draws each iteration
/// — near-identical responses for repeated prompts under slowly-moving
/// params, i.e. correlated experience. Round 0 is the training seed itself
/// (a single rollout replays exactly under the bare seed), and a fixed
/// `(seed, round)` pair is always replayable.
pub fn round_seed(seed: u64, round: u64) -> u64 {
    seed ^ round.wrapping_mul(0xd1342543de82ef95).rotate_left(17)
}

/// Drives one rollout: oversubscribe the scheduler with a prompt queue,
/// stream completions into an [`ExperienceBuffer`], and hand each ready
/// group (with the engine, for scoring) to the caller's callback.
pub struct RolloutEngine {
    /// Base seed of the per-request stream derivation.
    pub seed: u64,
    /// Fused decode steps per scheduler tick (`1` = stepwise; `N > 1`
    /// dispatches the `decode_chunk{N}` artifacts — needs the device-RNG
    /// backend and a paged engine, checked when the rollout starts).
    pub decode_chunk: usize,
}

impl RolloutEngine {
    pub fn new(seed: u64) -> Self {
        RolloutEngine { seed, decode_chunk: 1 }
    }

    /// Flush experience in fused N-token decode chunks: every scheduler
    /// tick advances all live slots by up to `n` tokens in one artifact
    /// dispatch, so generation — the paper's dominant Step-3 cost — pays
    /// ~1/n the dispatch overhead. Retirement (and therefore group
    /// flushing) moves to every-n-step boundaries; completions and their
    /// token streams are unchanged because the per-request device-RNG
    /// streams are chunking-independent.
    pub fn with_decode_chunk(mut self, n: usize) -> Self {
        self.decode_chunk = n;
        self
    }

    /// Generate `prompts.len()` sequences (per-request budgets in
    /// `budgets`, each capped at the engine's `max_new_tokens`) through the
    /// slot scheduler, flushing scored-ready groups of `group` completions
    /// to `on_group` in group order. Returns the scheduler counters
    /// (occupancy, bubbles, retirement mix) for the caller's logs/bench.
    ///
    /// `engine` is any [`SlotEngine`] — `&mut HybridEngine` for real
    /// rollouts (the borrow ends when this returns), a mock in tests.
    pub fn run<E, F>(
        &self,
        engine: E,
        backend: &mut dyn SamplingBackend,
        prompts: &[Vec<i32>],
        budgets: &[usize],
        group: usize,
        mut on_group: F,
    ) -> Result<SchedStats>
    where
        E: SlotEngine,
        F: FnMut(&mut E, ReadyGroup) -> Result<()>,
    {
        let n = prompts.len();
        if group == 0 || n == 0 || n % group != 0 {
            bail!(
                "rollout wants a positive multiple of the group size {group}, got {n} prompts"
            );
        }
        if budgets.len() != n {
            bail!("rollout wants {n} budgets, got {}", budgets.len());
        }
        let mut sched = Scheduler::new(engine)?;
        if self.decode_chunk != 1 {
            sched.set_decode_chunk(self.decode_chunk)?;
        }
        // The scheduler adopted the engine's telemetry handle; the rollout
        // phase span (and the score spans around group flushes) land on
        // the pipeline tracks of the same timeline.
        let tel = sched.telemetry().clone();
        tel.begin(telemetry::TID_ROLLOUT, "rollout", self.seed, n as i64);
        let mut buf = ExperienceBuffer::new(n, group);
        // Oversubscribe up front: the queue is the scheduler's to drain —
        // every EOS retirement admits the next prompt at a step boundary.
        for (id, prompt) in prompts.iter().enumerate() {
            sched.submit(Request {
                id: id as u64,
                prompt: prompt.clone(),
                max_new: budgets[id],
                seed: Some(request_seed(self.seed, id as u64)),
                prefix_len: 0,
            })?;
        }
        while !sched.is_idle() {
            sched.step_into(backend, &mut buf)?;
            // Flush every group that closed this step before decoding on —
            // scoring overlaps the remaining sequences' generation.
            while let Some(g) = buf.pop_ready() {
                let gi = g.index as u64;
                tel.begin(telemetry::TID_SCORE, "score", gi, g.completions.len() as i64);
                let r = on_group(&mut sched.engine, g);
                tel.end(telemetry::TID_SCORE, "score", gi, if r.is_ok() { 1 } else { 0 });
                r?;
            }
        }
        tel.end(telemetry::TID_ROLLOUT, "rollout", self.seed, sched.stats.completed as i64);
        debug_assert!(buf.is_drained(), "scheduler idle with unflushed groups");
        Ok(sched.stats.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::Vocab;
    use crate::sampling::{HostFullRow, PendingRow, SampleOut, SamplerConfig};
    use crate::serving::{Admission, AdmitOutcome, DecodeBatch};
    use anyhow::Result;

    const VOCAB: usize = 32;
    const SP: usize = 4;
    const SG: usize = 8;
    const CONTENT: i32 = 9;

    /// Scripted engine (the serving tests' convention): a prompt's first
    /// token encodes how many content tokens precede EOS; `flat` rows make
    /// sampling purely RNG-driven instead.
    struct MockEngine {
        n_slots: usize,
        flat: bool,
        plans: Vec<Option<(Vec<i32>, usize)>>,
        /// Slot of every admission, in admission order.
        prefills: Vec<usize>,
    }

    impl MockEngine {
        fn new(n_slots: usize) -> Self {
            MockEngine {
                n_slots,
                flat: false,
                plans: (0..n_slots).map(|_| None).collect(),
                prefills: Vec::new(),
            }
        }

        fn flat(mut self) -> Self {
            self.flat = true;
            self
        }

        fn logits_for(&self, tok: i32) -> Vec<f32> {
            if self.flat {
                return vec![0.0; VOCAB]; // uniform: the sampler's rng decides
            }
            let mut row = vec![0.0f32; VOCAB];
            row[tok as usize] = 1.0;
            row
        }
    }

    impl SlotEngine for MockEngine {
        fn n_slots(&self) -> usize {
            self.n_slots
        }

        fn prompt_len(&self) -> usize {
            SP
        }

        fn max_new_tokens(&self) -> usize {
            SG
        }

        fn supports_padded_prompts(&self) -> bool {
            true // the scripted plans work at any prompt length
        }

        fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
            assert!(self.plans[slot].is_none(), "prefill into busy slot {slot}");
            let n = adm.prompt[0] as usize;
            let plan: Vec<i32> = (0..SG + 2)
                .map(|j| if j < n { CONTENT } else { Vocab::EOS })
                .collect();
            let row = PendingRow::Logits(self.logits_for(plan[0]));
            self.plans[slot] = Some((plan, 1));
            self.prefills.push(slot);
            Ok(AdmitOutcome::cold(row))
        }

        fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
            let mut data = vec![0.0f32; self.n_slots * VOCAB];
            for slot in 0..self.n_slots {
                if !batch.active[slot] {
                    continue;
                }
                let (plan, cur) = self.plans[slot].as_mut().expect("active free slot");
                let row = self.flat.then(|| vec![0.0; VOCAB]).unwrap_or_else(|| {
                    let mut r = vec![0.0f32; VOCAB];
                    r[plan[*cur] as usize] = 1.0;
                    r
                });
                *cur += 1;
                data[slot * VOCAB..(slot + 1) * VOCAB].copy_from_slice(&row);
            }
            Ok(SampleOut::Logits { data, vocab: VOCAB })
        }

        fn release_slot(&mut self, slot: usize) -> Result<()> {
            assert!(self.plans[slot].is_some(), "release of free slot {slot}");
            self.plans[slot] = None;
            Ok(())
        }
    }

    fn greedy() -> HostFullRow {
        HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, 0)
    }

    /// Prompt whose scripted response is `eos_after` content tokens + EOS.
    fn prompt(eos_after: i32) -> Vec<i32> {
        prompt_n(eos_after, SP)
    }

    /// Same, with an explicit TRUE prompt length (mixed-length rollouts).
    fn prompt_n(eos_after: i32, len: usize) -> Vec<i32> {
        let mut p = vec![CONTENT; len];
        p[0] = eos_after;
        p
    }

    #[test]
    fn oversubscribed_rollout_retires_then_admits() {
        // 6 prompts through 2 slots: the queue oversubscribes the engine
        // 3x, every retirement frees a slot for the next prompt, and all
        // groups flush in order.
        let prompts: Vec<Vec<i32>> = vec![
            prompt(1),
            prompt(100), // length-capped straggler
            prompt(2),
            prompt(1),
            prompt(3),
            prompt(1),
        ];
        let budgets = vec![SG; 6];
        let mut flushed: Vec<(usize, Vec<u64>)> = Vec::new();
        let stats = RolloutEngine::new(0)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &budgets, 2, |eng, g| {
                assert!(eng.n_slots() == 2, "callback sees the engine");
                flushed.push((g.index, g.completions.iter().map(|c| c.id).collect()));
                Ok(())
            })
            .unwrap();
        assert_eq!(
            flushed,
            vec![(0, vec![0, 1]), (1, vec![2, 3]), (2, vec![4, 5])],
            "static groups, in-order flushes"
        );
        assert_eq!(stats.completed, 6);
        assert_eq!(stats.retired_eos, 5);
        assert_eq!(stats.retired_length, 1, "the straggler hits its budget");
        // Oversubscription actually happened: 6 admissions through 2 slots.
        assert_eq!(stats.prefills, 6);
        assert!(stats.peak_queue_depth >= 4);
        assert!(stats.utilization() > 0.5, "{}", stats.utilization());
        assert!((stats.bubble_fraction() - (1.0 - stats.utilization())).abs() < 1e-12);
    }

    #[test]
    fn straggler_never_blocks_later_groups_generation() {
        // Group 0 holds a straggler (id 1 runs to SG); ids 2..6 all EOS
        // after one token. The engine must keep admitting and retiring the
        // later prompts while group 0 stays open — pinned by the flush
        // order (groups 1+ close first internally but still flush after
        // group 0) and by prefill count reaching n well before idle.
        let prompts: Vec<Vec<i32>> =
            vec![prompt(1), prompt(100), prompt(1), prompt(1), prompt(1), prompt(1)];
        let budgets = vec![SG; 6];
        let mut order = Vec::new();
        let stats = RolloutEngine::new(0)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &budgets, 3, |_, g| {
                order.push(g.index);
                Ok(())
            })
            .unwrap();
        assert_eq!(order, vec![0, 1]);
        assert_eq!(stats.prefills, 6, "all prompts admitted despite the open group");
        // The straggler decoded SG tokens; the rest one content + EOS each.
        assert_eq!(stats.tokens_sampled, (SG + 5 * 2) as u64);
    }

    #[test]
    fn seed_derivations_separate_requests_and_rounds() {
        // Distinct ids and distinct rounds land in distinct streams; round
        // 0 is the bare training seed (single-rollout replays unchanged).
        assert_ne!(request_seed(5, 0), request_seed(5, 1));
        assert_ne!(request_seed(5, 1), request_seed(6, 1));
        assert_ne!(round_seed(5, 0), round_seed(5, 1));
        assert_ne!(round_seed(5, 1), round_seed(5, 2));
        assert_eq!(round_seed(5, 0), 5);
    }

    #[test]
    fn mixed_length_rollout_groups_preserve_true_lengths() {
        // Variable-length prompts through the rollout: every flushed
        // completion carries its TRUE prompt length and unpadded tokens,
        // and `flatten_group` lays each row out from its true length —
        // the boundary `score_experience`/PPO masks rely on.
        let prompts: Vec<Vec<i32>> = vec![
            prompt_n(1, SP),     // exact length
            prompt_n(2, 2),      // short
            prompt_n(1, SP - 1), // short
            prompt_n(3, 1),      // shortest admissible
        ];
        let budgets = vec![SG; 4];
        let s = SP + SG;
        let mut flushed = 0usize;
        RolloutEngine::new(0)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &budgets, 2, |_, g| {
                let (tokens, resp_lens, prompt_lens) = flatten_group(&g, s);
                for (i, c) in g.completions.iter().enumerate() {
                    let want_plen = prompts[c.id as usize].len();
                    assert_eq!(c.prompt_len, want_plen, "req {}", c.id);
                    assert_eq!(prompt_lens[i], want_plen);
                    assert_eq!(resp_lens[i], c.generated);
                    let row = &tokens[i * s..(i + 1) * s];
                    assert_eq!(&row[..c.tokens.len()], c.tokens.as_slice());
                    assert!(
                        row[c.tokens.len()..].iter().all(|&t| t == Vocab::PAD),
                        "row {} padded after its true tokens",
                        i
                    );
                    flushed += 1;
                }
                Ok(())
            })
            .unwrap();
        assert_eq!(flushed, 4);
    }

    #[test]
    fn rollout_size_must_be_group_multiple() {
        let prompts = vec![prompt(1); 3];
        let err = RolloutEngine::new(0)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &[SG; 3], 2, |_, _| Ok(()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("multiple"), "{err:#}");
        let err = RolloutEngine::new(0)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &[SG; 2], 3, |_, _| Ok(()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("budgets"), "{err:#}");
    }

    #[test]
    fn per_request_streams_survive_admission_reordering() {
        // Stochastic sampling over flat rows is purely RNG-driven, so this
        // pins the seed-derivation contract: request id 0 with base seed s
        // generates the same tokens whether it rolls out alone or packed
        // with five other requests whose retirements reshuffle every
        // admission — and a different base seed moves it.
        let stochastic =
            || HostFullRow::new(SamplerConfig { temperature: 1.0, ..Default::default() }, 555);
        let run = |n: usize, base: u64| -> Vec<Vec<i32>> {
            let prompts: Vec<Vec<i32>> = (0..n).map(|_| prompt(100)).collect();
            let budgets = vec![SG; n];
            let mut seqs: Vec<Vec<i32>> = Vec::new();
            RolloutEngine::new(base)
                .run(
                    MockEngine::new(2).flat(),
                    &mut stochastic(),
                    &prompts,
                    &budgets,
                    n,
                    |_, g| {
                        seqs = g.completions.iter().map(|c| c.tokens.clone()).collect();
                        Ok(())
                    },
                )
                .unwrap();
            seqs
        };
        let solo = run(1, 7);
        let crowd = run(6, 7);
        assert_eq!(solo[0], crowd[0], "request 0's stream is its own");
        let other_base = run(1, 8);
        assert_ne!(solo[0], other_base[0], "base seed steers every stream");
    }

    #[test]
    fn chunked_rollout_checks_capability_up_front() {
        // The arena mock has no decode_chunk artifacts: a chunked rollout
        // must refuse at startup (before any admission), not melt down
        // tick by tick — and chunk 1 stays the unchanged stepwise path.
        let prompts = vec![prompt(1), prompt(2)];
        let err = RolloutEngine::new(0)
            .with_decode_chunk(4)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &[SG; 2], 2, |_, _| Ok(()))
            .unwrap_err();
        assert!(format!("{err:#}").contains("decode_chunk"), "{err:#}");
        let stats = RolloutEngine::new(0)
            .with_decode_chunk(1)
            .run(MockEngine::new(2), &mut greedy(), &prompts, &[SG; 2], 2, |_, _| Ok(()))
            .unwrap();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn rollout_over_borrowed_engine_compiles_and_runs() {
        // The &mut E SlotEngine impl: run a rollout over a borrow, then
        // keep using the engine afterwards (the coordinator's shape).
        let mut eng = MockEngine::new(2);
        let prompts = vec![prompt(1), prompt(2)];
        let stats = RolloutEngine::new(0)
            .run(&mut eng, &mut greedy(), &prompts, &[SG; 2], 2, |e, g| {
                // Callback sees &mut &mut MockEngine.
                assert_eq!(e.n_slots(), 2);
                assert_eq!(g.completions.len(), 2);
                Ok(())
            })
            .unwrap();
        assert_eq!(stats.completed, 2);
        assert_eq!(eng.prefills.len(), 2, "the borrow handed the engine back");
    }
}
