//! The experience buffer: where scheduler completions land during a
//! rollout, and where they are regrouped into the fixed-size batches the
//! scoring/training artifacts want.
//!
//! # Grouping and flush contract
//!
//! The buffer is sized for one rollout of `total` requests partitioned into
//! `total / group` **static** groups by request id: group `g` owns ids
//! `[g*group, (g+1)*group)`. Completions arrive in retirement order — which
//! is data-dependent and may interleave across groups — but a group flushes
//! only when all of its members have retired, and groups flush **in index
//! order**. Static grouping plus in-order flushing is what makes the
//! training stream reproducible: which rows share a PPO batch, and the
//! order batches reach the optimizer, depend only on the submission order,
//! never on which sequence happened to hit EOS first. (Generation is never
//! blocked by a straggler — later groups keep decoding while an earlier
//! group waits to flush.)
//!
//! [`flatten_group`] lays a ready group back out as the `[group, seq_len]`
//! row-major token batch the fixed-shape artifacts expect: row `i` is the
//! group's `i`-th request (ascending id) padded with [`Vocab::PAD`] after
//! its last generated token — exactly the layout the fixed-batch
//! `HybridEngine::generate` leaves, which is what lets the greedy golden
//! compare the two paths bit for bit.

use crate::data::synthetic::Vocab;
use crate::serving::{Completion, CompletionSink};

/// One flushed group: `group` completions sorted by ascending request id.
#[derive(Debug)]
pub struct ReadyGroup {
    /// Group index within the rollout (flushes arrive in this order).
    pub index: usize,
    pub completions: Vec<Completion>,
}

/// Collects out-of-order completions and hands back ready groups in order.
pub struct ExperienceBuffer {
    group: usize,
    /// One slot per request id; `Some` once retired, taken at flush.
    entries: Vec<Option<Completion>>,
    /// Retired-member count per group.
    filled: Vec<usize>,
    /// Next group index to flush (groups flush strictly in order).
    next_flush: usize,
}

impl ExperienceBuffer {
    /// Buffer for `total` requests flushed in groups of `group`.
    /// `total` must be a positive multiple of `group`.
    pub fn new(total: usize, group: usize) -> Self {
        assert!(group > 0, "group size must be positive");
        assert!(
            total > 0 && total % group == 0,
            "rollout size {total} must be a positive multiple of the group size {group}"
        );
        ExperienceBuffer {
            group,
            entries: (0..total).map(|_| None).collect(),
            filled: vec![0; total / group],
            next_flush: 0,
        }
    }

    /// Record one retired sequence. Ids outside the rollout or retired
    /// twice are scheduler bugs, not recoverable states.
    pub fn push(&mut self, c: Completion) {
        let id = c.id as usize;
        assert!(id < self.entries.len(), "completion id {id} outside rollout");
        assert!(self.entries[id].is_none(), "request {id} retired twice");
        self.filled[id / self.group] += 1;
        self.entries[id] = Some(c);
    }

    /// Take the next in-order group whose members have all retired.
    pub fn pop_ready(&mut self) -> Option<ReadyGroup> {
        if self.next_flush >= self.filled.len() || self.filled[self.next_flush] < self.group {
            return None;
        }
        let index = self.next_flush;
        self.next_flush += 1;
        let lo = index * self.group;
        let completions: Vec<Completion> = self.entries[lo..lo + self.group]
            .iter_mut()
            .map(|e| e.take().expect("filled count lied"))
            .collect();
        Some(ReadyGroup { index, completions })
    }

    /// Completions held but not yet flushed.
    pub fn pending(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    /// True once every group has been flushed.
    pub fn is_drained(&self) -> bool {
        self.next_flush == self.filled.len()
    }
}

impl CompletionSink for ExperienceBuffer {
    fn complete(&mut self, c: Completion) {
        self.push(c);
    }
}

/// Flatten a ready group into the `[group, seq_len]` row-major token batch
/// plus per-row response lengths (generated tokens, EOS included when
/// emitted — the scheduler retires at the first EOS, so this matches
/// `PpoTrainer::response_len` over the padded row) and per-row TRUE prompt
/// lengths. Completions carry unpadded tokens (the scheduler strips the
/// admission-time left-padding before they ever reach the buffer), so row
/// `i` is the true sequence — prompt of `prompt_lens[i]` tokens, then the
/// response — RIGHT-padded with [`Vocab::PAD`] to `seq_len`: exactly the
/// layout the fixed-batch `generate` leaves for exact-length prompts, and
/// what the scoring forwards expect (causal attention makes the trailing
/// pads inert, and the per-row prompt lengths tell PPO where each row's
/// response region really starts).
pub fn flatten_group(g: &ReadyGroup, seq_len: usize) -> (Vec<i32>, Vec<usize>, Vec<usize>) {
    let b = g.completions.len();
    let mut tokens = vec![Vocab::PAD; b * seq_len];
    let mut resp_lens = Vec::with_capacity(b);
    let mut prompt_lens = Vec::with_capacity(b);
    for (i, c) in g.completions.iter().enumerate() {
        assert!(
            c.tokens.len() <= seq_len,
            "completion {} has {} tokens, seq_len {seq_len}",
            c.id,
            c.tokens.len()
        );
        tokens[i * seq_len..i * seq_len + c.tokens.len()].copy_from_slice(&c.tokens);
        resp_lens.push(c.generated);
        prompt_lens.push(c.prompt_len);
    }
    (tokens, resp_lens, prompt_lens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::FinishReason;

    fn comp(id: u64, prompt_len: usize, generated: usize) -> Completion {
        let mut tokens: Vec<i32> = vec![9; prompt_len + generated];
        // Mark the last generated token EOS so flatten's layout is testable.
        *tokens.last_mut().unwrap() = Vocab::EOS;
        Completion {
            id,
            slot: 0,
            prompt_len,
            tokens,
            generated,
            finish: FinishReason::Eos,
            queued_steps: 0,
            decode_steps: generated as u64,
        }
    }

    #[test]
    fn groups_flush_in_order_despite_out_of_order_completion() {
        let mut buf = ExperienceBuffer::new(4, 2);
        // Group 1 (ids 2,3) finishes entirely before group 0 closes.
        buf.push(comp(2, 4, 3));
        buf.push(comp(3, 4, 1));
        buf.push(comp(1, 4, 2));
        assert!(buf.pop_ready().is_none(), "group 0 still missing id 0");
        assert_eq!(buf.pending(), 3);
        buf.push(comp(0, 4, 5));
        let g0 = buf.pop_ready().unwrap();
        assert_eq!(g0.index, 0);
        assert_eq!(g0.completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![0, 1]);
        let g1 = buf.pop_ready().unwrap();
        assert_eq!(g1.index, 1);
        assert_eq!(g1.completions.iter().map(|c| c.id).collect::<Vec<_>>(), vec![2, 3]);
        assert!(buf.pop_ready().is_none());
        assert!(buf.is_drained());
        assert_eq!(buf.pending(), 0);
    }

    #[test]
    fn flush_boundary_is_exact() {
        // A group flushes on its b-th member, not one completion earlier.
        let b = 3;
        let mut buf = ExperienceBuffer::new(3, b);
        buf.push(comp(0, 4, 1));
        buf.push(comp(2, 4, 1));
        assert!(buf.pop_ready().is_none());
        buf.push(comp(1, 4, 1));
        assert_eq!(buf.pop_ready().unwrap().completions.len(), b);
    }

    #[test]
    #[should_panic(expected = "retired twice")]
    fn duplicate_completion_is_a_bug() {
        let mut buf = ExperienceBuffer::new(2, 2);
        buf.push(comp(0, 4, 1));
        buf.push(comp(0, 4, 1));
    }

    #[test]
    fn flatten_pads_rows_to_seq_len() {
        let mut buf = ExperienceBuffer::new(2, 2);
        buf.push(comp(0, 4, 2)); // 6 real tokens
        buf.push(comp(1, 4, 4)); // 8 real tokens
        let g = buf.pop_ready().unwrap();
        let s = 10;
        let (tokens, resp_lens, prompt_lens) = flatten_group(&g, s);
        assert_eq!(tokens.len(), 2 * s);
        assert_eq!(resp_lens, vec![2, 4]);
        assert_eq!(prompt_lens, vec![4, 4]);
        // Row 0: 6 real tokens then PAD to seq_len.
        assert_eq!(tokens[5], Vocab::EOS);
        assert!(tokens[6..s].iter().all(|&t| t == Vocab::PAD));
        // Row 1 starts at s with its own tokens.
        assert_eq!(tokens[s + 7], Vocab::EOS);
        assert!(tokens[s + 8..2 * s].iter().all(|&t| t == Vocab::PAD));
    }

    #[test]
    fn flatten_preserves_mixed_true_prompt_lengths() {
        // Variable-length prompts: each row's true prompt length rides out
        // of the flatten so PPO masks see real response boundaries.
        let mut buf = ExperienceBuffer::new(2, 2);
        buf.push(comp(0, 2, 3)); // 2-token prompt, 3 generated
        buf.push(comp(1, 7, 1)); // 7-token prompt, 1 generated
        let g = buf.pop_ready().unwrap();
        let s = 12;
        let (tokens, resp_lens, prompt_lens) = flatten_group(&g, s);
        assert_eq!(prompt_lens, vec![2, 7]);
        assert_eq!(resp_lens, vec![3, 1]);
        // Row layouts start at the TRUE lengths, not a fixed prompt_len.
        assert_eq!(tokens[4], Vocab::EOS, "row 0: prompt 2 + gen 3 ends at index 4");
        assert!(tokens[5..s].iter().all(|&t| t == Vocab::PAD));
        assert_eq!(tokens[s + 7], Vocab::EOS, "row 1: prompt 7 + gen 1 ends at index 7");
        assert!(tokens[s + 8..2 * s].iter().all(|&t| t == Vocab::PAD));
    }
}
