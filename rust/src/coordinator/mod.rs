//! The PPO coordinator: orchestrates one Step-3 RLHF iteration end to end —
//! the `generate_experience` / `train_rlhf` loop of the paper's §2.3 API —
//! on top of the hybrid engine.
//!
//! Each iteration:
//!   1. **Experience** (inference mode): sample prompts, generate
//!      responses, score them with the frozen RM, collect old/ref
//!      log-probs + values. Two paths share the scoring/shaping tail:
//!      * **fixed-batch** (`rollout_batch == 0`): exactly `b` prompts
//!        through `HybridEngine::generate` in lockstep — every slot decodes
//!        until the slowest row finishes; the pre-rollout behavior, kept as
//!        the golden baseline.
//!      * **scheduler rollout** (`rollout_batch = k·b`): the prompt queue
//!        oversubscribes the continuous-batching `serving::Scheduler` via
//!        [`crate::rollout::RolloutEngine`] — EOS-retired rows free their
//!        KV slot for the next prompt at the following step boundary, and
//!        the `ExperienceBuffer` flushes one scored [`Experience`] per `b`
//!        completions (scoring overlaps the remaining sequences'
//!        generation; training runs after the rollout drains, so the
//!        serving cache is never flipped away mid-flight). Each request
//!        samples from its own derived RNG stream, so the rollout is
//!        reproducible despite admission-order nondeterminism.
//!   2. **Shaping** (rust): KL-penalized per-token rewards, GAE advantages
//!      and returns, optional whitening.
//!   3. **Training** (train mode): per flushed experience batch,
//!      `ppo_epochs` of clipped actor + critic updates over the staged
//!      (upload-once) tensors, optional mixture (ptx) loss, optional EMA
//!      collection.
//!
//! # Anomaly guard (training-layer fault tolerance)
//!
//! Large-scale PPO diverges in recognizable ways — a NaN loss, a KL
//! blowup, a clip fraction pinned at 1 — and by the time the symptom is
//! visible the params are already poisoned (ChatGLM-RLHF documents this
//! stabilization machinery as a *requirement* at scale). The
//! [`AnomalyGuard`] validates every iteration's [`IterStats`];
//! [`PpoTrainer::iteration_guarded`] snapshots actor/critic/optimizer/EMA
//! state before each iteration, and on a trip restores the snapshot,
//! rewinds the EMA phase, and re-rolls the iteration — the rollout-round
//! counter does NOT rewind, so the retry draws fresh experience under a
//! perturbed round seed instead of replaying the draws that diverged.
//! After [`PpoConfig::max_guard_trips`] consecutive trips it bails loudly
//! rather than looping on a divergent run.

pub mod gae;

use anyhow::{bail, Result};

use crate::config::PpoConfig;
use crate::data::synthetic::{TaskGen, Vocab};
use crate::data::{Blend, Prompt};
use crate::hybrid::{ExperienceScores, HybridEngine};
use crate::rollout::{flatten_group, round_seed, RolloutEngine};
use crate::sampling::{HostFullRow, SamplerConfig, SamplingBackend};
use crate::serving::SchedStats;
use crate::util::rng::Rng;

/// One experience batch, fully scored and shaped. Rows may carry prompts
/// of DIFFERENT true lengths (the scheduler rollout admits variable-length
/// prompts): `prompt_lens[i]` is row i's real prompt boundary, and the
/// response mask/advantages/returns are laid out per row from it — never
/// from the artifact's fixed `prompt_len`.
#[derive(Debug, Clone)]
pub struct Experience {
    pub tokens: Vec<i32>,        // [b, s]
    pub old_logp: Vec<f32>,      // [b, s-1]
    pub advantages: Vec<f32>,    // [b, s-1] (masked)
    pub returns: Vec<f32>,       // [b, s-1]
    pub old_values: Vec<f32>,    // [b, s-1]
    pub mask: Vec<f32>,          // [b, s-1] response-region mask
    pub rm_scores: Vec<f32>,     // [b]
    pub true_rewards: Vec<f32>,  // [b] ground-truth task reward
    pub mean_kl: f32,
    pub resp_lens: Vec<usize>,   // [b]
    pub prompt_lens: Vec<usize>, // [b] TRUE per-row prompt lengths
}

/// Scalars logged per PPO iteration. With a multi-group rollout
/// (`rollout_batch > b`) the reward/loss scalars are means across the
/// iteration's flushed experience batches.
#[derive(Debug, Clone, Default)]
pub struct IterStats {
    pub rm_score: f32,
    pub true_reward: f32,
    pub kl_to_ref: f32,
    pub actor_loss: f32,
    pub critic_loss: f32,
    pub approx_kl: f32,
    pub clipfrac: f32,
    pub gen_secs: f64,
    pub train_secs: f64,
    pub gen_tokens: u64,
    /// Fraction of decode slot capacity burned on dead rows during the
    /// rollout (0.0 on the fixed-batch path, which has no such ledger).
    pub rollout_bubble: f64,
    /// Experience batches trained this iteration (1 on the fixed path).
    pub rollout_groups: usize,
}

/// Per-iteration training-health validator (see the module docs). Built
/// from [`PpoConfig`] thresholds; non-finite stats always trip it.
#[derive(Debug, Clone)]
pub struct AnomalyGuard {
    /// Trip when |approx_kl| exceeds this (0 disables the threshold).
    pub max_approx_kl: f32,
    /// Trip when clipfrac exceeds this (0 disables).
    pub max_clipfrac: f32,
}

impl AnomalyGuard {
    pub fn from_cfg(cfg: &PpoConfig) -> Self {
        AnomalyGuard { max_approx_kl: cfg.max_approx_kl, max_clipfrac: cfg.max_clipfrac }
    }

    /// `None` = healthy; `Some(reason)` names the first anomaly found.
    pub fn validate(&self, st: &IterStats) -> Option<String> {
        let finite = [
            ("actor_loss", st.actor_loss),
            ("critic_loss", st.critic_loss),
            ("approx_kl", st.approx_kl),
            ("clipfrac", st.clipfrac),
            ("rm_score", st.rm_score),
            ("kl_to_ref", st.kl_to_ref),
        ];
        for (name, v) in finite {
            if !v.is_finite() {
                return Some(format!("non-finite {name} ({v})"));
            }
        }
        if self.max_approx_kl > 0.0 && st.approx_kl.abs() > self.max_approx_kl {
            return Some(format!(
                "approx_kl {} exceeds the {} trust-region threshold",
                st.approx_kl, self.max_approx_kl
            ));
        }
        if self.max_clipfrac > 0.0 && st.clipfrac > self.max_clipfrac {
            return Some(format!(
                "clipfrac {} exceeds the {} off-policy threshold",
                st.clipfrac, self.max_clipfrac
            ));
        }
        None
    }
}

pub struct PpoTrainer {
    pub cfg: PpoConfig,
    /// Sampling backend driving experience generation. Defaults to the
    /// host full-row backend (bit-identical to the pre-refactor trainer);
    /// [`PpoTrainer::with_backend`] swaps in e.g. `DeviceTopK` to cut the
    /// generation phase's per-step host traffic to O(b·k).
    pub sampler: Box<dyn SamplingBackend>,
    /// Training-level seed of the scheduler rollout's RNG streams: round
    /// `r`'s base is `rollout::round_seed(rollout_seed, r)` and each
    /// request's stream is `rollout::request_seed(base, id)` — so
    /// iterations never replay each other's draws, while a fixed
    /// `(rollout_seed, round, id)` triple stays replayable.
    pub rollout_seed: u64,
    /// The training-health validator [`PpoTrainer::iteration_guarded`]
    /// runs over every iteration's stats.
    pub guard: AnomalyGuard,
    /// Guard trips across the whole run (diagnostic; never reset).
    pub guard_trips: u64,
    /// Rollout rounds completed (drives the per-round seed derivation).
    rollouts_done: u64,
    /// Completed training calls (drives the EMA interval).
    iters_done: usize,
    /// Guarded iterations ACCEPTED so far (rollback re-rolls do not
    /// advance this — it indexes the chaos-drill fault injection).
    guarded_iters: usize,
    /// Consecutive guard trips (reset by any healthy iteration).
    consecutive_trips: usize,
    /// One-shot chaos-drill fault still waiting to fire.
    fault_pending: bool,
}

impl PpoTrainer {
    pub fn new(cfg: PpoConfig, seed: u64) -> Self {
        let sampler = HostFullRow::new(
            SamplerConfig {
                temperature: cfg.temperature,
                top_k: cfg.top_k,
                top_p: cfg.top_p,
                ..Default::default()
            },
            seed,
        );
        Self::with_backend(cfg, Box::new(sampler), seed)
    }

    /// Build a trainer around an explicit sampling backend; `seed` anchors
    /// the rollout path's per-request stream derivation.
    pub fn with_backend(cfg: PpoConfig, sampler: Box<dyn SamplingBackend>, seed: u64) -> Self {
        let guard = AnomalyGuard::from_cfg(&cfg);
        let fault_pending = cfg.fault_iteration.is_some();
        PpoTrainer {
            cfg,
            sampler,
            rollout_seed: seed,
            guard,
            guard_trips: 0,
            rollouts_done: 0,
            iters_done: 0,
            guarded_iters: 0,
            consecutive_trips: 0,
            fault_pending,
        }
    }

    /// Phase counters `(rollouts_done, iters_done)` for the durable
    /// checkpoint — the rollout-seed derivation round and the EMA-interval
    /// phase a resumed run must continue from.
    pub fn progress(&self) -> (u64, usize) {
        (self.rollouts_done, self.iters_done)
    }

    /// Restore the phase counters saved by [`PpoTrainer::progress`] (the
    /// `dschat train --resume` path).
    pub fn set_progress(&mut self, rollouts_done: u64, iters_done: usize) {
        self.rollouts_done = rollouts_done;
        self.iters_done = iters_done;
        self.guarded_iters = iters_done;
    }

    /// Find the response length (tokens up to and including EOS, capped at
    /// gen_len) for one generated row.
    pub fn response_len(seq: &[i32], prompt_len: usize) -> usize {
        let gen = &seq[prompt_len..];
        for (i, &t) in gen.iter().enumerate() {
            if t == Vocab::EOS {
                return i + 1;
            }
        }
        gen.len()
    }

    /// Phase 1+2, fixed-batch path: generate exactly `b` prompts in
    /// lockstep through `HybridEngine::generate` and fully score the
    /// batch. The scheduler rollout
    /// ([`PpoTrainer::generate_experience_rollout`]) lifts the `n == b`
    /// restriction.
    pub fn generate_experience(
        &mut self,
        he: &mut HybridEngine,
        prompts: &[(TaskGen, Prompt)],
    ) -> Result<Experience> {
        let m = he.manifest();
        let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
        if prompts.len() != b {
            bail!(
                "fixed-batch generate_experience wants exactly the artifact batch of {b} \
                 prompts, got {} — set rollout_batch (a multiple of {b}) to roll larger \
                 prompt queues through the continuous-batching scheduler",
                prompts.len()
            );
        }

        let mut flat_prompts = Vec::with_capacity(b * sp);
        for (_, p) in prompts {
            flat_prompts.extend_from_slice(&p.tokens);
        }
        let tokens = he.generate(&flat_prompts, self.sampler.as_mut())?;

        // Score: RM reward at last response token; logprobs/values over all.
        // One call so the [b, s] token batch is uploaded once and the
        // device buffer is shared across all four forwards.
        let resp_lens: Vec<usize> =
            (0..b).map(|i| Self::response_len(&tokens[i * s..(i + 1) * s], sp)).collect();
        let lens: Vec<i32> = resp_lens.iter().map(|&l| (sp + l - 1) as i32).collect();
        let scores = he.score_experience(&tokens, &lens)?;
        Ok(assemble_experience(
            &self.cfg,
            prompts,
            tokens,
            resp_lens,
            scores,
            &vec![sp; b],
            s,
        ))
    }

    /// Phase 1+2, scheduler-rollout path: stream `n = k·b` prompts through
    /// the continuous-batching scheduler and return the `k` scored
    /// [`Experience`] batches (in static group order) plus the rollout's
    /// slot-occupancy counters. Scoring runs as each group of `b`
    /// completions closes, overlapping the remaining sequences'
    /// generation; the caller trains afterwards (training flips the engine
    /// to train mode, which would free the serving KV cache mid-rollout).
    pub fn generate_experience_rollout(
        &mut self,
        he: &mut HybridEngine,
        prompts: &[(TaskGen, Prompt)],
    ) -> Result<(Vec<Experience>, SchedStats)> {
        let m = he.manifest();
        let (b, sg, s) = (m.batch, m.gen_len, m.seq_len);
        let n = prompts.len();
        if n == 0 || n % b != 0 {
            bail!(
                "rollout_batch must be a positive multiple of the artifact batch {b}, got {n}"
            );
        }
        let prompt_toks: Vec<Vec<i32>> =
            prompts.iter().map(|(_, p)| p.tokens.clone()).collect();
        let budgets = vec![sg; n];
        let cfg = &self.cfg;
        let mut out: Vec<Experience> = Vec::with_capacity(n / b);
        // Fresh per-round base seed: request ids restart at 0 every
        // rollout, so reusing one base would replay the previous round's
        // draws verbatim (correlated experience under slowly-moving
        // params).
        let engine = RolloutEngine::new(round_seed(self.rollout_seed, self.rollouts_done))
            .with_decode_chunk(self.cfg.decode_chunk.max(1));
        self.rollouts_done += 1;
        let stats = engine.run(
            &mut *he,
            self.sampler.as_mut(),
            &prompt_toks,
            &budgets,
            b,
            |eng, group| {
                let (tokens, resp_lens, prompt_lens) = flatten_group(&group, s);
                // RM reward position = each row's TRUE last response token
                // (per-row prompt boundary + response length - 1): mixed
                // prompt lengths mean the boundary is per row, not the
                // artifact constant.
                let lens: Vec<i32> = resp_lens
                    .iter()
                    .zip(&prompt_lens)
                    .map(|(&l, &p)| (p + l - 1) as i32)
                    .collect();
                let scores = eng.score_experience(&tokens, &lens)?;
                let gp = &prompts[group.index * b..(group.index + 1) * b];
                out.push(assemble_experience(
                    cfg, gp, tokens, resp_lens, scores, &prompt_lens, s,
                ));
                Ok(())
            },
        )?;
        Ok((out, stats))
    }

    /// Phase 3: PPO updates (+ mixture + EMA) over one experience batch.
    pub fn train_rlhf(
        &mut self,
        he: &mut HybridEngine,
        exp: &Experience,
        blend: &mut Blend,
        rng: &mut Rng,
        actor_lr: f32,
        critic_lr: f32,
    ) -> Result<IterStats> {
        let tel = he.telemetry.clone();
        let step_id = self.iters_done as u64;
        tel.begin(
            crate::telemetry::TID_TRAIN,
            "train_step",
            step_id,
            self.cfg.ppo_epochs as i64,
        );
        let mut stats = IterStats {
            rm_score: mean(&exp.rm_scores),
            true_reward: mean(&exp.true_rewards),
            kl_to_ref: exp.mean_kl,
            ..Default::default()
        };
        let m = he.manifest();
        let b = m.batch;
        // The experience batch is epoch-constant: stage its tensors on
        // device once and re-feed them, so each additional epoch uploads
        // only a fresh ptx batch + scalars (like score_experience shares
        // its token buffer across the four scoring forwards).
        let staged = he.stage_experience(
            &exp.tokens,
            &exp.old_logp,
            &exp.advantages,
            &exp.returns,
            &exp.old_values,
            &exp.mask,
        )?;
        for _ in 0..self.cfg.ppo_epochs {
            let ptx = blend.ptx_batch(rng, b);
            let out = he.ppo_actor_step_staged(
                &staged,
                &ptx.tokens,
                self.cfg.clip_eps,
                self.cfg.ptx_coef,
                actor_lr,
            )?;
            stats.actor_loss = out.loss;
            stats.approx_kl = out.approx_kl;
            stats.clipfrac = out.clipfrac;
            stats.critic_loss =
                he.ppo_critic_step_staged(&staged, self.cfg.clip_eps, critic_lr)?;
        }
        if let Some(decay) = self.cfg.ema_decay {
            let k = self.cfg.ema_interval.max(1);
            self.iters_done += 1;
            if self.iters_done % k == 0 {
                // decay^k keeps the effective horizon identical to per-iter
                // updates while amortizing the fetch-bound EMA artifact.
                he.ema_update(decay.powi(k as i32))?;
            }
        }
        tel.end(
            crate::telemetry::TID_TRAIN,
            "train_step",
            step_id,
            (stats.actor_loss * 1e6) as i64,
        );
        Ok(stats)
    }

    /// One full PPO iteration (the paper's §2.3 two-call API).
    /// `rollout_batch == 0` keeps the fixed-batch path; `rollout_batch =
    /// k·b` rolls the whole prompt queue through the scheduler, then
    /// trains on each of the `k` flushed experience batches (all generated
    /// under the same pre-update policy — the per-batch `old_logp` keeps
    /// the PPO ratios honest, exactly as multi-epoch updates do).
    pub fn iteration(
        &mut self,
        he: &mut HybridEngine,
        blend: &mut Blend,
        rng: &mut Rng,
        actor_lr: f32,
        critic_lr: f32,
    ) -> Result<IterStats> {
        let b = he.manifest().batch;
        let gen0 = (he.stats.gen_secs, he.stats.gen_tokens, he.stats.train_secs);
        let mut stats = if self.cfg.rollout_batch == 0 {
            let prompts = blend.prompt_batch(rng, b);
            let exp = self.generate_experience(he, &prompts)?;
            let mut st = self.train_rlhf(he, &exp, blend, rng, actor_lr, critic_lr)?;
            st.rollout_groups = 1;
            st
        } else {
            // Heterogeneous prompt lengths (min_prompt_len > 0) draw each
            // prompt's true length per row — the scheduler left-pads them
            // into the fixed artifact shape at admission.
            let prompts = if self.cfg.min_prompt_len > 0 {
                blend.prompt_batch_mixed(rng, self.cfg.rollout_batch, self.cfg.min_prompt_len)
            } else {
                blend.prompt_batch(rng, self.cfg.rollout_batch)
            };
            let (exps, sched) = self.generate_experience_rollout(he, &prompts)?;
            let groups = exps.len();
            let mut agg = IterStats::default();
            for exp in &exps {
                let st = self.train_rlhf(he, exp, blend, rng, actor_lr, critic_lr)?;
                agg.rm_score += st.rm_score;
                agg.true_reward += st.true_reward;
                agg.kl_to_ref += st.kl_to_ref;
                agg.actor_loss += st.actor_loss;
                agg.critic_loss += st.critic_loss;
                agg.approx_kl += st.approx_kl;
                agg.clipfrac += st.clipfrac;
            }
            let k = groups.max(1) as f32;
            agg.rm_score /= k;
            agg.true_reward /= k;
            agg.kl_to_ref /= k;
            agg.actor_loss /= k;
            agg.critic_loss /= k;
            agg.approx_kl /= k;
            agg.clipfrac /= k;
            agg.rollout_bubble = sched.bubble_fraction();
            agg.rollout_groups = groups;
            agg
        };
        stats.gen_secs = he.stats.gen_secs - gen0.0;
        stats.gen_tokens = he.stats.gen_tokens - gen0.1;
        stats.train_secs = he.stats.train_secs - gen0.2;
        Ok(stats)
    }

    /// [`PpoTrainer::iteration`] wrapped in the anomaly guard (see the
    /// module docs): snapshot the training state, run the iteration,
    /// validate its stats; on a trip restore the snapshot, rewind the EMA
    /// phase, and re-roll under the advanced rollout-round seed. Bails
    /// after [`PpoConfig::max_guard_trips`] consecutive trips.
    pub fn iteration_guarded(
        &mut self,
        he: &mut HybridEngine,
        blend: &mut Blend,
        rng: &mut Rng,
        actor_lr: f32,
        critic_lr: f32,
    ) -> Result<IterStats> {
        let snap = he.snapshot_training_state()?;
        let iters0 = self.iters_done;
        loop {
            let mut stats = self.iteration(he, blend, rng, actor_lr, critic_lr)?;
            // Chaos drill (`--fault-iter N`): poison the reported loss once
            // so the rollback path is exercised on an otherwise-healthy run.
            if self.fault_pending && self.cfg.fault_iteration == Some(self.guarded_iters) {
                self.fault_pending = false;
                eprintln!(
                    "[ppo] chaos drill: poisoning iteration {} actor loss with NaN",
                    self.guarded_iters
                );
                stats.actor_loss = f32::NAN;
            }
            match self.guard.validate(&stats) {
                None => {
                    self.consecutive_trips = 0;
                    self.guarded_iters += 1;
                    return Ok(stats);
                }
                Some(why) => {
                    self.consecutive_trips += 1;
                    self.guard_trips += 1;
                    if self.consecutive_trips >= self.cfg.max_guard_trips.max(1) {
                        bail!(
                            "anomaly guard tripped {} consecutive times at iteration {} \
                             (last: {why}) — training has diverged; refusing to keep \
                             rolling back",
                            self.consecutive_trips,
                            self.guarded_iters
                        );
                    }
                    eprintln!(
                        "[ppo] anomaly guard trip {}/{} at iteration {}: {why} — \
                         restoring last-good training state and re-rolling",
                        self.consecutive_trips,
                        self.cfg.max_guard_trips,
                        self.guarded_iters
                    );
                    let tel = he.telemetry.clone();
                    tel.begin(
                        crate::telemetry::TID_GUARD,
                        "guard_rollback",
                        self.guarded_iters as u64,
                        self.consecutive_trips as i64,
                    );
                    he.restore_training_state(&snap)?;
                    tel.end(
                        crate::telemetry::TID_GUARD,
                        "guard_rollback",
                        self.guarded_iters as u64,
                        self.consecutive_trips as i64,
                    );
                    // EMA phase rewinds with the params; the rollout round
                    // does NOT — the retry draws fresh experience under a
                    // perturbed round seed instead of replaying the draws
                    // that diverged.
                    self.iters_done = iters0;
                }
            }
        }
    }
}

/// Shared tail of both experience paths: ground-truth rewards, response
/// masking, KL-shaped rewards, GAE, whitening — one scored `[b, s]` token
/// batch in, one training-ready [`Experience`] out. `prompt_lens[i]` is
/// row i's TRUE prompt length (all `prompt_len` on the fixed path; the
/// scheduler rollout admits variable-length prompts, so there the
/// boundaries are per row) — every response-region index below derives
/// from it, so PPO's log-prob/advantage masks see real boundaries, never
/// the artifact's fixed window. A free function (not a `&self` method) so
/// the rollout path can call it from the flush callback while the
/// trainer's sampling backend is mutably borrowed by the scheduler loop.
fn assemble_experience(
    cfg: &PpoConfig,
    prompts: &[(TaskGen, Prompt)],
    tokens: Vec<i32>,
    resp_lens: Vec<usize>,
    scores: ExperienceScores,
    prompt_lens: &[usize],
    s: usize,
) -> Experience {
    let b = prompts.len();
    assert_eq!(prompt_lens.len(), b);
    let rm_scores = scores.rm_scores;
    let old_logp = scores.old_logp;
    let ref_logp = scores.ref_logp;
    let values = scores.values; // [b, s]

    // Ground-truth task reward (the oracle the paper can't have).
    let true_rewards: Vec<f32> = prompts
        .iter()
        .enumerate()
        .map(|(i, (g, p))| g.reward(p, &tokens[i * s + prompt_lens[i]..(i + 1) * s]))
        .collect();

    // Response mask over next-token positions: prediction index j scores
    // token j+1, so row i's response region is [sp_i - 1, sp_i - 1 + len).
    let w = s - 1;
    let mut mask = vec![0.0f32; b * w];
    for i in 0..b {
        let sp_i = prompt_lens[i];
        for j in 0..resp_lens[i] {
            mask[i * w + sp_i - 1 + j] = 1.0;
        }
    }

    // KL-shaped rewards + GAE per sequence.
    let mut advantages = vec![0.0f32; b * w];
    let mut returns = vec![0.0f32; b * w];
    let mut kl_sum = 0.0f64;
    let mut kl_n = 0.0f64;
    for i in 0..b {
        let len = resp_lens[i];
        let sp_i = prompt_lens[i];
        let lo = i * w + sp_i - 1;
        let lp = &old_logp[lo..lo + len];
        let rlp = &ref_logp[lo..lo + len];
        kl_sum += lp.iter().zip(rlp).map(|(a, r)| (a - r) as f64).sum::<f64>();
        kl_n += len as f64;
        let rewards =
            gae::shaped_rewards(lp, rlp, rm_scores[i], cfg.kl_coef, cfg.reward_clip);
        // values for response positions + terminal bootstrap 0.
        let mut vals = Vec::with_capacity(len + 1);
        vals.extend_from_slice(&values[i * s + sp_i - 1..i * s + sp_i - 1 + len]);
        vals.push(0.0);
        let out = gae::gae(&rewards, &vals, cfg.gamma, cfg.lam);
        advantages[lo..lo + len].copy_from_slice(&out.advantages);
        returns[lo..lo + len].copy_from_slice(&out.returns);
    }
    if cfg.whiten_advantages {
        gae::whiten(&mut advantages, &mask);
    }

    // old_values laid out [b, s-1] = values[:, :-1]
    let mut old_values = vec![0.0f32; b * w];
    for i in 0..b {
        old_values[i * w..(i + 1) * w].copy_from_slice(&values[i * s..i * s + w]);
    }

    Experience {
        tokens,
        old_logp,
        advantages,
        returns,
        old_values,
        mask,
        rm_scores,
        true_rewards,
        mean_kl: (kl_sum / kl_n.max(1.0)) as f32,
        resp_lens,
        prompt_lens: prompt_lens.to_vec(),
    }
}

fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_len_finds_eos() {
        let sp = 4;
        let seq = [1, 1, 1, 1, 10, 11, Vocab::EOS, 0, 0, 0];
        assert_eq!(PpoTrainer::response_len(&seq, sp), 3);
    }

    #[test]
    fn response_len_caps_at_gen_len() {
        let sp = 2;
        let seq = [1, 1, 10, 11, 12, 13];
        assert_eq!(PpoTrainer::response_len(&seq, sp), 4);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    fn healthy_stats() -> IterStats {
        IterStats {
            rm_score: 0.5,
            true_reward: 0.3,
            kl_to_ref: 0.01,
            actor_loss: -0.02,
            critic_loss: 0.4,
            approx_kl: 0.003,
            clipfrac: 0.1,
            ..Default::default()
        }
    }

    #[test]
    fn guard_passes_healthy_stats() {
        let g = AnomalyGuard::from_cfg(&PpoConfig::default());
        assert_eq!(g.validate(&healthy_stats()), None);
    }

    #[test]
    fn guard_trips_on_every_non_finite_stat() {
        let g = AnomalyGuard::from_cfg(&PpoConfig::default());
        for field in ["actor_loss", "critic_loss", "approx_kl", "clipfrac", "rm_score"] {
            let mut st = healthy_stats();
            match field {
                "actor_loss" => st.actor_loss = f32::NAN,
                "critic_loss" => st.critic_loss = f32::INFINITY,
                "approx_kl" => st.approx_kl = f32::NEG_INFINITY,
                "clipfrac" => st.clipfrac = f32::NAN,
                _ => st.rm_score = f32::NAN,
            }
            let why = g.validate(&st).expect("must trip");
            assert!(why.contains(field), "{why}");
        }
    }

    #[test]
    fn guard_trips_on_kl_and_clipfrac_thresholds() {
        let g = AnomalyGuard { max_approx_kl: 1.0, max_clipfrac: 0.9 };
        let mut st = healthy_stats();
        st.approx_kl = -3.0; // magnitude matters, not sign
        assert!(g.validate(&st).unwrap().contains("approx_kl"));
        let mut st = healthy_stats();
        st.clipfrac = 0.95;
        assert!(g.validate(&st).unwrap().contains("clipfrac"));
    }

    #[test]
    fn guard_thresholds_zero_disable() {
        let g = AnomalyGuard { max_approx_kl: 0.0, max_clipfrac: 0.0 };
        let mut st = healthy_stats();
        st.approx_kl = 1e6;
        st.clipfrac = 1.0;
        assert_eq!(g.validate(&st), None, "0 disables the finite thresholds");
        st.actor_loss = f32::NAN;
        assert!(g.validate(&st).is_some(), "non-finite always trips");
    }
}
