//! Generalized Advantage Estimation and reward shaping — the L3 scalar math
//! between experience generation and the PPO updates.
//!
//! InstructGPT-style reward: per-token r_t = -kl_coef * (logp - ref_logp),
//! plus the reward-model score added at the final response token, clipped.

/// One sequence's per-token PPO inputs over the response region.
#[derive(Debug, Clone, Default)]
pub struct SeqAdvantage {
    pub advantages: Vec<f32>,
    pub returns: Vec<f32>,
}

/// KL-shaped per-token rewards for one sequence's response region.
///
/// `logp`/`ref_logp` are the response-region slices (length = response len);
/// `rm_score` lands on the last token.
pub fn shaped_rewards(
    logp: &[f32],
    ref_logp: &[f32],
    rm_score: f32,
    kl_coef: f32,
    clip: f32,
) -> Vec<f32> {
    assert_eq!(logp.len(), ref_logp.len());
    let n = logp.len();
    let mut r: Vec<f32> = logp
        .iter()
        .zip(ref_logp)
        .map(|(l, rl)| -kl_coef * (l - rl))
        .collect();
    if n > 0 {
        r[n - 1] += rm_score.clamp(-clip, clip);
    }
    r
}

/// O(n) GAE over one sequence. `values` has length n+1 (bootstrap value at
/// the end; pass 0.0 for terminal sequences).
pub fn gae(rewards: &[f32], values: &[f32], gamma: f32, lam: f32) -> SeqAdvantage {
    let n = rewards.len();
    assert_eq!(values.len(), n + 1, "values must include the bootstrap");
    let mut adv = vec![0.0f32; n];
    let mut last = 0.0f32;
    for t in (0..n).rev() {
        let delta = rewards[t] + gamma * values[t + 1] - values[t];
        last = delta + gamma * lam * last;
        adv[t] = last;
    }
    let returns = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    SeqAdvantage { advantages: adv, returns }
}

/// Quadratic-time reference implementation (tests pin `gae` against this).
pub fn gae_reference(rewards: &[f32], values: &[f32], gamma: f32, lam: f32) -> Vec<f32> {
    let n = rewards.len();
    let mut adv = vec![0.0f32; n];
    for t in 0..n {
        let mut acc = 0.0f32;
        for l in 0..(n - t) {
            let delta = rewards[t + l] + gamma * values[t + l + 1] - values[t + l];
            acc += (gamma * lam).powi(l as i32) * delta;
        }
        adv[t] = acc;
    }
    adv
}

/// Whiten to zero mean / unit variance over the masked entries (standard
/// PPO advantage normalization; the mean-shift keeps gradients centered).
pub fn whiten(xs: &mut [f32], mask: &[f32]) {
    assert_eq!(xs.len(), mask.len());
    let count: f32 = mask.iter().sum();
    if count < 2.0 {
        return;
    }
    let mean: f32 = xs.iter().zip(mask).map(|(x, m)| x * m).sum::<f32>() / count;
    let var: f32 = xs
        .iter()
        .zip(mask)
        .map(|(x, m)| m * (x - mean) * (x - mean))
        .sum::<f32>()
        / count;
    let inv = 1.0 / (var.sqrt() + 1e-8);
    for (x, m) in xs.iter_mut().zip(mask) {
        if *m > 0.0 {
            *x = (*x - mean) * inv;
        } else {
            *x = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    #[test]
    fn gae_matches_reference() {
        Prop::new(200).check("gae == O(n^2) reference", |rng| {
            let n = 1 + rng.below(32) as usize;
            let rewards: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let values: Vec<f32> = (0..=n).map(|_| rng.normal() as f32).collect();
            let gamma = rng.f32();
            let lam = rng.f32();
            let fast = gae(&rewards, &values, gamma, lam);
            let slow = gae_reference(&rewards, &values, gamma, lam);
            for (a, b) in fast.advantages.iter().zip(&slow) {
                prop_assert!((a - b).abs() < 1e-4, "gae mismatch: {a} vs {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn returns_equal_adv_plus_value() {
        let rewards = vec![1.0, 0.5, -0.5];
        let values = vec![0.1, 0.2, 0.3, 0.0];
        let out = gae(&rewards, &values, 0.99, 0.95);
        for t in 0..3 {
            assert!((out.returns[t] - (out.advantages[t] + values[t])).abs() < 1e-6);
        }
    }

    #[test]
    fn single_step_gae_is_td_error() {
        let out = gae(&[2.0], &[0.5, 0.25], 0.9, 0.95);
        assert!((out.advantages[0] - (2.0 + 0.9 * 0.25 - 0.5)).abs() < 1e-6);
    }

    #[test]
    fn perfect_critic_zero_advantage() {
        // If V(s_t) exactly equals discounted future rewards, advantages = 0.
        let gamma = 1.0;
        let rewards = vec![1.0, 1.0, 1.0];
        let values = vec![3.0, 2.0, 1.0, 0.0];
        let out = gae(&rewards, &values, gamma, 0.95);
        for a in out.advantages {
            assert!(a.abs() < 1e-6, "{a}");
        }
    }

    #[test]
    fn shaped_rewards_kl_and_score() {
        let logp = vec![-1.0, -2.0];
        let ref_logp = vec![-1.5, -1.0];
        let r = shaped_rewards(&logp, &ref_logp, 10.0, 0.1, 5.0);
        // token 0: -0.1 * (-1.0 - -1.5) = -0.05
        assert!((r[0] + 0.05).abs() < 1e-6, "{}", r[0]);
        // token 1: -0.1 * (-2.0 - -1.0) = +0.1, plus clipped score 5.0
        assert!((r[1] - 5.1).abs() < 1e-6, "{}", r[1]);
    }

    #[test]
    fn whiten_statistics() {
        let mut rng = Rng::new(1);
        let n = 512;
        let mut xs: Vec<f32> = (0..n).map(|_| 3.0 + 2.0 * rng.normal() as f32).collect();
        let mask = vec![1.0; n];
        whiten(&mut xs, &mask);
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 1e-3, "{mean}");
        assert!((var - 1.0).abs() < 1e-2, "{var}");
    }

    #[test]
    fn whiten_zeroes_masked_positions() {
        let mut xs = vec![5.0, -2.0, 7.0, 1.0];
        let mask = vec![1.0, 0.0, 1.0, 1.0];
        whiten(&mut xs, &mask);
        assert_eq!(xs[1], 0.0);
    }

    #[test]
    fn whiten_short_input_noop() {
        let mut xs = vec![5.0];
        whiten(&mut xs, &[1.0]);
        assert_eq!(xs, vec![5.0]);
    }
}
