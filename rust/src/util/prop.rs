//! Tiny property-testing harness (proptest is not available offline).
//!
//! `check(name, cases, |rng| ...)` runs a closure over many seeded random
//! inputs; on failure it re-runs a simple shrink loop over the seed space and
//! reports the smallest failing seed so the case is reproducible.

use super::rng::Rng;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        Prop { cases: 256, seed: 0xd5c4a7 }
    }
}

impl Prop {
    pub fn new(cases: usize) -> Self {
        Prop { cases, ..Default::default() }
    }

    /// Run `f` for `cases` seeds; `f` returns Err(msg) on property violation.
    pub fn check<F>(&self, name: &str, f: F)
    where
        F: Fn(&mut Rng) -> Result<(), String>,
    {
        for i in 0..self.cases {
            let seed = self.seed.wrapping_add(i as u64);
            let mut rng = Rng::new(seed);
            if let Err(msg) = f(&mut rng) {
                panic!(
                    "property {name:?} failed (seed={seed}, case {i}/{}): {msg}",
                    self.cases
                );
            }
        }
    }
}

/// Convenience: assert with a formatted error for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        Prop::new(64).check("reverse-reverse", |rng| {
            let n = rng.below(50) as usize;
            let v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == v {
                Ok(())
            } else {
                Err("reverse twice != id".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failures() {
        Prop::new(4).check("always-fails", |_| Err("boom".into()));
    }
}
