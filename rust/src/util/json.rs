//! Minimal JSON parser (serde_json is not available offline).
//!
//! Supports the full JSON grammar the AOT manifest uses: objects, arrays,
//! strings (with escapes), numbers, booleans, null. Not streaming — the
//! manifest is a few hundred KB at most.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access, panicking with a useful message.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("json: missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.at("a").as_arr().unwrap()[2].at("b").as_str().unwrap(),
            "c"
        );
        assert!(v.at("d").as_obj().unwrap().is_empty());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn manifest_shape() {
        let v = Json::parse(
            r#"{"artifacts": {"sft_step": {"file": "sft_step.hlo.txt",
                "inputs": [{"shape": [4, 32], "dtype": "int32"}]}}}"#,
        )
        .unwrap();
        let inp = &v.at("artifacts").at("sft_step").at("inputs").as_arr().unwrap()[0];
        let dims: Vec<usize> = inp
            .at("shape")
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(dims, vec![4, 32]);
        assert_eq!(inp.at("dtype").as_str().unwrap(), "int32");
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }
}
