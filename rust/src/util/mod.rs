//! Dependency-free substrates that would normally come from crates.io.
//!
//! The build image has no network access and only the `xla` crate's closure
//! in its offline registry, so the roles of `serde_json`, `rand`, `proptest`,
//! `clap` and `csv` are covered here (each with its own tests).

pub mod argparse;
pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;

/// Format a `f64` duration in seconds as a human-readable string.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.1}s", secs)
    } else if secs < 7200.0 {
        format!("{:.1}min", secs / 60.0)
    } else if secs < 48.0 * 3600.0 {
        format!("{:.1}h", secs / 3600.0)
    } else {
        format!("{:.1}d", secs / 86400.0)
    }
}

/// Format a byte count as GiB/MiB/KiB.
pub fn fmt_bytes(bytes: f64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    if bytes >= G {
        format!("{:.2}GiB", bytes / G)
    } else if bytes >= M {
        format!("{:.1}MiB", bytes / M)
    } else if bytes >= K {
        format!("{:.1}KiB", bytes / K)
    } else {
        format!("{:.0}B", bytes)
    }
}

/// Format a large count with engineering suffixes (1.3B, 350M, 6.7k).
pub fn fmt_count(n: f64) -> String {
    fn sig3(x: f64) -> String {
        // 3 significant digits, trailing zeros/point trimmed (like %g).
        let s = if x >= 100.0 {
            format!("{x:.0}")
        } else if x >= 10.0 {
            format!("{x:.1}")
        } else {
            format!("{x:.2}")
        };
        let s = if s.contains('.') {
            s.trim_end_matches('0').trim_end_matches('.').to_string()
        } else {
            s
        };
        s
    }
    if n >= 1e12 {
        format!("{}T", sig3(n / 1e12))
    } else if n >= 1e9 {
        format!("{}B", sig3(n / 1e9))
    } else if n >= 1e6 {
        format!("{}M", sig3(n / 1e6))
    } else if n >= 1e3 {
        format!("{}k", sig3(n / 1e3))
    } else {
        sig3(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn durations() {
        assert_eq!(fmt_duration(0.5e-3), "500.0us");
        assert_eq!(fmt_duration(0.25), "250.0ms");
        assert_eq!(fmt_duration(5.0), "5.0s");
        assert_eq!(fmt_duration(600.0), "10.0min");
        assert_eq!(fmt_duration(7200.0), "2.0h");
        assert_eq!(fmt_duration(86400.0 * 3.0), "3.0d");
    }

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512.0), "512B");
        assert_eq!(fmt_bytes(2048.0), "2.0KiB");
        assert_eq!(fmt_bytes(3.5 * 1024.0 * 1024.0 * 1024.0), "3.50GiB");
    }

    #[test]
    fn counts() {
        assert_eq!(fmt_count(1.3e9), "1.3B");
        assert_eq!(fmt_count(350e6), "350M");
        assert_eq!(fmt_count(42.0), "42");
    }
}
