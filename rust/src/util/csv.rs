//! CSV + markdown-table writers for run logs and paper-table regeneration.

use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Append-style CSV writer for training curves (`runs/*.csv`).
pub struct CsvWriter {
    w: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(File::create(path)?);
        writeln!(w, "{}", header.join(","))?;
        Ok(CsvWriter { w, cols: header.len() })
    }

    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.cols, "csv row arity mismatch");
        writeln!(self.w, "{}", values.join(","))?;
        self.w.flush()
    }

    pub fn rowf(&mut self, values: &[f64]) -> std::io::Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }
}

/// Markdown table builder mirroring the paper's table layout.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for i in 0..ncol {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(s, "{}", fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(s, "{sep}");
        for r in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(r, &widths));
        }
        s
    }

    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("Demo", &["GPUs", "OPT-13B"]);
        t.row(vec!["8x A100-40GB".into(), "10.8 hours".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 8x A100-40GB | 10.8 hours |"));
        // layout: "### Demo", "", header, separator
        assert!(md.lines().nth(3).unwrap().starts_with("|--"));
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("dschat_csv_test");
        let path = dir.join("x.csv");
        let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
        w.rowf(&[1.0, 2.5]).unwrap();
        w.rowf(&[2.0, 2.0]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,2.5\n2,2\n");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
