//! Minimal CLI flag parser (clap is not available offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generates usage text from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
    registered: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    flags.insert(rest.to_string(), it.next().unwrap());
                } else {
                    flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                positional.push(a);
            }
        }
        Args { flags, positional, registered: Vec::new() }
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn register(&mut self, name: &str, default: &str, help: &str) {
        self.registered.push((name.into(), default.into(), help.into()));
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for (n, d, h) in &self.registered {
            s.push_str(&format!("  --{n:<20} {h} (default: {d})\n"));
        }
        s
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(v) => panic!("--{key} expects a boolean, got {v:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_forms() {
        let a = args("train --run small --steps=200 --verbose --lr 1e-4 ckpt.bin");
        assert_eq!(a.positional(), &["train", "ckpt.bin"]);
        assert_eq!(a.str("run", "tiny"), "small");
        assert_eq!(a.usize("steps", 0), 200);
        assert!(a.bool("verbose", false));
        assert_eq!(a.f64("lr", 0.0), 1e-4);
    }

    #[test]
    fn defaults() {
        let a = args("x");
        assert_eq!(a.usize("steps", 7), 7);
        assert!(!a.bool("verbose", false));
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = args("--ema --run small");
        assert!(a.bool("ema", false));
        assert_eq!(a.str("run", ""), "small");
    }
}
