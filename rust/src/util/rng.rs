//! Small deterministic PRNG (the `rand` crate is not available offline).
//!
//! PCG-XSH-RR 64/32 — fast, well-distributed, and reproducible across
//! platforms; used by data synthesis, sampling, and the property-test
//! harness. Determinism given a seed is load-bearing: EXPERIMENTS.md runs
//! are replayable.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng { state: 0, inc: (seed << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(0x853c49e6748fea9b ^ seed);
        r.next_u32();
        r
    }

    /// Derive an independent stream (for per-worker / per-epoch rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// Raw generator state `(state, inc)` for checkpointing;
    /// [`Rng::from_state`] restores the stream mid-flight so a resumed run
    /// draws exactly what the uninterrupted run would have.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Rng::state`] output (NOT a seed — use
    /// [`Rng::new`] for seeding).
    pub fn from_state(state: u64, inc: u64) -> Rng {
        Rng { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u32) as i64
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            v.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.below(v.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn state_roundtrip_resumes_mid_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let (state, inc) = a.state();
        let mut b = Rng::from_state(state, inc);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(7);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[(r.f64() * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "{buckets:?}");
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for n in [1u32, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
