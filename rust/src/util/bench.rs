//! Minimal criterion-style benchmark harness (criterion is not available
//! offline). Warms up, runs timed iterations until a wall budget, reports
//! mean / p50 / p95 and throughput. Used by the `[[bench]]` targets
//! (`harness = false`).

use std::time::{Duration, Instant};

pub struct Bench {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
}

impl BenchResult {
    pub fn print(&self, per_iter_items: Option<(f64, &str)>) {
        let thr = per_iter_items
            .map(|(n, unit)| format!("  {:>10.1} {unit}/s", n / self.mean_secs))
            .unwrap_or_default();
        println!(
            "{:<44} {:>7} iters  mean {:>10}  p50 {:>10}  p95 {:>10}{}",
            self.name,
            self.iters,
            crate::util::fmt_duration(self.mean_secs),
            crate::util::fmt_duration(self.p50_secs),
            crate::util::fmt_duration(self.p95_secs),
            thr,
        );
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench { budget: Duration::from_millis(600), ..Default::default() }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean_secs: mean,
            p50_secs: p(0.5),
            p95_secs: p(0.95),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(50),
            min_iters: 3,
            max_iters: 100,
        };
        let r = b.run("sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean_secs >= 0.001 && r.mean_secs < 0.01, "{}", r.mean_secs);
        assert!(r.iters >= 3);
        assert!(r.p95_secs >= r.p50_secs);
    }
}
