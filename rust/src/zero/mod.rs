//! ZeRO (Zero Redundancy Optimizer) partitioning: the memory substrate the
//! paper's training mode stands on (Rajbhandari et al., SC'20).
//!
//! Two halves:
//!  * [`partition`] — the actual shard plan (which rank owns which slice of
//!    each tensor), used by the hybrid engine's (simulated) multi-GPU
//!    planning and property-tested for exact coverage.
//!  * [`MemoryModel`] — byte-exact per-GPU accounting for params / grads /
//!    optimizer states / activations under stages 0–3 (+ CPU offload),
//!    mixed-precision layout (fp16 model, fp32 master+moments), which drives
//!    Table 3, Figure 7 and every OOM boundary in Figures 3–4.

use crate::config::ModelConfig;

/// ZeRO stage: what is partitioned across the data-parallel group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ZeroStage {
    /// Plain data parallelism (DDP): everything replicated.
    Stage0,
    /// Optimizer states partitioned.
    Stage1,
    /// + gradients partitioned.
    Stage2,
    /// + parameters partitioned (gathered on the fly).
    Stage3,
}

impl ZeroStage {
    pub fn opt_sharded(self) -> bool {
        self >= ZeroStage::Stage1
    }
    pub fn grads_sharded(self) -> bool {
        self >= ZeroStage::Stage2
    }
    pub fn params_sharded(self) -> bool {
        self >= ZeroStage::Stage3
    }
}

/// One rank's contiguous shard of a flat tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub rank: usize,
    pub start: usize,
    pub len: usize,
}

/// Split `numel` elements across `world` ranks as evenly as possible
/// (first `numel % world` ranks get one extra element) — the canonical
/// ZeRO flat-buffer partitioning.
pub fn partition(numel: usize, world: usize) -> Vec<Shard> {
    assert!(world > 0);
    let base = numel / world;
    let extra = numel % world;
    let mut out = Vec::with_capacity(world);
    let mut start = 0;
    for rank in 0..world {
        let len = base + usize::from(rank < extra);
        out.push(Shard { rank, start, len });
        start += len;
    }
    out
}

/// Which rank owns flat element `idx`?
pub fn owner_of(numel: usize, world: usize, idx: usize) -> usize {
    assert!(idx < numel);
    let base = numel / world;
    let extra = numel % world;
    let big = (base + 1) * extra; // elements covered by the "big" ranks
    if idx < big {
        idx / (base + 1)
    } else {
        extra + (idx - big) / base.max(1)
    }
}

/// Mixed-precision byte constants (per parameter).
pub const FP16_PARAM: f64 = 2.0;
pub const FP16_GRAD: f64 = 2.0;
/// fp32 master + fp32 momentum + fp32 variance.
pub const ADAM_STATES: f64 = 12.0;

/// Per-GPU memory model for one model's training state.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub stage: ZeroStage,
    pub world: usize,
    /// Offload optimizer states (and stage-3 params) to host memory.
    pub cpu_offload: bool,
    /// Activation checkpointing (recompute in backward).
    pub act_checkpoint: bool,
}

impl MemoryModel {
    pub fn new(stage: ZeroStage, world: usize) -> Self {
        MemoryModel { stage, world, cpu_offload: false, act_checkpoint: true }
    }

    pub fn with_offload(mut self, on: bool) -> Self {
        self.cpu_offload = on;
        self
    }

    pub fn with_checkpointing(mut self, on: bool) -> Self {
        self.act_checkpoint = on;
        self
    }

    fn shard(&self, sharded: bool) -> f64 {
        if sharded {
            self.world as f64
        } else {
            1.0
        }
    }

    /// fp16 parameter bytes resident per GPU.
    pub fn param_bytes(&self, n_params: u64) -> f64 {
        let b = n_params as f64 * FP16_PARAM / self.shard(self.stage.params_sharded());
        if self.cpu_offload && self.stage == ZeroStage::Stage3 {
            // ZeRO-3 + offload parks the fp16 shards in host memory too and
            // streams them in; a working-set buffer remains.
            b * 0.25
        } else {
            b
        }
    }

    pub fn grad_bytes(&self, n_params: u64) -> f64 {
        n_params as f64 * FP16_GRAD / self.shard(self.stage.grads_sharded())
    }

    pub fn opt_bytes(&self, n_params: u64) -> f64 {
        if self.cpu_offload {
            return 0.0; // states live in host DRAM (ZeRO-Offload)
        }
        n_params as f64 * ADAM_STATES / self.shard(self.stage.opt_sharded())
    }

    /// Activation bytes for a microbatch (Megatron-style estimate: ~34·d
    /// bytes per token per layer fp16 without checkpointing, ~4·d with).
    pub fn activation_bytes(&self, cfg: &ModelConfig, microbatch: f64, seq: usize) -> f64 {
        let per_token_layer = if self.act_checkpoint { 4.0 } else { 34.0 };
        microbatch * seq as f64 * cfg.n_layers as f64 * per_token_layer * cfg.d_model as f64
    }

    /// Total training-state bytes per GPU (excluding activations).
    pub fn state_bytes(&self, n_params: u64) -> f64 {
        self.param_bytes(n_params) + self.grad_bytes(n_params) + self.opt_bytes(n_params)
    }

    /// Largest integer microbatch that fits in `budget` bytes alongside the
    /// training state; None if even the state alone does not fit.
    pub fn max_microbatch(&self, cfg: &ModelConfig, seq: usize, budget: f64) -> Option<u64> {
        let state = self.state_bytes(cfg.n_params());
        if state >= budget {
            return None;
        }
        let per_mb = self.activation_bytes(cfg, 1.0, seq);
        let mb = ((budget - state) / per_mb).floor();
        if mb < 1.0 {
            None
        } else {
            Some(mb as u64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::model;
    use crate::prop_assert;
    use crate::util::prop::Prop;

    #[test]
    fn partition_covers_exactly() {
        Prop::new(256).check("partition covers", |rng| {
            let numel = rng.below(100_000) as usize;
            let world = 1 + rng.below(64) as usize;
            let shards = partition(numel, world);
            prop_assert!(shards.len() == world, "wrong shard count");
            let mut pos = 0;
            for (i, s) in shards.iter().enumerate() {
                prop_assert!(s.rank == i, "rank order");
                prop_assert!(s.start == pos, "gap/overlap at rank {i}");
                pos += s.len;
            }
            prop_assert!(pos == numel, "total {pos} != {numel}");
            // balance: max - min <= 1
            let lens: Vec<usize> = shards.iter().map(|s| s.len).collect();
            let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            prop_assert!(mx - mn <= 1, "imbalance {mn}..{mx}");
            Ok(())
        });
    }

    #[test]
    fn owner_matches_partition() {
        Prop::new(128).check("owner_of consistent", |rng| {
            let numel = 1 + rng.below(10_000) as usize;
            let world = 1 + rng.below(32) as usize;
            let shards = partition(numel, world);
            for _ in 0..32 {
                let idx = rng.below(numel as u32) as usize;
                let owner = owner_of(numel, world, idx);
                let s = &shards[owner];
                prop_assert!(
                    idx >= s.start && idx < s.start + s.len,
                    "idx {idx} not in rank {owner}'s shard {s:?}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn stage_ordering() {
        assert!(ZeroStage::Stage3.params_sharded());
        assert!(!ZeroStage::Stage2.params_sharded());
        assert!(ZeroStage::Stage2.grads_sharded());
        assert!(ZeroStage::Stage1.opt_sharded());
        assert!(!ZeroStage::Stage0.opt_sharded());
    }

    #[test]
    fn memory_shrinks_with_stage_and_world() {
        let cfg = model("opt-1.3b");
        let p = cfg.n_params();
        let gib = 1024.0 * 1024.0 * 1024.0;
        let m0 = MemoryModel::new(ZeroStage::Stage0, 8).state_bytes(p) / gib;
        let m1 = MemoryModel::new(ZeroStage::Stage1, 8).state_bytes(p) / gib;
        let m2 = MemoryModel::new(ZeroStage::Stage2, 8).state_bytes(p) / gib;
        let m3 = MemoryModel::new(ZeroStage::Stage3, 8).state_bytes(p) / gib;
        assert!(m0 > m1 && m1 > m2 && m2 > m3, "{m0} {m1} {m2} {m3}");
        // DDP holds 16 bytes/param.
        assert!((m0 - 16.0 * p as f64 / gib).abs() < 0.1);
        // Stage 3 over 8 GPUs: 2 bytes/param.
        assert!((m3 - 2.0 * p as f64 / gib).abs() < 0.1);
    }

    #[test]
    fn offload_eliminates_opt_bytes() {
        let cfg = model("opt-13b");
        let m = MemoryModel::new(ZeroStage::Stage2, 1).with_offload(true);
        assert_eq!(m.opt_bytes(cfg.n_params()), 0.0);
        assert!(m.param_bytes(cfg.n_params()) > 0.0);
    }

    #[test]
    fn max_microbatch_monotone_in_budget() {
        let cfg = model("opt-1.3b");
        let m = MemoryModel::new(ZeroStage::Stage2, 8);
        let gib = 1024.0 * 1024.0 * 1024.0;
        let mb40 = m.max_microbatch(&cfg, 512, 40.0 * gib);
        let mb80 = m.max_microbatch(&cfg, 512, 80.0 * gib);
        assert!(mb80.unwrap() > mb40.unwrap());
        // A model too big for the budget returns None.
        let big = model("opt-175b");
        assert_eq!(MemoryModel::new(ZeroStage::Stage0, 1).max_microbatch(&big, 512, 40.0 * gib), None);
    }

    #[test]
    fn checkpointing_cuts_activations() {
        let cfg = model("opt-13b");
        let with = MemoryModel::new(ZeroStage::Stage2, 8).activation_bytes(&cfg, 8.0, 512);
        let without = MemoryModel::new(ZeroStage::Stage2, 8)
            .with_checkpointing(false)
            .activation_bytes(&cfg, 8.0, 512);
        assert!(without / with > 5.0);
    }
}
