//! System models for DeepSpeed-HE and the two comparison frameworks
//! (paper §5.2, Figures 3–5).
//!
//! Each system is a set of *mechanisms* (which ZeRO stage, offload, how the
//! generation phase is executed) plus calibrated efficiency constants. The
//! constants are pinned from public numbers: DeepSpeed-HE's generation
//! kernels reach a large fraction of HBM bandwidth; HF/Colossal generation
//! runs unfused kernels with per-token framework overhead (the paper's 9x /
//! 15x generation-phase gaps at 1.3B, Figure 5).

use crate::zero::ZeroStage;

#[derive(Debug, Clone)]
pub struct SystemModel {
    pub name: String,
    /// Fraction of HBM bandwidth achieved by the decode kernels.
    pub gen_bw_eff: f64,
    /// Fixed host/framework overhead per decode step, seconds.
    pub gen_overhead: f64,
    /// Peak training MFU at saturating microbatch.
    pub train_eff: f64,
    /// Best ZeRO stage the system can train with.
    pub stage: ZeroStage,
    /// ZeRO-Offload (optimizer states to host) available.
    pub offload: bool,
    /// Generation uses tensor parallelism (DS-HE); otherwise a ZeRO-3-style
    /// per-token parameter gather when the model exceeds one GPU.
    pub gen_tp: bool,
    /// Hybrid memory management: KV pool and training state swap at phase
    /// boundaries instead of coexisting.
    pub hybrid_memory: bool,
    /// Dedicated KV-cache memory manager (paper §4: "light-weight memory
    /// management system to handle the KV-cache"). Without it, fragmentation
    /// caps the practical generation batch.
    pub kv_manager: bool,
}

/// Practical generation-batch cap without a KV-cache manager.
pub const NO_KV_MANAGER_BATCH_CAP: u64 = 16;

/// DeepSpeed-HE: ZeRO-3 + offload + TP generation + fused kernels + hybrid
/// memory reconfiguration.
pub fn ds_he() -> SystemModel {
    SystemModel {
        name: "DeepSpeed-HE".into(),
        gen_bw_eff: 0.65,
        gen_overhead: 0.2e-3,
        train_eff: 0.45,
        stage: ZeroStage::Stage3,
        offload: true,
        gen_tp: true,
        hybrid_memory: true,
        kv_manager: true,
    }
}

/// HuggingFace DDP + native PyTorch generation (paper's "HF-DDP").
pub fn hf_ddp() -> SystemModel {
    SystemModel {
        name: "HF-DDP".into(),
        gen_bw_eff: 0.085,
        gen_overhead: 6.0e-3,
        train_eff: 0.33,
        stage: ZeroStage::Stage0,
        offload: false,
        gen_tp: false,
        hybrid_memory: false,
        kv_manager: false,
    }
}

/// Colossal-AI (Gemini ZeRO-3-style training, unfused generation — so the
/// generation phase pays the per-token parameter gather once the model no
/// longer fits a single GPU).
pub fn colossal_ai() -> SystemModel {
    SystemModel {
        name: "Colossal-AI".into(),
        gen_bw_eff: 0.05,
        gen_overhead: 9.0e-3,
        train_eff: 0.30,
        stage: ZeroStage::Stage3,
        offload: false,
        gen_tp: false,
        hybrid_memory: false,
        kv_manager: false,
    }
}

pub fn all_systems() -> Vec<SystemModel> {
    vec![ds_he(), hf_ddp(), colossal_ai()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ds_dominates_generation_efficiency() {
        let ds = ds_he();
        for other in [hf_ddp(), colossal_ai()] {
            assert!(ds.gen_bw_eff > 5.0 * other.gen_bw_eff, "{}", other.name);
            assert!(ds.gen_overhead < other.gen_overhead);
        }
    }

    #[test]
    fn only_ds_has_full_mechanism_set() {
        assert!(ds_he().gen_tp && ds_he().hybrid_memory && ds_he().offload);
        assert!(!hf_ddp().gen_tp && !hf_ddp().hybrid_memory);
        assert_eq!(hf_ddp().stage, ZeroStage::Stage0);
    }
}
