//! # dschat — a Rust + JAX + Pallas reproduction of DeepSpeed-Chat
//!
//! Three-layer architecture (Python never on the run path):
//! * **L3 (this crate)** — the coordination contribution: hybrid engine,
//!   PPO orchestration, 3-step pipeline, ZeRO/TP planners, cluster simulator.
//! * **L2 (JAX)** — transformer + RLHF losses, AOT-lowered to HLO text.
//! * **L1 (Pallas)** — flash/decode attention, fused LN and Adam kernels.
//!
//! See DESIGN.md for the system inventory and the paper-experiment index.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod examples_support;
pub mod hybrid;
pub mod metrics;
pub mod pipeline;
pub mod report;
pub mod rollout;
pub mod runtime;
pub mod sampling;
pub mod serving;
pub mod sim;
pub mod telemetry;
pub mod tp;
pub mod util;
pub mod zero;
