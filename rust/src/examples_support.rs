//! Shared helpers for the CLI and the `examples/` binaries (kept in the
//! library so the logic is tested and reused, not copy-pasted).

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::PpoTrainer;
use crate::data::synthetic::{TaskGen, Vocab};
use crate::hybrid::HybridEngine;
use crate::rollout::RolloutEngine;
use crate::sampling::{HostFullRow, RowRef, SamplerConfig, SamplingBackend};
use crate::serving::SchedStats;
use crate::util::rng::Rng;

/// A short scripted "conversation": sample task prompts, generate with the
/// actor, show detokenized exchanges plus the ground-truth score — the
/// reproduction of the paper's §2.1 inference-API demo, with the synthetic
/// task standing in for natural language.
pub fn chat_loop(he: &mut HybridEngine, turns: usize, seed: u64) -> Result<()> {
    let m = he.manifest();
    let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
    let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let mut rng = Rng::new(seed);
    let mut sampler = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, seed);
    for turn in 0..turns {
        let prompts: Vec<_> = (0..b).map(|_| task.sample_prompt(&mut rng)).collect();
        let mut flat = Vec::with_capacity(b * sp);
        for p in &prompts {
            flat.extend_from_slice(&p.tokens);
        }
        let seqs = he.generate(&flat, &mut sampler)?;
        // Show the first row of the batch each turn.
        let row = &seqs[..s];
        let p = &prompts[0];
        let response = &row[sp..];
        println!("Human     ({turn}): {}", task.detokenize(&p.tokens));
        println!("Assistant ({turn}): {}", task.detokenize(response));
        println!(
            "            [mode {:?}; ground-truth reward {:.2}]",
            p.mode,
            task.reward(p, response)
        );
    }
    Ok(())
}

/// Mean ground-truth reward of greedy generations over `n_batches` fresh
/// prompt batches (the evaluation metric of the e2e example).
pub fn eval_true_reward(he: &mut HybridEngine, n_batches: usize, seed: u64) -> Result<f32> {
    let m = he.manifest();
    let (b, sp, s) = (m.batch, m.prompt_len, m.seq_len);
    let task = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let mut rng = Rng::new(seed);
    let mut sampler = HostFullRow::new(SamplerConfig { greedy: true, ..Default::default() }, seed);
    let mut total = 0.0f32;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let prompts: Vec<_> = (0..b).map(|_| task.sample_prompt(&mut rng)).collect();
        let mut flat = Vec::with_capacity(b * sp);
        for p in &prompts {
            flat.extend_from_slice(&p.tokens);
        }
        let seqs = he.generate(&flat, &mut sampler)?;
        for (i, p) in prompts.iter().enumerate() {
            total += task.reward(p, &seqs[i * s + sp..(i + 1) * s]);
            count += 1;
        }
    }
    Ok(total / count as f32)
}

/// Naive-generation baseline: re-run the full-sequence forward for every
/// generated token (no KV cache, no decode kernel) — the mechanism behind
/// HF-style generation that Figure 5 shows DS-HE beating 9x. Returns
/// sequences identical in distribution to `HybridEngine::generate` (greedy),
/// but measured through the slow path. The baseline always materializes the
/// full logits, so only full-row backends (e.g. [`HostFullRow`]) fit here —
/// a device backend fed these rows errors out loudly.
pub fn naive_generate(
    he: &mut HybridEngine,
    prompts: &[i32],
    sampler: &mut dyn SamplingBackend,
) -> Result<Vec<i32>> {
    let m = he.manifest();
    let (b, sp, sg, s) = (m.batch, m.prompt_len, m.gen_len, m.seq_len);
    let vocab = m.actor.vocab;
    // Build padded sequences; the logprobs_forward artifact wants [b, s].
    let mut seqs = vec![0i32; b * s];
    for i in 0..b {
        seqs[i * s..i * s + sp].copy_from_slice(&prompts[i * sp..(i + 1) * sp]);
    }
    let mut done = vec![false; b];
    for step in 0..sg {
        // Full forward over the whole (padded) sequence; O(s) per token vs
        // the decode path's O(1) — recompute is the baseline's cost.
        let logits = he.full_logits(&seqs)?; // [b, s, vocab]
        let pos = sp + step - 1; // logits at pos predict token at pos+1
        for i in 0..b {
            if done[i] {
                continue;
            }
            let base = (i * s + pos) * vocab;
            let row = &logits[base..base + vocab];
            let hist = &seqs[i * s..i * s + sp + step];
            let t = sampler.sample(RowRef::Logits(row), hist)?;
            seqs[i * s + sp + step] = t;
            if t == crate::data::synthetic::Vocab::EOS {
                done[i] = true;
            }
        }
        if done.iter().all(|d| *d) {
            break;
        }
    }
    Ok(seqs)
}

/// A heterogeneous prompt queue: `n` prompts whose TRUE lengths are drawn
/// uniformly from `[min_len, prompt_len]` (clamped to the task's
/// structural floor) — the mixed-length traffic the serve/rollout benches
/// and the mixed-traffic ablation all share, so their workloads cannot
/// quietly diverge.
pub fn mixed_prompts(
    task: &TaskGen,
    rng: &mut Rng,
    n: usize,
    min_len: usize,
) -> Vec<Vec<i32>> {
    let lo = min_len.max(TaskGen::MIN_PROMPT_LEN).min(task.prompt_len);
    (0..n)
        .map(|_| {
            let len = rng.range(lo as i64, task.prompt_len as i64 + 1) as usize;
            task.sample_prompt_len(rng, len).tokens
        })
        .collect()
}

/// One measured experience-rollout phase — fixed lockstep baseline or the
/// continuous scheduler rollout. `examples/ablations.rs` and the
/// `runtime_e2e` rollout bench both consume these helpers so the
/// useful-token, slot-bubble, and padded-token accounting cannot diverge
/// between the ablation table and the BENCH JSONs.
pub struct RolloutPhase {
    /// Useful generated tokens: up to EOS or the per-request budget.
    pub useful_tokens: u64,
    pub secs: f64,
    /// Fraction of held slot capacity spent on dead rows.
    pub bubble: f64,
    /// Fraction of prefill-written prompt-window entries that were
    /// left-padding (0 for exact-length traffic; `SchedStats::pad_fraction`
    /// on the continuous path).
    pub pad_overhead: f64,
    /// Scheduler counters (continuous phase only).
    pub sched: Option<SchedStats>,
}

impl RolloutPhase {
    pub fn tok_per_sec(&self) -> f64 {
        self.useful_tokens as f64 / self.secs.max(1e-9)
    }
}

/// Fixed-batch rollout baseline: lockstep chunks of `b` through
/// `HybridEngine::generate`, per-request budgets honored only by
/// truncating afterwards (the lockstep loop cannot stop a single row).
/// Slot capacity counts the sampling steps each chunk ACTUALLY held all
/// `b` slots for — `generate` early-exits once every row is done, so the
/// bubble fraction reflects real dead slot-steps, not a `gen_len`
/// worst case. Callers should warm the engine (one generate) first.
pub fn rollout_fixed_baseline(
    he: &mut HybridEngine,
    prompts: &[Vec<i32>],
    budgets: &[usize],
    backend: &mut dyn SamplingBackend,
) -> Result<RolloutPhase> {
    let m = he.manifest();
    let (b, sp, sg, s) = (m.batch, m.prompt_len, m.gen_len, m.seq_len);
    anyhow::ensure!(
        !prompts.is_empty() && prompts.len() % b == 0 && budgets.len() == prompts.len(),
        "fixed baseline wants prompts/budgets sized a positive multiple of the batch {b}"
    );
    let t0 = Instant::now();
    let mut useful = 0u64;
    let mut capacity = 0u64;
    for (c, chunk) in prompts.chunks(b).enumerate() {
        let seqs = he.generate(&chunk.concat(), backend)?;
        // Steps the lockstep loop ran this chunk: to the slowest row's
        // EOS, or gen_len if any row never finished.
        let mut steps_run = 0usize;
        for (row, budget) in budgets[c * b..(c + 1) * b].iter().enumerate() {
            let gen = &seqs[row * s + sp..(row + 1) * s];
            let eos = gen.iter().position(|&t| t == Vocab::EOS);
            steps_run = steps_run.max(eos.map_or(sg, |i| i + 1));
            useful += match gen[..(*budget).min(sg)].iter().position(|&t| t == Vocab::EOS) {
                Some(i) => (i + 1) as u64,
                None => (*budget).min(sg) as u64,
            };
        }
        capacity += (b * steps_run) as u64;
    }
    Ok(RolloutPhase {
        useful_tokens: useful,
        secs: t0.elapsed().as_secs_f64(),
        bubble: 1.0 - useful as f64 / capacity.max(1) as f64,
        pad_overhead: 0.0,
        sched: None,
    })
}

/// Continuous rollout discipline: the same queue through the slot
/// scheduler (`crate::rollout`) — budgets honored exactly, retired slots
/// admit the next queued prompt, prompts may carry mixed true lengths
/// (left-padded at admission). Callers should warm the serving artifacts
/// (one small rollout) before timing.
pub fn rollout_continuous(
    he: &mut HybridEngine,
    prompts: &[Vec<i32>],
    budgets: &[usize],
    seed: u64,
    backend: &mut dyn SamplingBackend,
) -> Result<RolloutPhase> {
    rollout_continuous_chunked(he, prompts, budgets, seed, backend, 1)
}

/// Continuous rollout with `chunk` decode steps fused per scheduler
/// dispatch (`chunk == 1` is the stepwise path; `chunk > 1` needs a
/// device-RNG backend, paged serving, and the `decode_chunk{N}` artifact
/// capability — the rollout bails up front otherwise).
pub fn rollout_continuous_chunked(
    he: &mut HybridEngine,
    prompts: &[Vec<i32>],
    budgets: &[usize],
    seed: u64,
    backend: &mut dyn SamplingBackend,
    chunk: usize,
) -> Result<RolloutPhase> {
    let b = he.manifest().batch;
    let t0 = Instant::now();
    let mut useful = 0u64;
    let stats = RolloutEngine::new(seed).with_decode_chunk(chunk).run(
        &mut *he,
        backend,
        prompts,
        budgets,
        b,
        |_, g| {
            useful += g.completions.iter().map(|c| c.generated as u64).sum::<u64>();
            Ok(())
        },
    )?;
    Ok(RolloutPhase {
        useful_tokens: useful,
        secs: t0.elapsed().as_secs_f64(),
        bubble: stats.bubble_fraction(),
        pad_overhead: stats.pad_fraction(),
        sched: Some(stats),
    })
}

/// PPO smoke helper used by ablation examples: run `iters` PPO iterations
/// and return (first, last) true-reward.
pub fn ppo_probe(
    he: &mut HybridEngine,
    blend: &mut crate::data::Blend,
    cfg: crate::config::PpoConfig,
    iters: usize,
    lr: (f32, f32),
    seed: u64,
) -> Result<(f32, f32)> {
    let mut trainer = PpoTrainer::new(cfg, seed);
    let mut rng = Rng::new(seed ^ 0xa5a5);
    let mut first = 0.0;
    let mut last = 0.0;
    for i in 0..iters {
        let stats = trainer.iteration(he, blend, &mut rng, lr.0, lr.1)?;
        if i == 0 {
            first = stats.true_reward;
        }
        last = stats.true_reward;
    }
    Ok((first, last))
}
