//! Checkpoint format: a simple self-describing binary container for named
//! tensors (no serde available offline). Layout:
//!
//! ```text
//! magic "DSCHKPT1" | u32 n_tensors | n x {
//!     u32 name_len | name utf-8 | u8 dtype (0=f32, 1=i32) |
//!     u32 ndims | ndims x u64 | data (little-endian)
//! }
//! ```
//!
//! Durable training checkpoints go through [`save_atomic`] (write a
//! sibling temp file, then rename over the destination) so a crash
//! mid-write can never leave a half-written file where the last good
//! checkpoint used to be — the rollback/resume contract depends on the
//! newest `ppo_ckpt.bin` always being loadable. [`RunState`] rides inside
//! the same container as an `i32` tensor, carrying the non-tensor half of
//! a resumable run: the iteration counter, the data-RNG stream state, and
//! the rollout/EMA phase counters.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

const MAGIC: &[u8; 8] = b"DSCHKPT1";

pub fn save(path: impl AsRef<Path>, tensors: &[(String, HostTensor)]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(&path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        match t {
            HostTensor::F32(data, shape) => {
                w.write_all(&[0u8])?;
                write_shape(&mut w, shape)?;
                for x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            HostTensor::I32(data, shape) => {
                w.write_all(&[1u8])?;
                write_shape(&mut w, shape)?;
                for x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Atomic variant of [`save`]: write `<name>.tmp` beside the destination,
/// then rename over it. Rename is atomic on POSIX filesystems, so readers
/// only ever see the previous complete checkpoint or the new complete one.
pub fn save_atomic(path: impl AsRef<Path>, tensors: &[(String, HostTensor)]) -> Result<()> {
    let path = path.as_ref();
    let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
        bail!("checkpoint path {path:?} has no file name");
    };
    let tmp = path.with_file_name(format!("{name}.tmp"));
    save(&tmp, tensors)?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} over {path:?}"))?;
    Ok(())
}

/// The non-tensor half of a resumable PPO run, encoded as one `i32` tensor
/// (name [`RunState::TENSOR_NAME`]) inside the durable checkpoint: each
/// `u64` field is stored as a little-endian (lo, hi) pair of `i32` words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunState {
    /// Completed PPO iterations; resume starts at this index.
    pub iteration: u64,
    /// Data-stream RNG `state` word at the checkpoint boundary.
    pub rng_state: u64,
    /// Data-stream RNG `inc` word.
    pub rng_inc: u64,
    /// Rollout rounds completed (the per-round seed-derivation phase).
    pub rollouts_done: u64,
    /// Training calls completed (the EMA-interval phase).
    pub ema_phase: u64,
}

impl RunState {
    pub const TENSOR_NAME: &'static str = "__run_state__";

    fn fields(&self) -> [u64; 5] {
        [self.iteration, self.rng_state, self.rng_inc, self.rollouts_done, self.ema_phase]
    }

    pub fn to_tensor(&self) -> (String, HostTensor) {
        let mut words = Vec::with_capacity(10);
        for f in self.fields() {
            words.push((f as u32) as i32);
            words.push(((f >> 32) as u32) as i32);
        }
        let n = words.len();
        (Self::TENSOR_NAME.to_string(), HostTensor::I32(words, vec![n]))
    }

    pub fn from_tensor(t: &HostTensor) -> Result<RunState> {
        let HostTensor::I32(words, _) = t else {
            bail!("run state tensor has the wrong dtype (want i32)");
        };
        if words.len() != 10 {
            bail!("run state tensor has {} words, want 10", words.len());
        }
        let u = |i: usize| -> u64 {
            (words[2 * i] as u32 as u64) | ((words[2 * i + 1] as u32 as u64) << 32)
        };
        Ok(RunState {
            iteration: u(0),
            rng_state: u(1),
            rng_inc: u(2),
            rollouts_done: u(3),
            ema_phase: u(4),
        })
    }
}

fn write_shape(w: &mut impl Write, shape: &[usize]) -> Result<()> {
    w.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, HostTensor)>> {
    let mut r = BufReader::new(
        File::open(&path).with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a dschat checkpoint (bad magic)");
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let mut dtype = [0u8; 1];
        r.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut r)? as usize;
        if ndims > 16 {
            bail!("corrupt checkpoint: {ndims} dims");
        }
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let numel: usize = shape.iter().product();
        let t = match dtype[0] {
            0 => {
                let mut data = vec![0f32; numel];
                for x in data.iter_mut() {
                    let mut b = [0u8; 4];
                    r.read_exact(&mut b)?;
                    *x = f32::from_le_bytes(b);
                }
                HostTensor::F32(data, shape)
            }
            1 => {
                let mut data = vec![0i32; numel];
                for x in data.iter_mut() {
                    let mut b = [0u8; 4];
                    r.read_exact(&mut b)?;
                    *x = i32::from_le_bytes(b);
                }
                HostTensor::I32(data, shape)
            }
            d => bail!("unknown dtype tag {d}"),
        };
        out.push((name, t));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/rt.bin");
        let tensors = vec![
            ("embed".to_string(), HostTensor::F32(vec![1.5, -2.0, 0.25], vec![3])),
            ("ids".to_string(), HostTensor::I32(vec![7, 8], vec![2, 1])),
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(tensors, back);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/garbage.bin");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn empty_is_fine() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/empty.bin");
        save(&path, &[]).unwrap();
        assert!(load(&path).unwrap().is_empty());
    }

    #[test]
    fn save_atomic_replaces_and_leaves_no_temp() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/atomic.bin");
        let first = vec![("a".to_string(), HostTensor::F32(vec![1.0], vec![1]))];
        let second = vec![("a".to_string(), HostTensor::F32(vec![2.0], vec![1]))];
        save_atomic(&path, &first).unwrap();
        assert_eq!(load(&path).unwrap(), first);
        save_atomic(&path, &second).unwrap();
        assert_eq!(load(&path).unwrap(), second, "rename replaced the old file");
        assert!(
            !path.with_file_name("atomic.bin.tmp").exists(),
            "temp file must not linger"
        );
    }

    #[test]
    fn run_state_roundtrips_through_tensor() {
        let rs = RunState {
            iteration: 42,
            rng_state: u64::MAX - 7,
            rng_inc: 0x9e3779b97f4a7c15,
            rollouts_done: 3,
            ema_phase: 17,
        };
        let (name, t) = rs.to_tensor();
        assert_eq!(name, RunState::TENSOR_NAME);
        assert_eq!(RunState::from_tensor(&t).unwrap(), rs);
        // Survives the container too.
        let path = std::env::temp_dir().join("dschat_ckpt_test/runstate.bin");
        save(&path, &[(name, t)]).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(RunState::from_tensor(&back[0].1).unwrap(), rs);
        // Wrong dtype fails loudly.
        assert!(RunState::from_tensor(&HostTensor::F32(vec![0.0; 10], vec![10])).is_err());
    }
}
