//! Checkpoint format: a simple self-describing binary container for named
//! tensors (no serde available offline). Layout:
//!
//! ```text
//! magic "DSCHKPT1" | u32 n_tensors | n x {
//!     u32 name_len | name utf-8 | u8 dtype (0=f32, 1=i32) |
//!     u32 ndims | ndims x u64 | data (little-endian)
//! }
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::HostTensor;

const MAGIC: &[u8; 8] = b"DSCHKPT1";

pub fn save(path: impl AsRef<Path>, tensors: &[(String, HostTensor)]) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(File::create(&path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        match t {
            HostTensor::F32(data, shape) => {
                w.write_all(&[0u8])?;
                write_shape(&mut w, shape)?;
                for x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
            HostTensor::I32(data, shape) => {
                w.write_all(&[1u8])?;
                write_shape(&mut w, shape)?;
                for x in data {
                    w.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

fn write_shape(w: &mut impl Write, shape: &[usize]) -> Result<()> {
    w.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<Vec<(String, HostTensor)>> {
    let mut r = BufReader::new(
        File::open(&path).with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a dschat checkpoint (bad magic)");
    }
    let n = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let mut dtype = [0u8; 1];
        r.read_exact(&mut dtype)?;
        let ndims = read_u32(&mut r)? as usize;
        if ndims > 16 {
            bail!("corrupt checkpoint: {ndims} dims");
        }
        let mut shape = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let numel: usize = shape.iter().product();
        let t = match dtype[0] {
            0 => {
                let mut data = vec![0f32; numel];
                for x in data.iter_mut() {
                    let mut b = [0u8; 4];
                    r.read_exact(&mut b)?;
                    *x = f32::from_le_bytes(b);
                }
                HostTensor::F32(data, shape)
            }
            1 => {
                let mut data = vec![0i32; numel];
                for x in data.iter_mut() {
                    let mut b = [0u8; 4];
                    r.read_exact(&mut b)?;
                    *x = i32::from_le_bytes(b);
                }
                HostTensor::I32(data, shape)
            }
            d => bail!("unknown dtype tag {d}"),
        };
        out.push((name, t));
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/rt.bin");
        let tensors = vec![
            ("embed".to_string(), HostTensor::F32(vec![1.5, -2.0, 0.25], vec![3])),
            ("ids".to_string(), HostTensor::I32(vec![7, 8], vec![2, 1])),
        ];
        save(&path, &tensors).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(tensors, back);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/garbage.bin");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn empty_is_fine() {
        let path = std::env::temp_dir().join("dschat_ckpt_test/empty.bin");
        save(&path, &[]).unwrap();
        assert!(load(&path).unwrap().is_empty());
    }
}
