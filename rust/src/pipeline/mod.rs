//! The full 3-step RLHF pipeline (paper §3 / Figure 1): the `train.py`
//! experience as a library. Each step driver logs a CSV curve and returns a
//! summary; `run_all` chains them exactly like DeepSpeed-Chat's single
//! script.

pub mod checkpoint;

use std::path::Path;

use anyhow::Result;

use crate::config::TrainRecipe;
use crate::coordinator::{IterStats, PpoTrainer};
use crate::data::Blend;
use crate::hybrid::HybridEngine;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

/// Step summary used by EXPERIMENTS.md and the Table 4–6 analogues.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub steps: usize,
    pub wall_secs: f64,
    pub first_metric: f32,
    /// Mean of the final 10 steps' metric (noise-robust).
    pub last_metric: f32,
    /// Step-specific extra (RM: final accuracy; PPO: final true reward).
    pub extra: f32,
}

/// Noise-robust trailing mean over a training curve.
fn tail_mean(values: &[f32], n: usize) -> f32 {
    let tail = &values[values.len().saturating_sub(n)..];
    if tail.is_empty() {
        return 0.0;
    }
    tail.iter().sum::<f32>() / tail.len() as f32
}

/// Step 1: supervised fine-tuning on correct demonstrations.
pub fn run_sft(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<StepReport> {
    let t0 = std::time::Instant::now();
    let b = he.manifest().batch;
    let mut report = StepReport { steps: recipe.sft_steps, ..Default::default() };
    let mut log = log;
    let mut losses = Vec::with_capacity(recipe.sft_steps);
    for step in 0..recipe.sft_steps {
        let batch = blend.sft_batch(rng, b);
        let lr = recipe.lr_at(recipe.sft_lr, step, recipe.sft_steps);
        let loss = he.sft_step(&batch, lr)?;
        if step == 0 {
            report.first_metric = loss;
        }
        losses.push(loss);
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[step as f64, loss as f64, lr as f64])?;
        }
    }
    report.last_metric = tail_mean(&losses, 10);
    report.wall_secs = t0.elapsed().as_secs_f64();
    // The SFT actor becomes the frozen PPO reference (and seeds the EMA).
    he.freeze_reference()?;
    Ok(report)
}

/// Step 2: reward-model fine-tuning on preference pairs.
pub fn run_rm(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<StepReport> {
    let t0 = std::time::Instant::now();
    let b = he.manifest().batch;
    let mut report = StepReport { steps: recipe.rm_steps, ..Default::default() };
    let mut log = log;
    let mut losses = Vec::with_capacity(recipe.rm_steps);
    for step in 0..recipe.rm_steps {
        let pb = blend.pair_batch(rng, b);
        let lr = recipe.lr_at(recipe.rm_lr, step, recipe.rm_steps);
        let (loss, acc) = he.rm_step(&pb, lr)?;
        if step == 0 {
            report.first_metric = loss;
        }
        losses.push(loss);
        let _ = acc;
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[step as f64, loss as f64, acc as f64, lr as f64])?;
        }
    }
    report.last_metric = tail_mean(&losses, 10);
    // Held-out accuracy over fresh pairs.
    let mut acc_sum = 0.0f32;
    let evals = 8;
    for _ in 0..evals {
        let pb = blend.pair_batch(rng, b);
        let (_, acc) = he.rm_eval(&pb)?;
        acc_sum += acc;
    }
    report.extra = acc_sum / evals as f32;
    report.wall_secs = t0.elapsed().as_secs_f64();
    // The trained RM is frozen for PPO; the critic continues from it.
    he.freeze_reward_model()?;
    Ok(report)
}

/// Step 3: PPO RLHF with EMA + mixture training.
pub fn run_ppo(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<(StepReport, Vec<IterStats>)> {
    let t0 = std::time::Instant::now();
    let mut trainer = PpoTrainer::new(recipe.ppo.clone(), recipe.seed ^ 0x9907);
    let mut report = StepReport { steps: recipe.ppo_iters, ..Default::default() };
    let mut history = Vec::with_capacity(recipe.ppo_iters);
    let mut log = log;
    let mut rewards = Vec::with_capacity(recipe.ppo_iters);
    for iter in 0..recipe.ppo_iters {
        let actor_lr = recipe.lr_at(recipe.actor_lr, iter, recipe.ppo_iters);
        let critic_lr = recipe.lr_at(recipe.critic_lr, iter, recipe.ppo_iters);
        let stats = trainer.iteration(he, blend, rng, actor_lr, critic_lr)?;
        if iter == 0 {
            report.first_metric = stats.true_reward;
        }
        rewards.push(stats.true_reward);
        report.extra = stats.rm_score;
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[
                iter as f64,
                stats.true_reward as f64,
                stats.rm_score as f64,
                stats.kl_to_ref as f64,
                stats.actor_loss as f64,
                stats.critic_loss as f64,
                stats.clipfrac as f64,
                stats.gen_secs,
                stats.train_secs,
            ])?;
        }
        history.push(stats);
    }
    report.last_metric = tail_mean(&rewards, 10);
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok((report, history))
}

/// All three steps, with optional CSV logging into `run_dir`.
pub struct PipelineReport {
    pub sft: StepReport,
    pub rm: StepReport,
    pub ppo: StepReport,
    pub ppo_history: Vec<IterStats>,
}

pub fn run_all(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    run_dir: Option<&Path>,
) -> Result<PipelineReport> {
    let mut rng = Rng::new(recipe.seed);
    let mut sft_log = match run_dir {
        Some(d) => Some(CsvWriter::create(d.join("sft.csv"), &["step", "loss", "lr"])?),
        None => None,
    };
    let sft = run_sft(he, blend, recipe, &mut rng, sft_log.as_mut())?;

    let mut rm_log = match run_dir {
        Some(d) => Some(CsvWriter::create(d.join("rm.csv"), &["step", "loss", "acc", "lr"])?),
        None => None,
    };
    let rm = run_rm(he, blend, recipe, &mut rng, rm_log.as_mut())?;

    let mut ppo_log = match run_dir {
        Some(d) => Some(CsvWriter::create(
            d.join("ppo.csv"),
            &[
                "iter", "true_reward", "rm_score", "kl", "actor_loss", "critic_loss",
                "clipfrac", "gen_secs", "train_secs",
            ],
        )?),
        None => None,
    };
    let (ppo, ppo_history) = run_ppo(he, blend, recipe, &mut rng, ppo_log.as_mut())?;

    Ok(PipelineReport { sft, rm, ppo, ppo_history })
}

/// Save / load the actor (used by `chat` and `serve` after training).
pub fn save_actor(he: &HybridEngine, path: impl AsRef<Path>) -> Result<()> {
    let host = he.actor.to_host()?;
    let named: Vec<(String, crate::runtime::HostTensor)> = he
        .manifest()
        .actor_params
        .iter()
        .map(|s| s.name.clone())
        .zip(host)
        .collect();
    checkpoint::save(path, &named)
}

pub fn load_actor(he: &mut HybridEngine, path: impl AsRef<Path>) -> Result<()> {
    let named = checkpoint::load(path)?;
    let specs = he.manifest().actor_params.clone();
    anyhow::ensure!(
        named.len() == specs.len(),
        "checkpoint has {} tensors, manifest expects {}",
        named.len(),
        specs.len()
    );
    let mut lits = Vec::with_capacity(named.len());
    for ((name, t), spec) in named.iter().zip(&specs) {
        anyhow::ensure!(
            name == &spec.name && t.shape() == spec.shape.as_slice(),
            "checkpoint tensor {name:?} {:?} does not match manifest {:?} {:?}",
            t.shape(),
            spec.name,
            spec.shape
        );
        lits.push(t.to_literal()?);
    }
    he.actor.replace(&he.engine.clone(), &lits)?;
    Ok(())
}
