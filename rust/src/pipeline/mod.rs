//! The full 3-step RLHF pipeline (paper §3 / Figure 1): the `train.py`
//! experience as a library. Each step driver logs a CSV curve and returns a
//! summary; `run_all` chains them exactly like DeepSpeed-Chat's single
//! script.
//!
//! # Checkpoint / rollback contract (training-layer fault tolerance)
//!
//! PPO runs are guarded at two nested scopes:
//!
//! * **In-run rollback** — every iteration goes through
//!   [`PpoTrainer::iteration_guarded`]: a host-side snapshot of the
//!   mutable training state is taken before the iteration, the resulting
//!   stats are validated by the anomaly guard, and a trip restores the
//!   snapshot and re-rolls under a perturbed rollout seed. This heals
//!   transient divergence (a NaN loss, a KL blowup) without touching disk.
//! * **Durable checkpoints** — [`run_ppo_from`] writes `ppo_ckpt.bin` into
//!   the run directory every [`TrainRecipe::ppo_ckpt_interval`] iterations
//!   (and at the end) via [`checkpoint::save_atomic`], so the newest
//!   checkpoint on disk is always complete. The container holds every
//!   param/optimizer store under a role prefix (`actor/…`, `ref_actor/…`,
//!   `critic/…`, `rm/…`, `actor_opt/…`, `critic_opt/…`, optional `ema/…`)
//!   plus a [`checkpoint::RunState`] record (iteration counter, data-RNG
//!   stream state, rollout/EMA phase counters). `dschat train --resume`
//!   reloads all of it with [`load_ppo_checkpoint`] and continues from the
//!   recorded iteration — the restored RNG stream and phase counters mean
//!   the resumed run draws what the uninterrupted run would have.

pub mod checkpoint;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, ensure, Result};

use checkpoint::RunState;

use crate::config::TrainRecipe;
use crate::coordinator::{IterStats, PpoTrainer};
use crate::data::Blend;
use crate::hybrid::HybridEngine;
use crate::runtime::{Engine, HostTensor, ParamStore};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;

/// Step summary used by EXPERIMENTS.md and the Table 4–6 analogues.
#[derive(Debug, Clone, Default)]
pub struct StepReport {
    pub steps: usize,
    pub wall_secs: f64,
    pub first_metric: f32,
    /// Mean of the final 10 steps' metric (noise-robust).
    pub last_metric: f32,
    /// Step-specific extra (RM: final accuracy; PPO: final true reward).
    pub extra: f32,
}

/// Noise-robust trailing mean over a training curve.
fn tail_mean(values: &[f32], n: usize) -> f32 {
    let tail = &values[values.len().saturating_sub(n)..];
    if tail.is_empty() {
        return 0.0;
    }
    tail.iter().sum::<f32>() / tail.len() as f32
}

/// Step 1: supervised fine-tuning on correct demonstrations.
pub fn run_sft(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<StepReport> {
    let t0 = std::time::Instant::now();
    let b = he.manifest().batch;
    let mut report = StepReport { steps: recipe.sft_steps, ..Default::default() };
    let mut log = log;
    let mut losses = Vec::with_capacity(recipe.sft_steps);
    for step in 0..recipe.sft_steps {
        let batch = blend.sft_batch(rng, b);
        let lr = recipe.lr_at(recipe.sft_lr, step, recipe.sft_steps);
        let loss = he.sft_step(&batch, lr)?;
        if step == 0 {
            report.first_metric = loss;
        }
        losses.push(loss);
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[step as f64, loss as f64, lr as f64])?;
        }
    }
    report.last_metric = tail_mean(&losses, 10);
    report.wall_secs = t0.elapsed().as_secs_f64();
    // The SFT actor becomes the frozen PPO reference (and seeds the EMA).
    he.freeze_reference()?;
    Ok(report)
}

/// Step 2: reward-model fine-tuning on preference pairs.
pub fn run_rm(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<StepReport> {
    let t0 = std::time::Instant::now();
    let b = he.manifest().batch;
    let mut report = StepReport { steps: recipe.rm_steps, ..Default::default() };
    let mut log = log;
    let mut losses = Vec::with_capacity(recipe.rm_steps);
    for step in 0..recipe.rm_steps {
        let pb = blend.pair_batch(rng, b);
        let lr = recipe.lr_at(recipe.rm_lr, step, recipe.rm_steps);
        let (loss, acc) = he.rm_step(&pb, lr)?;
        if step == 0 {
            report.first_metric = loss;
        }
        losses.push(loss);
        let _ = acc;
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[step as f64, loss as f64, acc as f64, lr as f64])?;
        }
    }
    report.last_metric = tail_mean(&losses, 10);
    // Held-out accuracy over fresh pairs.
    let mut acc_sum = 0.0f32;
    let evals = 8;
    for _ in 0..evals {
        let pb = blend.pair_batch(rng, b);
        let (_, acc) = he.rm_eval(&pb)?;
        acc_sum += acc;
    }
    report.extra = acc_sum / evals as f32;
    report.wall_secs = t0.elapsed().as_secs_f64();
    // The trained RM is frozen for PPO; the critic continues from it.
    he.freeze_reward_model()?;
    Ok(report)
}

/// Step 3: PPO RLHF with EMA + mixture training (fresh run, no durable
/// checkpointing — the full-control variant is [`run_ppo_from`]).
pub fn run_ppo(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
) -> Result<(StepReport, Vec<IterStats>)> {
    run_ppo_from(he, blend, recipe, rng, log, None, None)
}

/// Step 3 with the fault-tolerance controls exposed: every iteration runs
/// through the anomaly guard (see the module docs), `ckpt` enables durable
/// atomically-replaced checkpoints every
/// [`TrainRecipe::ppo_ckpt_interval`] iterations, and `resume` continues a
/// previous run from its [`RunState`] (the caller restores the params via
/// [`load_ppo_checkpoint`] first; this restores the RNG stream and phase
/// counters and skips the completed iterations).
pub fn run_ppo_from(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    rng: &mut Rng,
    log: Option<&mut CsvWriter>,
    ckpt: Option<&Path>,
    resume: Option<&RunState>,
) -> Result<(StepReport, Vec<IterStats>)> {
    let t0 = std::time::Instant::now();
    let mut trainer = if recipe.ppo.decode_chunk > 1 {
        // Fused N-token decode: the rollout scheduler drives the
        // `decode_chunk{N}` artifact, which samples on-device from its own
        // counter-RNG stream — so the trainer must carry the device
        // categorical backend (a host backend would need to see every
        // token before the next step) and the KV cache must serve paged
        // (chunked decode advances whole block runs).
        ensure!(
            recipe.ppo.rollout_batch > 0,
            "decode_chunk {} needs the continuous-batching rollout (set rollout_batch \
             to a positive multiple of the artifact batch) — the fixed-batch generate \
             path dispatches one step at a time by design",
            recipe.ppo.decode_chunk
        );
        let (k, vocab) = {
            let m = he.manifest();
            (m.sample_k, m.actor.vocab)
        };
        let sampler = crate::sampling::DeviceCategorical::new(
            crate::sampling::SamplerConfig {
                temperature: recipe.ppo.temperature,
                top_k: recipe.ppo.top_k,
                top_p: recipe.ppo.top_p,
                ..Default::default()
            },
            k,
            vocab,
        )?;
        he.use_paged_serving(true)?;
        PpoTrainer::with_backend(recipe.ppo.clone(), Box::new(sampler), recipe.seed ^ 0x9907)
    } else {
        PpoTrainer::new(recipe.ppo.clone(), recipe.seed ^ 0x9907)
    };
    let start = match resume {
        Some(rs) => {
            *rng = Rng::from_state(rs.rng_state, rs.rng_inc);
            trainer.set_progress(rs.rollouts_done, rs.ema_phase as usize);
            ensure!(
                (rs.iteration as usize) < recipe.ppo_iters,
                "checkpoint is already at iteration {} of {} — nothing to resume",
                rs.iteration,
                recipe.ppo_iters
            );
            rs.iteration as usize
        }
        None => 0,
    };
    let mut report = StepReport { steps: recipe.ppo_iters, ..Default::default() };
    let mut history = Vec::with_capacity(recipe.ppo_iters);
    let mut log = log;
    let mut rewards = Vec::with_capacity(recipe.ppo_iters);
    for iter in start..recipe.ppo_iters {
        let actor_lr = recipe.lr_at(recipe.actor_lr, iter, recipe.ppo_iters);
        let critic_lr = recipe.lr_at(recipe.critic_lr, iter, recipe.ppo_iters);
        let stats = trainer.iteration_guarded(he, blend, rng, actor_lr, critic_lr)?;
        if iter == start {
            report.first_metric = stats.true_reward;
        }
        rewards.push(stats.true_reward);
        report.extra = stats.rm_score;
        if let Some(w) = log.as_deref_mut() {
            w.rowf(&[
                iter as f64,
                stats.true_reward as f64,
                stats.rm_score as f64,
                stats.kl_to_ref as f64,
                stats.actor_loss as f64,
                stats.critic_loss as f64,
                stats.clipfrac as f64,
                stats.gen_secs,
                stats.train_secs,
            ])?;
        }
        history.push(stats);
        if let Some(path) = ckpt {
            let k = recipe.ppo_ckpt_interval;
            let done = iter + 1;
            if k > 0 && (done % k == 0 || done == recipe.ppo_iters) {
                let (rollouts_done, iters_done) = trainer.progress();
                let (rng_state, rng_inc) = rng.state();
                let rs = RunState {
                    iteration: done as u64,
                    rng_state,
                    rng_inc,
                    rollouts_done,
                    ema_phase: iters_done as u64,
                };
                let tel = he.telemetry.clone();
                tel.begin(
                    crate::telemetry::TID_CHECKPOINT,
                    "checkpoint",
                    done as u64,
                    iters_done as i64,
                );
                save_ppo_checkpoint(he, &rs, path)?;
                tel.end(
                    crate::telemetry::TID_CHECKPOINT,
                    "checkpoint",
                    done as u64,
                    iters_done as i64,
                );
            }
        }
    }
    if trainer.guard_trips > 0 {
        eprintln!(
            "[ppo] run finished with {} anomaly-guard trip(s) healed by rollback",
            trainer.guard_trips
        );
    }
    report.last_metric = tail_mean(&rewards, 10);
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok((report, history))
}

/// All three steps, with optional CSV logging into `run_dir`.
pub struct PipelineReport {
    pub sft: StepReport,
    pub rm: StepReport,
    pub ppo: StepReport,
    pub ppo_history: Vec<IterStats>,
}

pub fn run_all(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    run_dir: Option<&Path>,
) -> Result<PipelineReport> {
    let mut rng = Rng::new(recipe.seed);
    let mut sft_log = match run_dir {
        Some(d) => Some(CsvWriter::create(d.join("sft.csv"), &["step", "loss", "lr"])?),
        None => None,
    };
    let sft = run_sft(he, blend, recipe, &mut rng, sft_log.as_mut())?;

    let mut rm_log = match run_dir {
        Some(d) => Some(CsvWriter::create(d.join("rm.csv"), &["step", "loss", "acc", "lr"])?),
        None => None,
    };
    let rm = run_rm(he, blend, recipe, &mut rng, rm_log.as_mut())?;

    let mut ppo_log = match run_dir {
        Some(d) => Some(CsvWriter::create(
            d.join("ppo.csv"),
            &[
                "iter", "true_reward", "rm_score", "kl", "actor_loss", "critic_loss",
                "clipfrac", "gen_secs", "train_secs",
            ],
        )?),
        None => None,
    };
    let ckpt_path = run_dir.map(|d| d.join("ppo_ckpt.bin"));
    let (ppo, ppo_history) = run_ppo_from(
        he,
        blend,
        recipe,
        &mut rng,
        ppo_log.as_mut(),
        ckpt_path.as_deref(),
        None,
    )?;

    Ok(PipelineReport { sft, rm, ppo, ppo_history })
}

/// Save / load the actor (used by `chat` and `serve` after training).
pub fn save_actor(he: &HybridEngine, path: impl AsRef<Path>) -> Result<()> {
    let host = he.actor.to_host()?;
    let named: Vec<(String, crate::runtime::HostTensor)> = he
        .manifest()
        .actor_params
        .iter()
        .map(|s| s.name.clone())
        .zip(host)
        .collect();
    checkpoint::save(path, &named)
}

pub fn load_actor(he: &mut HybridEngine, path: impl AsRef<Path>) -> Result<()> {
    let named = checkpoint::load(path)?;
    let specs = he.manifest().actor_params.clone();
    anyhow::ensure!(
        named.len() == specs.len(),
        "checkpoint has {} tensors, manifest expects {}",
        named.len(),
        specs.len()
    );
    let mut lits = Vec::with_capacity(named.len());
    for ((name, t), spec) in named.iter().zip(&specs) {
        anyhow::ensure!(
            name == &spec.name && t.shape() == spec.shape.as_slice(),
            "checkpoint tensor {name:?} {:?} does not match manifest {:?} {:?}",
            t.shape(),
            spec.name,
            spec.shape
        );
        lits.push(t.to_literal()?);
    }
    he.actor.replace(&he.engine.clone(), &lits)?;
    Ok(())
}

fn append_store(
    out: &mut Vec<(String, HostTensor)>,
    prefix: &str,
    store: &ParamStore,
) -> Result<()> {
    let host = store.to_host()?;
    for (spec, t) in store.specs.iter().zip(host) {
        out.push((format!("{prefix}/{}", spec.name), t));
    }
    Ok(())
}

fn restore_store(
    map: &mut HashMap<String, HostTensor>,
    prefix: &str,
    store: &mut ParamStore,
    engine: &Engine,
) -> Result<()> {
    let mut lits = Vec::with_capacity(store.specs.len());
    for spec in &store.specs {
        let key = format!("{prefix}/{}", spec.name);
        let Some(t) = map.remove(&key) else {
            bail!("ppo checkpoint is missing tensor {key:?}");
        };
        ensure!(
            t.shape() == spec.shape.as_slice(),
            "ppo checkpoint tensor {key:?} has shape {:?}, manifest expects {:?}",
            t.shape(),
            spec.shape
        );
        lits.push(t.to_literal()?);
    }
    store.replace(engine, &lits)
}

/// Write the durable PPO checkpoint: every param/optimizer store under its
/// role prefix plus the [`RunState`] record, atomically replaced so a
/// crash mid-write preserves the previous checkpoint (see the module
/// docs for the full contract).
pub fn save_ppo_checkpoint(
    he: &HybridEngine,
    state: &RunState,
    path: impl AsRef<Path>,
) -> Result<()> {
    let mut tensors: Vec<(String, HostTensor)> = Vec::new();
    append_store(&mut tensors, "actor", &he.actor)?;
    append_store(&mut tensors, "ref_actor", &he.ref_actor)?;
    append_store(&mut tensors, "critic", &he.critic)?;
    append_store(&mut tensors, "rm", &he.rm)?;
    append_store(&mut tensors, "actor_opt", &he.actor_opt)?;
    append_store(&mut tensors, "critic_opt", &he.critic_opt)?;
    if let Some(ema) = &he.ema {
        append_store(&mut tensors, "ema", ema)?;
    }
    tensors.push(state.to_tensor());
    checkpoint::save_atomic(path, &tensors)
}

/// Load a [`save_ppo_checkpoint`] container back into the engine (all six
/// stores + the EMA shadow when present, validated by name and shape) and
/// return its [`RunState`] for [`run_ppo_from`]'s `resume`.
pub fn load_ppo_checkpoint(
    he: &mut HybridEngine,
    path: impl AsRef<Path>,
) -> Result<RunState> {
    let named = checkpoint::load(&path)?;
    let mut map: HashMap<String, HostTensor> = named.into_iter().collect();
    let Some(rs_t) = map.remove(RunState::TENSOR_NAME) else {
        bail!(
            "checkpoint {:?} carries no run state — this is not a resumable PPO \
             checkpoint (actor-only checkpoints load via the chat/serve path)",
            path.as_ref()
        );
    };
    let state = RunState::from_tensor(&rs_t)?;
    let engine = he.engine.clone();
    restore_store(&mut map, "actor", &mut he.actor, &engine)?;
    restore_store(&mut map, "ref_actor", &mut he.ref_actor, &engine)?;
    restore_store(&mut map, "critic", &mut he.critic, &engine)?;
    restore_store(&mut map, "rm", &mut he.rm, &engine)?;
    restore_store(&mut map, "actor_opt", &mut he.actor_opt, &engine)?;
    restore_store(&mut map, "critic_opt", &mut he.critic_opt, &engine)?;
    let ckpt_has_ema = map.keys().any(|k| k.starts_with("ema/"));
    match (&mut he.ema, ckpt_has_ema) {
        (Some(store), true) => restore_store(&mut map, "ema", store, &engine)?,
        (None, false) => {}
        (have, _) => bail!(
            "EMA mismatch: the engine {} an EMA shadow but the checkpoint {} one — \
             rerun with the matching --ema setting",
            if have.is_some() { "has" } else { "lacks" },
            if ckpt_has_ema { "carries" } else { "lacks" }
        ),
    }
    if !map.is_empty() {
        let mut extras: Vec<&String> = map.keys().collect();
        extras.sort();
        bail!(
            "ppo checkpoint has {} unrecognized tensor(s), e.g. {:?}",
            extras.len(),
            extras[0]
        );
    }
    Ok(state)
}
