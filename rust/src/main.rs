//! `dschat` — the DeepSpeed-Chat reproduction CLI.
//!
//! Mirrors the paper's single-script experience (`python train.py
//! --actor-model ... --deployment-type ...`) plus the simulator front-ends:
//!
//! ```text
//! dschat train    --run tiny --sft-steps 300 --rm-steps 150 --ppo-iters 50
//! dschat chat     --run tiny --ckpt runs/tiny/actor.bin
//! dschat tables               # regenerate paper Tables 1-6 (simulator)
//! dschat figures              # regenerate paper Figures 3-7 (simulator)
//! dschat stats    --run tiny  # artifact/manifest inventory
//! ```

use std::path::PathBuf;
use std::rc::Rc;

use anyhow::Result;

use dschat::config::{PpoConfig, TrainRecipe};
use dschat::data::synthetic::{Mode, TaskGen};
use dschat::data::{Blend, DataSplit};
use dschat::hybrid::HybridEngine;
use dschat::pipeline;
use dschat::runtime::{Engine, Manifest};
use dschat::util::argparse::Args;
use dschat::util::fmt_duration;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let code = match run(cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "train" => train(args),
        "chat" => chat(args),
        "tables" => {
            for t in dschat::report::all_tables() {
                t.print();
            }
            Ok(())
        }
        "figures" => {
            for t in dschat::report::all_figures() {
                t.print();
            }
            Ok(())
        }
        "stats" => stats(args),
        "simulate" => simulate(args),
        "help" | _ => {
            println!(
                "dschat — DeepSpeed-Chat reproduction (rust + JAX + Pallas)\n\n\
                 commands:\n\
                 \x20 train    run the 3-step RLHF pipeline on AOT artifacts\n\
                 \x20 chat     interactive session with a trained actor\n\
                 \x20 tables   regenerate paper Tables 1-6 (cluster simulator)\n\
                 \x20 figures  regenerate paper Figures 3-7 (cluster simulator)\n\
                 \x20 stats    manifest/artifact inventory for a run config\n\
                 \x20 simulate what-if Step-3 simulation (--model opt-13b --nodes 2\n\
                 \x20          --gpu a100-80g --system ds-he|hf-ddp|colossal-ai)\n\n\
                 common flags: --run <tiny|small> --artifacts <dir> --seed <n>\n\
                 train flags:  --sft-steps N --rm-steps N --ppo-iters N --ema <bool>\n\
                 \x20             --ptx-coef X --kl-coef X --out runs/<name>\n\
                 \x20             --ckpt-interval N   durable PPO checkpoint every N iters (0 off)\n\
                 \x20             --resume            continue PPO from <out>/ppo_ckpt.bin\n\
                 \x20             --fault-iter N      chaos drill: poison iteration N's loss\n\
                 \x20                                 with NaN to exercise the rollback path\n\
                 \x20             --trace-out F       Chrome trace-event JSON (Perfetto) at exit\n\
                 \x20             --metrics-out F     unified JSON metrics snapshot at exit"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> String {
    let run = args.str("run", "tiny");
    args.str("artifacts", &format!("artifacts/{run}"))
}

fn make_blend(m: &Manifest) -> Blend {
    // Two blended sources (75/25) exercising the paper's data-blending
    // capability, split 2/4/4 across the three stages like DeepSpeed-Chat's
    // default `data_split`.
    let all = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len);
    let counting = TaskGen::new(m.actor.vocab, m.prompt_len, m.gen_len)
        .with_modes(vec![Mode::Count]);
    Blend::new(vec![(all, 3.0), (counting, 1.0)], DataSplit::new(2.0, 4.0, 4.0))
}

fn train(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let seed = args.usize("seed", 0) as i32;
    let with_ema = args.bool("ema", true);
    let recipe = TrainRecipe {
        run: args.str("run", "tiny"),
        seed: seed as u64,
        sft_steps: args.usize("sft-steps", 300),
        sft_lr: args.f64("sft-lr", 1e-2) as f32,
        rm_steps: args.usize("rm-steps", 200),
        rm_lr: args.f64("rm-lr", 3e-3) as f32,
        ppo_iters: args.usize("ppo-iters", 60),
        actor_lr: args.f64("actor-lr", 3e-4) as f32,
        critic_lr: args.f64("critic-lr", 1e-3) as f32,
        ppo: PpoConfig {
            ptx_coef: args.f64("ptx-coef", 0.2) as f32,
            kl_coef: args.f64("kl-coef", 0.1) as f32,
            ema_decay: if with_ema { Some(0.992) } else { None },
            fault_iteration: args.get("fault-iter").map(|_| args.usize("fault-iter", 0)),
            ..Default::default()
        },
        ppo_ckpt_interval: args.usize("ckpt-interval", 20),
        ..Default::default()
    };
    let out = PathBuf::from(args.str("out", &format!("runs/{}", recipe.run)));
    std::fs::create_dir_all(&out)?;

    println!("== dschat train ==");
    let engine = Rc::new(Engine::cpu()?);
    println!("platform: {}", engine.platform());
    let mut he = HybridEngine::init(engine, &dir, seed, with_ema)?;
    let m = he.manifest();
    println!(
        "actor: {} ({} params)  critic: {} ({} params)  batch {}  seq {}",
        m.actor.name,
        dschat::util::fmt_count(m.actor.n_params() as f64),
        m.critic.name,
        dschat::util::fmt_count(m.critic.n_params() as f64),
        m.batch,
        m.seq_len,
    );
    let mut blend = make_blend(he.manifest());

    // Pipeline-phase tracing (rollout / score / train step / checkpoint /
    // guard rollback spans) + the unified metrics snapshot: enabled
    // whenever either output flag is given.
    let trace_out = args.get("trace-out").map(|s| s.to_string());
    let metrics_out = args.get("metrics-out").map(|s| s.to_string());
    if trace_out.is_some() || metrics_out.is_some() {
        he.set_telemetry(dschat::telemetry::Telemetry::enabled_default());
    }

    if args.bool("resume", false) {
        let r = resume_ppo(&mut he, &mut blend, &recipe, &out, with_ema);
        write_telemetry_outputs(&he, &[], trace_out.as_deref(), metrics_out.as_deref())?;
        return r;
    }

    let report = pipeline::run_all(&mut he, &mut blend, &recipe, Some(&out))?;

    println!("\n-- step 1 (SFT):  loss {:.3} -> {:.3}  [{}]",
        report.sft.first_metric, report.sft.last_metric, fmt_duration(report.sft.wall_secs));
    println!("-- step 2 (RM):   loss {:.3} -> {:.3}, held-out acc {:.1}%  [{}]",
        report.rm.first_metric, report.rm.last_metric, 100.0 * report.rm.extra,
        fmt_duration(report.rm.wall_secs));
    println!("-- step 3 (PPO):  true reward {:.3} -> {:.3}  [{}]",
        report.ppo.first_metric, report.ppo.last_metric, fmt_duration(report.ppo.wall_secs));
    println!(
        "   phases: gen {} ({} tok, {:.1} tok/s) | train {} | {} mode flips",
        fmt_duration(he.stats.gen_secs),
        he.stats.gen_tokens,
        he.stats.gen_tok_per_sec(),
        fmt_duration(he.stats.train_secs),
        he.stats.mode_flips,
    );
    let (up, down) = he.engine.bytes_moved();
    let fallbacks = he.engine.fallback_untuples();
    println!(
        "   host transfer: {} up, {} down ({} fused-tuple fallbacks; K/V and params stay on device)",
        dschat::util::fmt_bytes(up as f64),
        dschat::util::fmt_bytes(down as f64),
        fallbacks,
    );
    if fallbacks > 0 {
        eprintln!(
            "[train] WARNING: {fallbacks} fused-tuple fallback(s) — artifact outputs \
             were copied through host literals instead of donated device tuples; \
             throughput is degraded (stale artifacts? re-run `make artifacts`)"
        );
    }
    write_telemetry_outputs(
        &he,
        &report.ppo_history,
        trace_out.as_deref(),
        metrics_out.as_deref(),
    )?;
    if args.bool("ema", true) {
        he.promote_ema()?;
        println!("   promoted EMA checkpoint as the serving actor");
    }
    let ckpt = out.join("actor.bin");
    pipeline::save_actor(&he, &ckpt)?;
    println!("   saved actor to {}", ckpt.display());
    println!("   curves: {}/sft.csv rm.csv ppo.csv", out.display());
    Ok(())
}

/// Write the training run's telemetry artifacts: the Chrome trace-event
/// JSON (`--trace-out`, Perfetto-loadable pipeline-phase timeline) and the
/// unified metrics snapshot (`--metrics-out`, runtime + training + KV +
/// histograms in one document).
fn write_telemetry_outputs(
    he: &HybridEngine,
    history: &[dschat::coordinator::IterStats],
    trace_out: Option<&str>,
    metrics_out: Option<&str>,
) -> Result<()> {
    if let Some(path) = trace_out {
        std::fs::write(path, he.telemetry.chrome_trace_json())?;
        println!("   wrote Chrome trace ({} events) to {path}", he.telemetry.event_count());
    }
    if let Some(path) = metrics_out {
        let snapshot = dschat::telemetry::metrics_snapshot_json(
            &he.engine.stats(),
            None,
            history,
            he.kv_occupancy().as_ref(),
            &he.telemetry,
        );
        std::fs::write(path, snapshot)?;
        println!("   wrote metrics snapshot to {path}");
    }
    Ok(())
}

/// `dschat train --resume`: skip SFT/RM and continue Step 3 from the last
/// durable checkpoint in the run directory — all six param/optimizer
/// stores, the RNG stream, and the phase counters come from the
/// checkpoint, so the resumed run continues where the interrupted one
/// stopped.
fn resume_ppo(
    he: &mut HybridEngine,
    blend: &mut Blend,
    recipe: &TrainRecipe,
    out: &std::path::Path,
    with_ema: bool,
) -> Result<()> {
    let ckpt = out.join("ppo_ckpt.bin");
    let state = pipeline::load_ppo_checkpoint(he, &ckpt)?;
    println!(
        "resuming PPO from {} at iteration {}/{}",
        ckpt.display(),
        state.iteration,
        recipe.ppo_iters
    );
    // Overwritten from the checkpointed stream inside run_ppo_from.
    let mut rng = dschat::util::rng::Rng::new(recipe.seed);
    let mut log = dschat::util::csv::CsvWriter::create(
        out.join("ppo_resume.csv"),
        &[
            "iter", "true_reward", "rm_score", "kl", "actor_loss", "critic_loss",
            "clipfrac", "gen_secs", "train_secs",
        ],
    )?;
    let (ppo, _history) = pipeline::run_ppo_from(
        he,
        blend,
        recipe,
        &mut rng,
        Some(&mut log),
        Some(&ckpt),
        Some(&state),
    )?;
    println!(
        "-- step 3 (PPO, resumed): true reward {:.3} -> {:.3}  [{}]",
        ppo.first_metric,
        ppo.last_metric,
        fmt_duration(ppo.wall_secs)
    );
    if with_ema {
        he.promote_ema()?;
        println!("   promoted EMA checkpoint as the serving actor");
    }
    let actor_ckpt = out.join("actor.bin");
    pipeline::save_actor(he, &actor_ckpt)?;
    println!("   saved actor to {}", actor_ckpt.display());
    println!("   resumed curve: {}/ppo_resume.csv", out.display());
    Ok(())
}

fn chat(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let engine = Rc::new(Engine::cpu()?);
    let mut he = HybridEngine::init(engine, &dir, 0, false)?;
    if let Some(ckpt) = args.get("ckpt") {
        pipeline::load_actor(&mut he, ckpt)?;
        println!("loaded {ckpt}");
    } else {
        println!("note: no --ckpt given; chatting with an untrained actor");
    }
    dschat::examples_support::chat_loop(&mut he, args.usize("turns", 4), args.usize("seed", 1) as u64)
}

/// What-if simulator front-end: one Step-3 run on an arbitrary deployment.
fn simulate(args: &Args) -> Result<()> {
    use dschat::baselines::{colossal_ai, ds_he, hf_ddp};
    use dschat::config::model;
    use dschat::sim::{a100_40g, a100_80g, a6000_48g, simulate_step3, v100_32g, Cluster, Recipe};

    let m = model(&args.str("model", "opt-13b"));
    let critic = model(&args.str("critic", "opt-350m"));
    let gpu = match args.str("gpu", "a100-80g").as_str() {
        "v100-32g" => v100_32g(),
        "a6000-48g" => a6000_48g(),
        "a100-40g" => a100_40g(),
        "a100-80g" => a100_80g(),
        other => anyhow::bail!("unknown gpu {other:?} (v100-32g|a6000-48g|a100-40g|a100-80g)"),
    };
    let nodes = args.usize("nodes", 1);
    let cluster = if args.usize("gpus-per-node", 8) == 1 || nodes == 0 {
        Cluster::single(gpu)
    } else {
        Cluster::dgx(gpu, nodes.max(1))
    };
    let sys = match args.str("system", "ds-he").as_str() {
        "ds-he" => ds_he(),
        "hf-ddp" => hf_ddp(),
        "colossal-ai" => colossal_ai(),
        other => anyhow::bail!("unknown system {other:?} (ds-he|hf-ddp|colossal-ai)"),
    };
    let recipe = Recipe {
        global_batch: args.usize("global-batch", 1024) as u64,
        prompt_len: args.usize("prompt-len", 256) as u64,
        gen_len: args.usize("gen-len", 256) as u64,
        dataset_pairs: args.usize("dataset-pairs", 263_800) as u64,
    };
    println!(
        "simulating {} | actor {} ({}) | {} GPUs ({} x {})",
        sys.name,
        m.name,
        dschat::util::fmt_count(m.n_params() as f64),
        cluster.world(),
        cluster.nodes,
        cluster.gpu.name
    );
    match simulate_step3(&sys, &m, &critic, &cluster, &recipe) {
        None => println!("OOM: this deployment cannot hold the Step-3 working set"),
        Some(o) => {
            let epoch = o.iter_secs() * recipe.steps_per_epoch() as f64;
            println!("per-iteration: gen {} (mb {} x {} waves) + train {} (mb {})",
                fmt_duration(o.gen_secs), o.gen_microbatch, o.gen_waves,
                fmt_duration(o.train_secs), o.train_microbatch);
            println!("throughput: {:.3} pairs/s | {:.0} effective TFLOPs/GPU (gen {:.0}, train {:.0})",
                o.pairs_per_sec, o.effective_tflops_per_gpu, o.gen_tflops_per_gpu,
                o.train_tflops_per_gpu);
            println!("one epoch ({} steps): {}  (~${:.0} on Azure)",
                recipe.steps_per_epoch(), fmt_duration(epoch), cluster.dollars(epoch));
        }
    }
    Ok(())
}

fn stats(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let m = Manifest::load(&dir)?;
    m.validate()?;
    println!("run {:?} at {}", m.run, dir);
    println!(
        "actor {} ({} params, {} tensors)  critic {} ({} params)",
        m.actor.name,
        dschat::util::fmt_count(m.actor.n_params() as f64),
        m.actor_params.len(),
        m.critic.name,
        dschat::util::fmt_count(m.critic.n_params() as f64),
    );
    println!("batch {}  prompt {}  gen {}", m.batch, m.prompt_len, m.gen_len);
    println!("{} artifacts:", m.artifacts.len());
    for (name, a) in &m.artifacts {
        println!(
            "  {name:<20} {:>3} inputs  -> {:?}  ({} HLO)",
            a.inputs.len(),
            a.outputs,
            dschat::util::fmt_bytes(a.hlo_bytes as f64),
        );
    }
    Ok(())
}
