//! Training recipes: the knobs `train.py` exposes, mirroring DeepSpeed-Chat's
//! three-step pipeline options (§3 of the paper), including the two features
//! other frameworks omit: EMA collection and mixture (ptx) training.

/// PPO / Step-3 hyper-parameters (InstructGPT defaults).
#[derive(Debug, Clone)]
pub struct PpoConfig {
    /// PPO clip epsilon for both actor ratio and critic value clipping.
    pub clip_eps: f32,
    /// KL penalty coefficient against the frozen reference policy.
    pub kl_coef: f32,
    /// GAE discount.
    pub gamma: f32,
    /// GAE lambda.
    pub lam: f32,
    /// PPO epochs per experience batch.
    pub ppo_epochs: usize,
    /// Mixture-training (pretraining objective) coefficient; 0 disables.
    pub ptx_coef: f32,
    /// EMA decay for checkpoint collection; None disables EMA.
    pub ema_decay: Option<f32>,
    /// Apply the EMA artifact every k iterations with decay^k (§Perf: the
    /// EMA step is fetch-bound — every param round-trips the tuple output —
    /// so amortizing it across iterations buys back wall-clock at equal
    /// effective decay).
    pub ema_interval: usize,
    /// Clip the per-token KL-shaped reward to this magnitude.
    pub reward_clip: f32,
    /// Whiten advantages per batch.
    pub whiten_advantages: bool,
    /// Sampling temperature during experience generation.
    pub temperature: f32,
    /// Top-k during experience generation (0 = disabled).
    pub top_k: usize,
    /// Top-p during experience generation (1.0 = disabled).
    pub top_p: f32,
    /// Prompts rolled out per PPO iteration through the continuous-batching
    /// scheduler (`crate::rollout`): must be a positive multiple of the
    /// artifact batch `b`; EOS-retired slots admit the next prompt, and the
    /// experience buffer flushes one scored training batch per `b`
    /// completions. `0` (default) selects the legacy fixed-batch
    /// `generate` path with exactly `b` prompts.
    pub rollout_batch: usize,
    /// Minimum prompt length for HETEROGENEOUS-length rollout prompts:
    /// `0` (default) keeps every prompt at the artifact's fixed
    /// `prompt_len`; a positive value makes the scheduler-rollout path
    /// draw each prompt's length uniformly from `[min_prompt_len,
    /// prompt_len]` (clamped to the synthetic task's 5-token structural
    /// floor), exercising the left-padded variable-length serving path.
    /// Requires artifacts with the `padded_prompts` capability; only
    /// meaningful with `rollout_batch > 0`.
    pub min_prompt_len: usize,
    /// Fused decode steps per scheduler tick during continuous rollouts:
    /// `1` (default) dispatches one artifact call per generated token
    /// (legacy stepwise path, bit-compatible with every prior run); `N > 1`
    /// drives the `decode_chunk{N}` artifact, sampling N tokens per live
    /// slot on-device per dispatch. Requires `rollout_batch > 0`, a
    /// device-RNG sampling backend ([`crate::sampling::DeviceCategorical`])
    /// and artifacts built with the matching `decode_chunk{N}` capability.
    pub decode_chunk: usize,
    /// Anomaly-guard threshold on an iteration's |approx_kl| (ChatGLM-RLHF
    /// style training stabilization: a KL blowup means the policy jumped
    /// off the trust region and the iteration should be rolled back).
    /// Non-finite stats always trip the guard; `0` disables this
    /// threshold. The default is generous — healthy runs sit orders of
    /// magnitude below it.
    pub max_approx_kl: f32,
    /// Anomaly-guard threshold on an iteration's clip fraction (nearly
    /// every sample clipping means the update was far off-policy). `0`
    /// disables.
    pub max_clipfrac: f32,
    /// Consecutive anomaly-guard trips tolerated before the trainer bails
    /// loudly instead of looping rollback/re-roll on a divergent run.
    pub max_guard_trips: usize,
    /// Chaos-drill hook (`dschat train --fault-iter N`): poison the
    /// reported actor loss with NaN once, at guarded iteration N, to
    /// exercise the anomaly-guard rollback path end to end. `None` in
    /// production.
    pub fault_iteration: Option<usize>,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            clip_eps: 0.2,
            kl_coef: 0.1,
            gamma: 1.0,
            lam: 0.95,
            ppo_epochs: 1,
            ptx_coef: 0.0,
            ema_decay: Some(0.992),
            ema_interval: 1,
            reward_clip: 5.0,
            whiten_advantages: true,
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            rollout_batch: 0,
            min_prompt_len: 0,
            decode_chunk: 1,
            max_approx_kl: 25.0,
            max_clipfrac: 0.999,
            max_guard_trips: 3,
            fault_iteration: None,
        }
    }
}

/// The full three-step recipe.
#[derive(Debug, Clone)]
pub struct TrainRecipe {
    pub run: String,
    pub seed: u64,
    pub sft_steps: usize,
    pub sft_lr: f32,
    pub rm_steps: usize,
    pub rm_lr: f32,
    pub ppo_iters: usize,
    pub actor_lr: f32,
    pub critic_lr: f32,
    pub ppo: PpoConfig,
    /// Warmup fraction of total steps for the linear LR schedule.
    pub warmup_frac: f32,
    /// Write a durable, atomically-replaced PPO checkpoint
    /// (`ppo_ckpt.bin` + run state, the `dschat train --resume` target)
    /// every k iterations when a run directory is given. `0` disables.
    pub ppo_ckpt_interval: usize,
}

impl Default for TrainRecipe {
    fn default() -> Self {
        TrainRecipe {
            run: "tiny".into(),
            seed: 0,
            sft_steps: 200,
            sft_lr: 3e-3,
            rm_steps: 150,
            rm_lr: 2e-3,
            ppo_iters: 100,
            actor_lr: 3e-4,
            critic_lr: 1e-3,
            ppo: PpoConfig::default(),
            warmup_frac: 0.05,
            ppo_ckpt_interval: 20,
        }
    }
}

impl TrainRecipe {
    /// Linear warmup then linear decay to 10% — the schedule DeepSpeed-Chat's
    /// examples use.
    pub fn lr_at(&self, base: f32, step: usize, total: usize) -> f32 {
        let total = total.max(1);
        let warmup = ((total as f32 * self.warmup_frac) as usize).max(1);
        if step < warmup {
            base * (step + 1) as f32 / warmup as f32
        } else {
            let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
            base * (1.0 - 0.9 * t.min(1.0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let r = TrainRecipe::default();
        let base = 1.0;
        let total = 100;
        // warmup rises
        assert!(r.lr_at(base, 0, total) < r.lr_at(base, 4, total));
        // peak near warmup end
        let peak = r.lr_at(base, 5, total);
        assert!((peak - base).abs() < 0.05, "{peak}");
        // decays to ~10%
        let last = r.lr_at(base, total - 1, total);
        assert!((0.08..0.2).contains(&last), "{last}");
    }

    #[test]
    fn lr_never_negative_or_above_base() {
        let r = TrainRecipe::default();
        for s in 0..500 {
            let lr = r.lr_at(2.0, s, 200);
            assert!(lr > 0.0 && lr <= 2.0 + 1e-6, "step {s}: {lr}");
        }
    }
}
