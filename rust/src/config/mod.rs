//! Configuration: the OPT model zoo (paper scales for the simulator, real
//! small scales for the CPU runs), training recipes, and the PPO/RLHF
//! hyper-parameters. Mirrors `python/compile/configs.py` for the real runs.

pub mod recipe;

pub use recipe::{PpoConfig, TrainRecipe};

/// Decoder-only transformer architecture shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameters (tied LM head; matches `configs.py::n_params`).
    pub fn n_params(&self) -> u64 {
        let (d, v, s, ff) = (
            self.d_model as u64,
            self.vocab as u64,
            self.max_seq as u64,
            self.d_ff as u64,
        );
        let per_layer = 4 * d * d + 2 * d * ff + ff + d + 4 * d;
        v * d + s * d + self.n_layers as u64 * per_layer + 2 * d
    }

    /// FLOPs for one forward pass over `tokens` tokens (2·params·tokens,
    /// attention quadratic term included separately).
    pub fn fwd_flops(&self, tokens: u64, seq_len: u64) -> u64 {
        let matmul = 2 * self.n_params() * tokens;
        // attention scores+context: 2 * 2 * s * d per token
        let attn = 4 * tokens * seq_len * self.d_model as u64;
        matmul + attn
    }

    /// FLOPs for forward+backward (the standard 3x forward approximation).
    pub fn fwd_bwd_flops(&self, tokens: u64, seq_len: u64) -> u64 {
        3 * self.fwd_flops(tokens, seq_len)
    }

    /// Bytes read per generated token in the decode phase (every parameter
    /// once, fp16) — the paper's "memory-bandwidth-bound" generation model.
    pub fn decode_bytes_per_token(&self, dtype_bytes: u64) -> u64 {
        self.n_params() * dtype_bytes
    }

    /// KV-cache bytes for a batch at full sequence length.
    pub fn kv_cache_bytes(&self, batch: u64, seq: u64, dtype_bytes: u64) -> u64 {
        2 * self.n_layers as u64 * batch * seq * self.d_model as u64 * dtype_bytes
    }
}

/// The OPT family at the paper's scales (OPT paper table 1) plus the small
/// real configs that ship as AOT artifacts.
pub fn model_zoo() -> Vec<ModelConfig> {
    let opt = |name: &str, l, d, h| ModelConfig {
        name: name.into(),
        vocab: 50272,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_ff: 4 * d,
        max_seq: 2048,
    };
    let real = |name: &str, v, d, l, h, ff, s| ModelConfig {
        name: name.into(),
        vocab: v,
        d_model: d,
        n_layers: l,
        n_heads: h,
        d_ff: ff,
        max_seq: s,
    };
    vec![
        // paper scales (simulator)
        opt("opt-125m", 12, 768, 12),
        opt("opt-350m", 24, 1024, 16),
        opt("opt-1.3b", 24, 2048, 32),
        opt("opt-2.7b", 32, 2560, 32),
        opt("opt-6.7b", 32, 4096, 32),
        opt("opt-13b", 40, 5120, 40),
        opt("opt-30b", 48, 7168, 56),
        opt("opt-66b", 64, 9216, 72),
        opt("opt-175b", 96, 12288, 96),
        // real AOT scales (mirror python/compile/configs.py)
        real("nano", 256, 32, 1, 2, 64, 64),
        real("tiny", 256, 64, 2, 2, 256, 64),
        real("small", 512, 128, 4, 4, 512, 128),
        real("base", 512, 256, 6, 8, 1024, 128),
        real("medium", 512, 512, 8, 8, 2048, 256),
    ]
}

pub fn model(name: &str) -> ModelConfig {
    model_zoo()
        .into_iter()
        .find(|m| m.name == name)
        .unwrap_or_else(|| panic!("unknown model {name:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opt_param_counts_match_published() {
        // Published OPT sizes; tolerate ±10% (embedding conventions differ).
        for (name, published) in [
            ("opt-125m", 125e6),
            ("opt-350m", 350e6),
            ("opt-1.3b", 1.3e9),
            ("opt-2.7b", 2.7e9),
            ("opt-6.7b", 6.7e9),
            ("opt-13b", 13e9),
            ("opt-30b", 30e9),
            ("opt-66b", 66e9),
            ("opt-175b", 175e9),
        ] {
            let n = model(name).n_params() as f64;
            let ratio = n / published;
            assert!(
                (0.9..1.15).contains(&ratio),
                "{name}: computed {n:.3e} vs published {published:.3e} (ratio {ratio:.3})"
            );
        }
    }

    #[test]
    fn real_configs_match_python() {
        // Mirror of python/compile/configs.py — keep in lockstep.
        let t = model("tiny");
        assert_eq!((t.vocab, t.d_model, t.n_layers, t.n_heads, t.d_ff, t.max_seq),
                   (256, 64, 2, 2, 256, 64));
        let b = model("base");
        assert_eq!((b.vocab, b.d_model, b.n_layers), (512, 256, 6));
    }

    #[test]
    fn flops_scale_linearly_in_tokens() {
        let m = model("opt-1.3b");
        assert_eq!(m.fwd_flops(2000, 512), 2 * m.fwd_flops(1000, 512));
    }

    #[test]
    fn kv_cache_example() {
        // 1.3B, batch 8, seq 512, fp16: 2*24*8*512*2048*2 = 805 MiB
        let m = model("opt-1.3b");
        assert_eq!(m.kv_cache_bytes(8, 512, 2), 2 * 24 * 8 * 512 * 2048 * 2);
    }
}
