//! Byte-exact memory accounting for the hybrid engine.
//!
//! Tracks every named allocation (params, optimizer, KV cache) plus the
//! high-water mark, mirroring what the GPU version must fit in HBM. The
//! simulator (`sim::memory`) applies the same ledger to paper-scale models
//! to reproduce Table 3 (max model per GPU) and Figure 7's batch planning.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct MemoryTracker {
    live: BTreeMap<String, usize>,
    total: usize,
    peak: usize,
    events: Vec<(String, isize)>,
}

impl MemoryTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, name: &str, bytes: usize) {
        *self.live.entry(name.to_string()).or_insert(0) += bytes;
        self.total += bytes;
        self.peak = self.peak.max(self.total);
        self.events.push((name.to_string(), bytes as isize));
    }

    pub fn free(&mut self, name: &str, bytes: usize) {
        let e = self
            .live
            .get_mut(name)
            .unwrap_or_else(|| panic!("free of unknown allocation {name:?}"));
        assert!(*e >= bytes, "free {bytes} > live {e} for {name:?}");
        *e -= bytes;
        self.total -= bytes;
        self.events.push((name.to_string(), -(bytes as isize)));
    }

    pub fn live_bytes(&self) -> usize {
        self.total
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak
    }

    pub fn live_named(&self, name: &str) -> usize {
        self.live.get(name).copied().unwrap_or(0)
    }

    pub fn breakdown(&self) -> Vec<(String, usize)> {
        self.live
            .iter()
            .filter(|(_, &b)| b > 0)
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_tracks_high_water() {
        let mut m = MemoryTracker::new();
        m.alloc("a", 100);
        m.alloc("kv", 50);
        assert_eq!(m.live_bytes(), 150);
        assert_eq!(m.peak_bytes(), 150);
        m.free("kv", 50);
        assert_eq!(m.live_bytes(), 100);
        assert_eq!(m.peak_bytes(), 150);
        m.alloc("kv", 20);
        assert_eq!(m.peak_bytes(), 150);
    }

    #[test]
    #[should_panic(expected = "free of unknown")]
    fn free_unknown_panics() {
        MemoryTracker::new().free("ghost", 1);
    }

    #[test]
    #[should_panic(expected = "free 10 > live")]
    fn overfree_panics() {
        let mut m = MemoryTracker::new();
        m.alloc("a", 5);
        m.free("a", 10);
    }

    #[test]
    fn breakdown_hides_zero() {
        let mut m = MemoryTracker::new();
        m.alloc("a", 5);
        m.alloc("b", 7);
        m.free("a", 5);
        assert_eq!(m.breakdown(), vec![("b".to_string(), 7)]);
    }
}
