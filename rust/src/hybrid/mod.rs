//! The Hybrid Engine (DeepSpeed-HE, paper §4): one runtime that flips the
//! actor between **inference mode** (experience generation over a KV cache,
//! decode-attention kernel, token-level loop) and **training mode** (PPO
//! updates over full sequences), reconfiguring memory at each boundary.
//!
//! On the paper's GPUs the flip swaps tensor-parallel inference sharding for
//! ZeRO training sharding; on this CPU testbed the flip swaps executables
//! and the KV-cache buffer pool while the [`MemoryTracker`] accounts for
//! every byte the way the GPU version would (`zero::MemoryModel` maps the
//! same accounting onto paper-scale hardware in the simulator).

pub mod kv;
pub mod memory;

pub use kv::KvCache;
pub use memory::MemoryTracker;

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};
use xla::{Literal, PjRtBuffer};

use crate::data::{PairBatch, TokenBatch};
use crate::runtime::{ArtifactSet, Engine, HostTensor, ParamStore};
use crate::sampling::Sampler;

/// Which configuration the actor model is currently in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// ZeRO-style training configuration (full-sequence fwd/bwd).
    Train,
    /// Inference configuration (KV cache alive, decode executables hot).
    Inference,
}

/// Per-phase timing/throughput accounting (drives Figure 5/6 analogues).
#[derive(Debug, Default, Clone)]
pub struct PhaseStats {
    pub gen_secs: f64,
    pub gen_tokens: u64,
    pub train_secs: f64,
    pub train_tokens: u64,
    pub mode_flips: u64,
    pub flip_secs: f64,
}

impl PhaseStats {
    pub fn gen_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.gen_secs.max(1e-9)
    }

    pub fn train_tok_per_sec(&self) -> f64 {
        self.train_tokens as f64 / self.train_secs.max(1e-9)
    }
}

/// Scalar results of one PPO actor update.
#[derive(Debug, Clone, Copy)]
pub struct ActorStepOut {
    pub loss: f32,
    pub approx_kl: f32,
    pub clipfrac: f32,
}

/// The hybrid engine: owns every model role's device-resident state.
pub struct HybridEngine {
    pub engine: Rc<Engine>,
    pub arts: ArtifactSet,
    pub actor: ParamStore,
    /// Frozen reference policy (KL anchor) — a copy of the SFT actor.
    pub ref_actor: ParamStore,
    pub critic: ParamStore,
    /// Frozen reward model (copy of the trained critic after Step 2).
    pub rm: ParamStore,
    /// EMA shadow of the actor (paper Step-3 optional feature).
    pub ema: Option<ParamStore>,
    pub actor_opt: ParamStore,
    pub critic_opt: ParamStore,
    mode: EngineMode,
    kv: Option<KvCache>,
    pub stats: PhaseStats,
    pub memory: MemoryTracker,
}

impl HybridEngine {
    /// Build from a manifest dir; parameters come from the `init_*`
    /// artifacts (seeded), so rust never needs Python at run time.
    pub fn init(engine: Rc<Engine>, dir: &str, seed: i32, with_ema: bool) -> Result<Self> {
        let arts = ArtifactSet::load_all(&engine, dir)?;
        let m = &arts.manifest;

        let actor_lits = arts
            .get("init_actor")?
            .call_literals(&[HostTensor::scalar_i32(seed).to_literal()?])?;
        let critic_lits = arts
            .get("init_critic")?
            .call_literals(&[HostTensor::scalar_i32(seed + 1).to_literal()?])?;

        let actor = ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?;
        let ref_actor = ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?;
        let critic = ParamStore::from_literals(&engine, &m.critic_params, &critic_lits)?;
        let rm = ParamStore::from_literals(&engine, &m.critic_params, &critic_lits)?;
        let ema = if with_ema {
            Some(ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?)
        } else {
            None
        };

        let zeros = |specs: &[crate::runtime::TensorSpec]| -> Vec<HostTensor> {
            specs.iter().map(|s| HostTensor::zeros_f32(&s.shape)).collect()
        };
        let actor_opt = ParamStore::from_host(&engine, &m.actor_opt, &zeros(&m.actor_opt))?;
        let critic_opt = ParamStore::from_host(&engine, &m.critic_opt, &zeros(&m.critic_opt))?;

        let mut memory = MemoryTracker::new();
        memory.alloc("actor_params", actor.bytes());
        memory.alloc("ref_params", ref_actor.bytes());
        memory.alloc("critic_params", critic.bytes());
        memory.alloc("rm_params", rm.bytes());
        if let Some(e) = &ema {
            memory.alloc("ema_params", e.bytes());
        }
        memory.alloc("actor_opt", actor_opt.bytes());
        memory.alloc("critic_opt", critic_opt.bytes());

        Ok(HybridEngine {
            engine,
            arts,
            actor,
            ref_actor,
            critic,
            rm,
            ema,
            actor_opt,
            critic_opt,
            mode: EngineMode::Train,
            kv: None,
            stats: PhaseStats::default(),
            memory,
        })
    }

    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.arts.manifest
    }

    /// Snapshot the current actor as the frozen reference policy (done once
    /// after SFT) — the KL anchor of PPO.
    pub fn freeze_reference(&mut self) -> Result<()> {
        let host = self.actor.to_host()?;
        self.ref_actor = ParamStore::from_host(
            &self.engine,
            &self.arts.manifest.actor_params.clone(),
            &host,
        )?;
        if let Some(ema) = &mut self.ema {
            let lits: Vec<Literal> =
                host.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            ema.replace(&self.engine, &lits)?;
        }
        Ok(())
    }

    /// Snapshot the trained critic as the frozen reward model (after Step 2;
    /// the critic then continues training during PPO, initialized from the
    /// RM exactly as InstructGPT does).
    pub fn freeze_reward_model(&mut self) -> Result<()> {
        let host = self.critic.to_host()?;
        self.rm = ParamStore::from_host(
            &self.engine,
            &self.arts.manifest.critic_params.clone(),
            &host,
        )?;
        Ok(())
    }

    // ------------------------------------------------------------------
    // Mode transitions (the paper's §4 "seamless transition")
    // ------------------------------------------------------------------

    fn enter(&mut self, mode: EngineMode) {
        if self.mode == mode {
            return;
        }
        let t0 = Instant::now();
        match mode {
            EngineMode::Train => {
                // Inference → training: release the KV pool so training can
                // use the memory for activations/larger batches (§4: "
                // reconfigure the memory system to maximize availability").
                if let Some(kv) = self.kv.take() {
                    self.memory.free("kv_cache", kv.bytes());
                }
            }
            EngineMode::Inference => {
                // Training → inference: nothing to allocate until prefill
                // (the KV pool is sized by the incoming batch).
            }
        }
        self.mode = mode;
        self.stats.mode_flips += 1;
        self.stats.flip_secs += t0.elapsed().as_secs_f64();
    }

    // ------------------------------------------------------------------
    // Inference mode: experience generation
    // ------------------------------------------------------------------

    /// Generate `gen_len` tokens for a batch of prompts (row-major
    /// `[b, prompt_len]`). Returns full sequences `[b, seq_len]`.
    ///
    /// This is the paper's memory-bandwidth-bound phase: one prefill call,
    /// then `gen_len - 1` decode calls with device-resident actor params.
    pub fn generate(&mut self, prompts: &[i32], sampler: &mut Sampler) -> Result<Vec<i32>> {
        let m = &self.arts.manifest;
        let (b, sp, sg, s) = (m.batch, m.prompt_len, m.gen_len, m.seq_len);
        if prompts.len() != b * sp {
            bail!("prompts must be [{b}, {sp}], got {} elements", prompts.len());
        }
        let vocab = m.actor.vocab;
        self.enter(EngineMode::Inference);
        let t0 = Instant::now();

        // Prefill: params + prompt -> (logits, k_cache, v_cache).
        let prefill = self.arts.get("prefill")?;
        let prompt_buf = self
            .engine
            .upload(&HostTensor::I32(prompts.to_vec(), vec![b, sp]))?;
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&prompt_buf);
        let out = prefill.call_buffers(&inputs)?;
        let (logits_l, kc_l, vc_l) = (&out[0], &out[1], &out[2]);

        let kv = KvCache::from_literals(&self.engine, kc_l, vc_l)?;
        self.memory.alloc("kv_cache", kv.bytes());
        self.kv = Some(kv);

        let mut seqs = vec![0i32; b * s];
        for i in 0..b {
            seqs[i * s..i * s + sp].copy_from_slice(&prompts[i * sp..(i + 1) * sp]);
        }
        let mut done = vec![false; b];
        // Keep logits as the HostTensor fetched from the device — indexing
        // into it directly avoids a second b*vocab copy per decode step
        // (§Perf change 2).
        let mut logits_t = HostTensor::from_literal(logits_l)?;

        let decode = self.arts.get("decode_step")?;
        for step in 0..sg {
            // Sample token `sp + step` for every unfinished row.
            let active = done.iter().filter(|d| !**d).count() as u64;
            let logits = logits_t.as_f32()?;
            let mut toks = vec![crate::data::synthetic::Vocab::PAD; b];
            for i in 0..b {
                if done[i] {
                    continue;
                }
                let row = &logits[i * vocab..(i + 1) * vocab];
                let hist = &seqs[i * s..i * s + sp + step];
                let t = sampler.sample(row, hist);
                seqs[i * s + sp + step] = t;
                toks[i] = t;
                if t == crate::data::synthetic::Vocab::EOS {
                    done[i] = true;
                }
            }
            self.stats.gen_tokens += active;
            if step + 1 == sg || done.iter().all(|d| *d) {
                break;
            }
            // Decode: (params, kv, token, pos) -> (logits, kv').
            let kv = self.kv.as_ref().unwrap();
            let tok_buf = self.engine.upload(&HostTensor::I32(toks, vec![b]))?;
            let pos_buf = self
                .engine
                .upload(&HostTensor::I32(vec![(sp + step) as i32], vec![1]))?;
            let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
            inputs.push(&kv.k);
            inputs.push(&kv.v);
            inputs.push(&tok_buf);
            inputs.push(&pos_buf);
            let out = decode.call_buffers(&inputs)?;
            logits_t = HostTensor::from_literal(&out[0])?;
            self.kv.as_mut().unwrap().update(&self.engine, &out[1], &out[2])?;
        }

        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(seqs)
    }

    // ------------------------------------------------------------------
    // Forward passes over full sequences (experience scoring)
    // ------------------------------------------------------------------

    fn forward_with(
        &self,
        artifact: &str,
        params: &ParamStore,
        extra: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let art = self.arts.get(artifact)?;
        let extra_bufs: Vec<PjRtBuffer> = extra
            .iter()
            .map(|t| self.engine.upload(t))
            .collect::<Result<_>>()?;
        let mut inputs: Vec<&PjRtBuffer> = params.buffers.iter().collect();
        inputs.extend(extra_bufs.iter());
        let out = art.call_buffers(&inputs)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    fn batch_tensor(&self, tokens: &[i32]) -> HostTensor {
        let m = &self.arts.manifest;
        HostTensor::I32(tokens.to_vec(), vec![m.batch, m.seq_len])
    }

    /// Current-policy log-probs `[b, s-1]`.
    pub fn actor_logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.forward_with("logprobs_forward", &self.actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Frozen-reference log-probs `[b, s-1]` (the KL anchor).
    pub fn ref_logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out =
            self.forward_with("logprobs_forward", &self.ref_actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Full per-position logits `[b, s, vocab]` flattened — the naive
    /// no-KV-cache generation baseline's forward (ablation for Figure 5).
    pub fn full_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out =
            self.forward_with("logits_forward", &self.actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Critic values `[b, s]`.
    pub fn critic_values(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.forward_with("critic_forward", &self.critic, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Frozen reward-model scores `[b]` at `lens` positions.
    pub fn rm_rewards(&self, tokens: &[i32], lens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let out = self.forward_with(
            "rm_forward",
            &self.rm,
            &[self.batch_tensor(tokens), HostTensor::I32(lens.to_vec(), vec![m.batch])],
        )?;
        Ok(out[0].as_f32()?.to_vec())
    }

    // ------------------------------------------------------------------
    // Training mode: the train-step artifacts
    // ------------------------------------------------------------------

    /// One SFT step; returns the loss.
    pub fn sft_step(&mut self, batch: &TokenBatch, lr: f32) -> Result<f32> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let art = self.arts.get("sft_step")?;
        let np = self.actor.len();
        let no = self.actor_opt.len();
        let extra = [
            HostTensor::I32(batch.tokens.clone(), vec![batch.b, batch.s]),
            HostTensor::F32(batch.loss_mask.clone(), vec![batch.b, batch.s - 1]),
            HostTensor::scalar_f32(lr),
        ];
        let extra_bufs: Vec<PjRtBuffer> =
            extra.iter().map(|t| self.engine.upload(t)).collect::<Result<_>>()?;
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.extend(self.actor_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_buffers(&inputs)?;
        self.actor.replace(&self.engine, &out[..np])?;
        self.actor_opt.replace(&self.engine, &out[np..np + no])?;
        let loss = HostTensor::from_literal(&out[np + no])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (batch.b * batch.s) as u64;
        Ok(loss)
    }

    /// SFT eval loss (no update).
    pub fn sft_eval(&self, batch: &TokenBatch) -> Result<f32> {
        let out = self.forward_with(
            "sft_eval",
            &self.actor,
            &[
                HostTensor::I32(batch.tokens.clone(), vec![batch.b, batch.s]),
                HostTensor::F32(batch.loss_mask.clone(), vec![batch.b, batch.s - 1]),
            ],
        )?;
        out[0].item_f32()
    }

    /// One reward-model step; returns (loss, pairwise accuracy).
    pub fn rm_step(&mut self, pb: &PairBatch, lr: f32) -> Result<(f32, f32)> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let art = self.arts.get("rm_step")?;
        let np = self.critic.len();
        let no = self.critic_opt.len();
        let extra = [
            HostTensor::I32(pb.chosen.clone(), vec![pb.b, pb.s]),
            HostTensor::I32(pb.rejected.clone(), vec![pb.b, pb.s]),
            HostTensor::I32(pb.lens_chosen.clone(), vec![pb.b]),
            HostTensor::I32(pb.lens_rejected.clone(), vec![pb.b]),
            HostTensor::scalar_f32(lr),
        ];
        let extra_bufs: Vec<PjRtBuffer> =
            extra.iter().map(|t| self.engine.upload(t)).collect::<Result<_>>()?;
        let mut inputs: Vec<&PjRtBuffer> = self.critic.buffers.iter().collect();
        inputs.extend(self.critic_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_buffers(&inputs)?;
        self.critic.replace(&self.engine, &out[..np])?;
        self.critic_opt.replace(&self.engine, &out[np..np + no])?;
        let loss = HostTensor::from_literal(&out[np + no])?.item_f32()?;
        let acc = HostTensor::from_literal(&out[np + no + 1])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (2 * pb.b * pb.s) as u64;
        Ok((loss, acc))
    }

    /// RM eval (loss, accuracy) without update.
    pub fn rm_eval(&self, pb: &PairBatch) -> Result<(f32, f32)> {
        let out = self.forward_with(
            "rm_eval",
            &self.critic,
            &[
                HostTensor::I32(pb.chosen.clone(), vec![pb.b, pb.s]),
                HostTensor::I32(pb.rejected.clone(), vec![pb.b, pb.s]),
                HostTensor::I32(pb.lens_chosen.clone(), vec![pb.b]),
                HostTensor::I32(pb.lens_rejected.clone(), vec![pb.b]),
            ],
        )?;
        Ok((out[0].item_f32()?, out[1].item_f32()?))
    }

    /// One PPO actor update over a full experience batch.
    #[allow(clippy::too_many_arguments)]
    pub fn ppo_actor_step(
        &mut self,
        tokens: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        mask: &[f32],
        ptx_tokens: &[i32],
        clip_eps: f32,
        ptx_coef: f32,
        lr: f32,
    ) -> Result<ActorStepOut> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let art = self.arts.get("ppo_actor_step")?;
        let np = self.actor.len();
        let no = self.actor_opt.len();
        let extra = [
            HostTensor::I32(tokens.to_vec(), vec![b, s]),
            HostTensor::F32(old_logp.to_vec(), vec![b, s - 1]),
            HostTensor::F32(adv.to_vec(), vec![b, s - 1]),
            HostTensor::F32(mask.to_vec(), vec![b, s - 1]),
            HostTensor::I32(ptx_tokens.to_vec(), vec![b, s]),
            HostTensor::F32(vec![clip_eps, ptx_coef, 0.0, 0.0], vec![4]),
            HostTensor::scalar_f32(lr),
        ];
        let extra_bufs: Vec<PjRtBuffer> =
            extra.iter().map(|t| self.engine.upload(t)).collect::<Result<_>>()?;
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.extend(self.actor_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_buffers(&inputs)?;
        self.actor.replace(&self.engine, &out[..np])?;
        self.actor_opt.replace(&self.engine, &out[np..np + no])?;
        let loss = HostTensor::from_literal(&out[np + no])?.item_f32()?;
        let kl = HostTensor::from_literal(&out[np + no + 1])?.item_f32()?;
        let clipfrac = HostTensor::from_literal(&out[np + no + 2])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (b * s) as u64;
        Ok(ActorStepOut { loss, approx_kl: kl, clipfrac })
    }

    /// One PPO critic update.
    pub fn ppo_critic_step(
        &mut self,
        tokens: &[i32],
        returns: &[f32],
        old_values: &[f32],
        mask: &[f32],
        clip_eps: f32,
        lr: f32,
    ) -> Result<f32> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let art = self.arts.get("ppo_critic_step")?;
        let np = self.critic.len();
        let no = self.critic_opt.len();
        let extra = [
            HostTensor::I32(tokens.to_vec(), vec![b, s]),
            HostTensor::F32(returns.to_vec(), vec![b, s - 1]),
            HostTensor::F32(old_values.to_vec(), vec![b, s - 1]),
            HostTensor::F32(mask.to_vec(), vec![b, s - 1]),
            HostTensor::F32(vec![clip_eps, 0.0, 0.0, 0.0], vec![4]),
            HostTensor::scalar_f32(lr),
        ];
        let extra_bufs: Vec<PjRtBuffer> =
            extra.iter().map(|t| self.engine.upload(t)).collect::<Result<_>>()?;
        let mut inputs: Vec<&PjRtBuffer> = self.critic.buffers.iter().collect();
        inputs.extend(self.critic_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_buffers(&inputs)?;
        self.critic.replace(&self.engine, &out[..np])?;
        self.critic_opt.replace(&self.engine, &out[np..np + no])?;
        let loss = HostTensor::from_literal(&out[np + no])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (b * s) as u64;
        Ok(loss)
    }

    /// EMA shadow update (no-op if EMA disabled).
    pub fn ema_update(&mut self, decay: f32) -> Result<()> {
        let Some(ema) = &mut self.ema else { return Ok(()) };
        let art = self.arts.get("ema_update")?;
        let decay_buf = self.engine.upload(&HostTensor::scalar_f32(decay))?;
        let mut inputs: Vec<&PjRtBuffer> = ema.buffers.iter().collect();
        inputs.extend(self.actor.buffers.iter());
        inputs.push(&decay_buf);
        let out = art.call_buffers(&inputs)?;
        ema.replace(&self.engine, &out)?;
        Ok(())
    }

    /// Swap the EMA shadow in as the serving actor (final checkpoint choice).
    pub fn promote_ema(&mut self) -> Result<()> {
        let Some(ema) = &self.ema else {
            bail!("EMA is disabled");
        };
        let host = ema.to_host()?;
        let lits: Vec<Literal> = host.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.actor.replace(&self.engine, &lits)?;
        Ok(())
    }
}
