//! The Hybrid Engine (DeepSpeed-HE, paper §4): one runtime that flips the
//! actor between **inference mode** (experience generation over a KV cache,
//! decode-attention kernel, token-level loop) and **training mode** (PPO
//! updates over full sequences), reconfiguring memory at each boundary.
//!
//! On the paper's GPUs the flip swaps tensor-parallel inference sharding for
//! ZeRO training sharding; on this CPU testbed the flip swaps executables
//! and the KV-cache buffer pool while the [`MemoryTracker`] accounts for
//! every byte the way the GPU version would (`zero::MemoryModel` maps the
//! same accounting onto paper-scale hardware in the simulator).
//!
//! Data movement contract (see `runtime` for the buffer API): the decode
//! loop is zero-copy — K/V never leave the device between prefill and the
//! train-mode flip (and with the donated decode artifacts XLA may update
//! the cache buffers in place), and what crosses the host boundary per
//! step is a property of the [`SamplingBackend`] driving generation:
//!
//! * [`TrafficClass::FullRow`] (`HostFullRow`): `b` token ids up, one
//!   `[b, vocab]` logits row down — the pre-refactor contract, kept for
//!   repetition-penalty correctness.
//! * [`TrafficClass::DeviceIds`] (`DeviceTopK`, greedy): `b` ids up, `b`
//!   ids down — the device argmax tail ran inside the artifact; per-token
//!   host traffic is O(b), independent of the vocabulary.
//! * [`TrafficClass::DeviceTopK`] (`DeviceTopK`, stochastic): `b` ids up,
//!   `[b, k]` candidate logits+ids down; the host finishes temperature /
//!   top-p / the categorical draw over the k candidates with its seeded
//!   RNG, so generation stays deterministic and EOS/length retirement
//!   stays host-side.
//! * [`TrafficClass::DeviceCategorical`] (`DeviceCategorical`): `b` ids +
//!   per-row `(seed, step)` counters up, `b` sampled ids down — the
//!   stochastic draw itself runs on device from a counter-based Threefry
//!   stream, so stochastic decode matches greedy's O(b) traffic and each
//!   request's stream is a pure function of its seed and draw index
//!   (serving-path only: the scheduler carries the per-request seeds, and
//!   with the `decode_chunk{N}` artifacts it fuses N such steps into one
//!   dispatch — see [`HybridEngine::decode_slots_chunk`]).
//!
//! Train steps keep the updated parameters and optimizer state on device
//! and fetch scalars only; experience scoring uploads the `[b, seq_len]`
//! token batch once and shares the buffer across all four forwards; PPO
//! epochs re-feed one [`StagedExperience`] (tokens, log-probs, advantages,
//! returns, values, mask staged once per experience batch) instead of
//! re-uploading per epoch.
//!
//! Generation is exposed at two altitudes: the batch path
//! ([`HybridEngine::prefill`] + [`HybridEngine::decode_step`], wrapped by
//! [`HybridEngine::generate`] for the fixed-batch training loop, plus the
//! variable-length [`HybridEngine::prefill_mixed`] +
//! [`HybridEngine::generate_mixed`] pair) runs all rows in lockstep,
//! while the serving path ([`HybridEngine::begin_serving`] +
//! [`HybridEngine::prefill_slot`] + [`HybridEngine::decode_slots`]) gives
//! every batch slot its own sequence position so the continuous-batching
//! scheduler in `crate::serving` can retire and admit requests at
//! decode-step boundaries. Prompts need not match the fixed AOT
//! `prompt_len`: with the `padded_prompts` artifact capability, shorter
//! prompts are LEFT-PADDED and masked via per-row valid-start inputs —
//! bit-identical to the exact-length computation (see `crate::serving`'s
//! module docs for the full contract). The per-slot
//! entry points serve two masters: the serve loop and RLHF experience
//! generation (`crate::rollout`, which borrows the engine for one rollout
//! via `Scheduler<&mut HybridEngine>`). Scoring forwards
//! ([`HybridEngine::score_experience`]) upload their own inputs and flip
//! no mode, so the rollout may score flushed experience groups while other
//! slots keep decoding — only train steps flip modes (and free the
//! serving cache).
//!
//! Serving has two cache layouts. The default ARENA gives each slot a
//! contiguous `[smax]` row group (the `prefill_slot`/`decode_slots`
//! artifacts). Opting in via [`HybridEngine::use_paged_serving`] switches
//! the session to the BLOCK-PAGED pool (the `*_paged` artifacts +
//! `paged_kv` manifest capability): K/V live in fixed-size pages behind
//! refcounted per-slot block tables (`kv::PageLedger`), prompts are
//! front-aligned instead of left-padded, and admissions declaring a
//! shared prefix ([`Admission::prefix_len`]) map the prefix's pages
//! copy-on-write instead of recomputing them — identical traffic decodes
//! bit-identically on either layout.
//!
//! When the artifacts additionally carry the `lazy_kv` capability, the
//! pool is a true oversubscribed allocator: admissions draw only the
//! pages covering the prompt, decode maps one page per boundary crossing
//! ([`HybridEngine::kv_reserve_rows`], which the scheduler runs before
//! every dispatch), dead block-table tails point at garbage page 0 (safe
//! because every artifact read is masked by the live length — see
//! `python/compile/kernels/decode.py`), and
//! [`HybridEngine::limit_kv_pages`] may cap the allocator below
//! `n_slots * blocks_per_slot`. Under pressure the ledger LRU-evicts
//! registered prefixes whose pages only the registry still references;
//! when even that cannot cover a reservation, the scheduler preempts the
//! slot ([`FinishReason::Preempted`](crate::serving::FinishReason) after
//! the retry budget) and requeues it — greedy replay is deterministic,
//! so completions still match an uncapped run bit for bit.

pub mod kv;
pub mod memory;

pub use kv::KvCache;
pub use memory::MemoryTracker;

use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};
use xla::{Literal, PjRtBuffer};

use crate::data::{PairBatch, TokenBatch};
use crate::runtime::{Artifact, ArtifactSet, Engine, HostTensor, ParamStore};
use crate::sampling::{PendingRow, SampleOut, SamplingBackend, TrafficClass};
use crate::serving::{Admission, AdmitOutcome, ChunkBatch, DecodeBatch};
use crate::telemetry::{Hist, Telemetry};
use kv::KvLayout;

/// Which configuration the actor model is currently in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// ZeRO-style training configuration (full-sequence fwd/bwd).
    Train,
    /// Inference configuration (KV cache alive, decode executables hot).
    Inference,
}

/// Per-phase timing/throughput accounting (drives Figure 5/6 analogues).
#[derive(Debug, Default, Clone)]
pub struct PhaseStats {
    pub gen_secs: f64,
    pub gen_tokens: u64,
    pub train_secs: f64,
    pub train_tokens: u64,
    pub mode_flips: u64,
    pub flip_secs: f64,
}

impl PhaseStats {
    pub fn gen_tok_per_sec(&self) -> f64 {
        self.gen_tokens as f64 / self.gen_secs.max(1e-9)
    }

    pub fn train_tok_per_sec(&self) -> f64 {
        self.train_tokens as f64 / self.train_secs.max(1e-9)
    }
}

/// Scalar results of one PPO actor update.
#[derive(Debug, Clone, Copy)]
pub struct ActorStepOut {
    pub loss: f32,
    pub approx_kl: f32,
    pub clipfrac: f32,
}

/// Host-side results of scoring one experience batch with all four models
/// (see [`HybridEngine::score_experience`]).
#[derive(Debug, Clone)]
pub struct ExperienceScores {
    /// Current-policy log-probs `[b, s-1]`.
    pub old_logp: Vec<f32>,
    /// Frozen-reference log-probs `[b, s-1]` (the KL anchor).
    pub ref_logp: Vec<f32>,
    /// Critic values `[b, s]`.
    pub values: Vec<f32>,
    /// Frozen reward-model scores `[b]` at the given positions.
    pub rm_scores: Vec<f32>,
}

/// One experience batch's epoch-constant tensors, uploaded once via
/// [`HybridEngine::stage_experience`] and re-fed across PPO epochs (the
/// actor step consumes tokens/old_logp/adv/mask, the critic step
/// tokens/returns/old_values/mask). The per-epoch host→device traffic
/// shrinks to the fresh ptx batch plus scalar hyperparameters.
pub struct StagedExperience {
    tokens: PjRtBuffer,
    old_logp: PjRtBuffer,
    adv: PjRtBuffer,
    returns: PjRtBuffer,
    old_values: PjRtBuffer,
    mask: PjRtBuffer,
}

/// Host-side copy of the mutable training state (actor + critic params,
/// both optimizer stores, the EMA shadow when enabled) captured by
/// [`HybridEngine::snapshot_training_state`] — the anomaly guard's
/// rollback point, and the payload of the durable PPO checkpoint.
pub struct TrainSnapshot {
    pub actor: Vec<HostTensor>,
    pub critic: Vec<HostTensor>,
    pub actor_opt: Vec<HostTensor>,
    pub critic_opt: Vec<HostTensor>,
    pub ema: Option<Vec<HostTensor>>,
}

/// Split a train-step artifact's output buffers into (params, opt, scalars)
/// without any host transit, validating the arity loudly.
fn split_outputs(
    mut out: Vec<PjRtBuffer>,
    np: usize,
    no: usize,
    n_scalars: usize,
    what: &str,
) -> Result<(Vec<PjRtBuffer>, Vec<PjRtBuffer>, Vec<PjRtBuffer>)> {
    if out.len() != np + no + n_scalars {
        bail!(
            "{what}: expected {} outputs ({np} params + {no} opt + {n_scalars} scalars), got {}",
            np + no + n_scalars,
            out.len()
        );
    }
    let scalars = out.split_off(np + no);
    let opt = out.split_off(np);
    Ok((out, opt, scalars))
}

/// The hybrid engine: owns every model role's device-resident state.
pub struct HybridEngine {
    pub engine: Rc<Engine>,
    pub arts: ArtifactSet,
    pub actor: ParamStore,
    /// Frozen reference policy (KL anchor) — a copy of the SFT actor.
    pub ref_actor: ParamStore,
    pub critic: ParamStore,
    /// Frozen reward model (copy of the trained critic after Step 2).
    pub rm: ParamStore,
    /// EMA shadow of the actor (paper Step-3 optional feature).
    pub ema: Option<ParamStore>,
    pub actor_opt: ParamStore,
    pub critic_opt: ParamStore,
    mode: EngineMode,
    kv: Option<KvCache>,
    /// Serve from the block-paged KV pool instead of the per-slot arena
    /// (see [`HybridEngine::use_paged_serving`]). Takes effect at the next
    /// [`HybridEngine::begin_serving`].
    paged_serving: bool,
    /// Pre-staged `[1]` position buffers for decode steps `0..gen_len`,
    /// uploaded once and re-fed every generate call (they are tiny and the
    /// positions are fixed by the manifest, so they survive mode flips).
    pos_bufs: Vec<PjRtBuffer>,
    pub stats: PhaseStats,
    pub memory: MemoryTracker,
    /// Telemetry handle shared with every scheduler/trainer built on this
    /// engine (disabled by default: zero hot-path cost until a frontend
    /// calls [`HybridEngine::set_telemetry`]).
    pub telemetry: Telemetry,
}

impl HybridEngine {
    /// Build from a manifest dir; parameters come from the `init_*`
    /// artifacts (seeded), so rust never needs Python at run time.
    pub fn init(engine: Rc<Engine>, dir: &str, seed: i32, with_ema: bool) -> Result<Self> {
        let arts = ArtifactSet::load_all(&engine, dir)?;
        let m = &arts.manifest;

        let actor_lits = arts
            .get("init_actor")?
            .call_literals(&[HostTensor::scalar_i32(seed).to_literal()?])?;
        let critic_lits = arts
            .get("init_critic")?
            .call_literals(&[HostTensor::scalar_i32(seed + 1).to_literal()?])?;

        let actor = ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?;
        let ref_actor = ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?;
        let critic = ParamStore::from_literals(&engine, &m.critic_params, &critic_lits)?;
        let rm = ParamStore::from_literals(&engine, &m.critic_params, &critic_lits)?;
        let ema = if with_ema {
            Some(ParamStore::from_literals(&engine, &m.actor_params, &actor_lits)?)
        } else {
            None
        };

        let zeros = |specs: &[crate::runtime::TensorSpec]| -> Vec<HostTensor> {
            specs.iter().map(|s| HostTensor::zeros_f32(&s.shape)).collect()
        };
        let actor_opt = ParamStore::from_host(&engine, &m.actor_opt, &zeros(&m.actor_opt))?;
        let critic_opt = ParamStore::from_host(&engine, &m.critic_opt, &zeros(&m.critic_opt))?;

        let mut memory = MemoryTracker::new();
        memory.alloc("actor_params", actor.bytes());
        memory.alloc("ref_params", ref_actor.bytes());
        memory.alloc("critic_params", critic.bytes());
        memory.alloc("rm_params", rm.bytes());
        if let Some(e) = &ema {
            memory.alloc("ema_params", e.bytes());
        }
        memory.alloc("actor_opt", actor_opt.bytes());
        memory.alloc("critic_opt", critic_opt.bytes());

        Ok(HybridEngine {
            engine,
            arts,
            actor,
            ref_actor,
            critic,
            rm,
            ema,
            actor_opt,
            critic_opt,
            mode: EngineMode::Train,
            kv: None,
            paged_serving: false,
            pos_bufs: Vec::new(),
            stats: PhaseStats::default(),
            memory,
            telemetry: Telemetry::disabled(),
        })
    }

    /// Install an (enabled) telemetry handle; schedulers and trainers built
    /// on this engine afterwards adopt it automatically.
    pub fn set_telemetry(&mut self, tel: Telemetry) {
        self.telemetry = tel;
    }

    /// Point-in-time KV occupancy for the metrics snapshot: slot/token
    /// counts for both layouts, page/prefix counts when serving paged.
    pub fn kv_occupancy(&self) -> Option<crate::telemetry::KvOccupancy> {
        let kv = self.kv.as_ref()?;
        let ledger = &kv.ledger;
        let (paged, page_size, n_pages, free_pages, registered_prefixes) = match ledger.layout() {
            KvLayout::Paged { page_size, n_pages } => (
                true,
                page_size,
                n_pages,
                ledger.free_pages(),
                ledger.n_prefixes(),
            ),
            KvLayout::Arena => (false, 0, 0, 0, 0),
        };
        Some(crate::telemetry::KvOccupancy {
            paged,
            n_slots: ledger.n_slots(),
            active_slots: ledger.n_active(),
            valid_tokens: ledger.valid_tokens(),
            page_size,
            n_pages,
            free_pages,
            registered_prefixes,
            usable_pages: if paged { ledger.usable_pages() } else { 0 },
            peak_used_pages: if paged { ledger.peak_used_pages() } else { 0 },
            prefix_evictions: ledger.evictions(),
            pages_stolen: ledger.pages_stolen(),
            hash_collisions: ledger.collisions(),
        })
    }

    pub fn mode(&self) -> EngineMode {
        self.mode
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.arts.manifest
    }

    /// Snapshot the current actor as the frozen reference policy (done once
    /// after SFT) — the KL anchor of PPO.
    pub fn freeze_reference(&mut self) -> Result<()> {
        let host = self.actor.to_host()?;
        self.ref_actor = ParamStore::from_host(
            &self.engine,
            &self.arts.manifest.actor_params.clone(),
            &host,
        )?;
        if let Some(ema) = &mut self.ema {
            let lits: Vec<Literal> =
                host.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            ema.replace(&self.engine, &lits)?;
        }
        Ok(())
    }

    /// Snapshot the trained critic as the frozen reward model (after Step 2;
    /// the critic then continues training during PPO, initialized from the
    /// RM exactly as InstructGPT does).
    pub fn freeze_reward_model(&mut self) -> Result<()> {
        let host = self.critic.to_host()?;
        self.rm = ParamStore::from_host(
            &self.engine,
            &self.arts.manifest.critic_params.clone(),
            &host,
        )?;
        Ok(())
    }

    /// Host-side copy of everything a PPO update mutates — the anomaly
    /// guard's last-good rollback point. The frozen reference policy and
    /// reward model are deliberately excluded: PPO never writes them, so
    /// restoring them would only burn upload bandwidth.
    pub fn snapshot_training_state(&self) -> Result<TrainSnapshot> {
        Ok(TrainSnapshot {
            actor: self.actor.to_host()?,
            critic: self.critic.to_host()?,
            actor_opt: self.actor_opt.to_host()?,
            critic_opt: self.critic_opt.to_host()?,
            ema: self.ema.as_ref().map(|e| e.to_host()).transpose()?,
        })
    }

    /// Restore a [`TrainSnapshot`] in place (actor, critic, both optimizer
    /// states, and the EMA shadow when present) — device buffers are
    /// re-uploaded; specs and modes are untouched.
    pub fn restore_training_state(&mut self, snap: &TrainSnapshot) -> Result<()> {
        let lits = |ts: &[HostTensor]| -> Result<Vec<Literal>> {
            ts.iter().map(|t| t.to_literal()).collect()
        };
        self.actor.replace(&self.engine, &lits(&snap.actor)?)?;
        self.critic.replace(&self.engine, &lits(&snap.critic)?)?;
        self.actor_opt.replace(&self.engine, &lits(&snap.actor_opt)?)?;
        self.critic_opt.replace(&self.engine, &lits(&snap.critic_opt)?)?;
        match (&mut self.ema, &snap.ema) {
            (Some(store), Some(host)) => store.replace(&self.engine, &lits(host)?)?,
            (None, None) => {}
            (have, _) => bail!(
                "training snapshot EMA mismatch: engine {} an EMA shadow but the \
                 snapshot {} one",
                if have.is_some() { "has" } else { "lacks" },
                if snap.ema.is_some() { "carries" } else { "lacks" }
            ),
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Mode transitions (the paper's §4 "seamless transition")
    // ------------------------------------------------------------------

    fn enter(&mut self, mode: EngineMode) {
        if self.mode == mode {
            return;
        }
        let t0 = Instant::now();
        match mode {
            EngineMode::Train => {
                // Inference → training: release the KV pool so training can
                // use the memory for activations/larger batches (§4: "
                // reconfigure the memory system to maximize availability").
                // The pre-staged pos buffers are a few bytes and kept.
                if let Some(kv) = self.kv.take() {
                    self.memory.free("kv_cache", kv.bytes());
                }
            }
            EngineMode::Inference => {
                // Training → inference: nothing to allocate until prefill
                // (the KV pool is sized by the incoming batch).
            }
        }
        self.mode = mode;
        self.stats.mode_flips += 1;
        self.stats.flip_secs += t0.elapsed().as_secs_f64();
        self.telemetry.instant(
            crate::telemetry::TID_ENGINE,
            match mode {
                EngineMode::Train => "mode_flip_train",
                EngineMode::Inference => "mode_flip_inference",
            },
            self.stats.mode_flips,
            (t0.elapsed().as_secs_f64() * 1e6) as i64,
        );
    }

    // ------------------------------------------------------------------
    // Inference mode: experience generation
    // ------------------------------------------------------------------

    /// Install a freshly built cache as the live KV cache, keeping the
    /// memory tracker balanced on inference re-entry (a second prefill
    /// without an intervening train flip replaces the live cache, so the
    /// old allocation must be released first).
    fn install_kv(&mut self, kv: KvCache) {
        if let Some(old) = self.kv.take() {
            self.memory.free("kv_cache", old.bytes());
        }
        self.memory.alloc("kv_cache", kv.bytes());
        self.kv = Some(kv);
    }

    /// Upload the `[1]` position scalars for decode steps `0..gen_len` once
    /// per engine; later calls re-feed the same device buffers.
    fn stage_pos_bufs(&mut self) -> Result<()> {
        if self.pos_bufs.is_empty() {
            let (sp, sg) = (self.arts.manifest.prompt_len, self.arts.manifest.gen_len);
            for step in 0..sg {
                self.pos_bufs
                    .push(self.engine.upload_i32(&[(sp + step) as i32], &[1])?);
            }
        }
        Ok(())
    }

    /// Resolve a generation-family artifact for a traffic class: the plain
    /// entry for full-row sampling, the `_sampled` variant (logits matmul +
    /// fused Pallas sampling tail) for device sampling. Returns the
    /// artifact and its output arity.
    fn gen_artifact(&self, base: &str, traffic: TrafficClass) -> Result<(&Artifact, usize)> {
        match traffic {
            TrafficClass::FullRow => Ok((self.arts.get(base)?, 3)),
            TrafficClass::DeviceCategorical => {
                // The `_rng` family: `_sampled` compute + the on-device
                // categorical draw; outputs gain `sampled_ids` at index 3.
                self.arts.manifest.require_device_rng()?;
                Ok((self.arts.get(&format!("{base}_rng"))?, 6))
            }
            _ => {
                let name = format!("{base}_sampled");
                let art = self.arts.get(&name).map_err(|e| {
                    e.context("artifacts predate device-side sampling — re-run `make artifacts`")
                })?;
                Ok((art, 5))
            }
        }
    }

    /// Fetch exactly what the backend consumes from a generation call's
    /// non-cache outputs — this is where the per-step host-traffic
    /// contract lands: the `[b, vocab]` logits row (FullRow), the `[b]`
    /// device-argmax ids (DeviceIds), or the `[b, k]` top-k candidate
    /// logits+ids (DeviceTopK). `bufs` holds `[logits]` (plain artifacts)
    /// or `[ids, topk_logits, topk_ids]` (`_sampled` artifacts).
    fn fetch_sample(
        &self,
        key: &str,
        traffic: TrafficClass,
        bufs: &[PjRtBuffer],
    ) -> Result<SampleOut> {
        match traffic {
            TrafficClass::FullRow => {
                match self.engine.fetch(key, &bufs[0])? {
                    HostTensor::F32(data, _) => {
                        Ok(SampleOut::Logits { data, vocab: self.arts.manifest.actor.vocab })
                    }
                    other => bail!("{key}: logits fetch returned {:?}", other.shape()),
                }
            }
            TrafficClass::DeviceIds => match self.engine.fetch(key, &bufs[0])? {
                HostTensor::I32(ids, _) => Ok(SampleOut::Ids(ids)),
                other => bail!("{key}: ids fetch returned f32 {:?}", other.shape()),
            },
            // The device already drew the token (`sampled_ids`, output 3):
            // per-step host traffic is `b` ints regardless of k or vocab.
            TrafficClass::DeviceCategorical => match self.engine.fetch(key, &bufs[3])? {
                HostTensor::I32(ids, _) => Ok(SampleOut::Ids(ids)),
                other => bail!("{key}: sampled-ids fetch returned f32 {:?}", other.shape()),
            },
            TrafficClass::DeviceTopK => {
                let k = self.arts.manifest.sample_k;
                if k == 0 {
                    bail!("{key}: manifest has no sample_k — re-run `make artifacts`");
                }
                let vals = self.engine.fetch(key, &bufs[1])?;
                let ids = self.engine.fetch(key, &bufs[2])?;
                match (vals, ids) {
                    (HostTensor::F32(vals, _), HostTensor::I32(ids, _)) => {
                        Ok(SampleOut::TopK { vals, ids, k })
                    }
                    _ => bail!("{key}: top-k fetch returned unexpected dtypes"),
                }
            }
        }
    }

    /// Full-batch prefill: run every prompt row through the `prefill` (or
    /// `prefill_sampled`) artifact, install the resulting caches (all
    /// slots claimed at `prompt_len`), and return the backend's view of
    /// the last-position logits — full rows, ids, or top-k candidates per
    /// the traffic class. First half of the resumable generation pair —
    /// the decode loop continues from here via
    /// [`HybridEngine::decode_step`]. Exact-length rows only; mixed
    /// lengths go through [`HybridEngine::prefill_mixed`].
    pub fn prefill(&mut self, prompts: &[i32], traffic: TrafficClass) -> Result<SampleOut> {
        let m = &self.arts.manifest;
        let (b, sp) = (m.batch, m.prompt_len);
        if prompts.len() != b * sp {
            bail!("prompts must be [{b}, {sp}], got {} elements", prompts.len());
        }
        self.prefill_rows(prompts.to_vec(), vec![0; b], traffic)
    }

    /// Full-batch prefill of VARIABLE-LENGTH prompts: each row of true
    /// length `1..=prompt_len` is LEFT-PADDED into the fixed AOT shape and
    /// the per-row valid-start vector tells the artifact to mask the
    /// padding out of attention and shift position embeddings — row i's
    /// computation is bit-identical to prefilling its unpadded prompt at
    /// exact length, and (left-alignment's payoff) every row's next write
    /// position is `prompt_len`, so the rows stay depth-aligned for the
    /// decode loop. Requires the `padded_prompts` artifact capability
    /// whenever any row is short.
    pub fn prefill_mixed(
        &mut self,
        prompts: &[Vec<i32>],
        traffic: TrafficClass,
    ) -> Result<SampleOut> {
        let m = &self.arts.manifest;
        let (b, sp) = (m.batch, m.prompt_len);
        if prompts.len() != b {
            bail!("prefill_mixed wants exactly {b} prompt rows, got {}", prompts.len());
        }
        let mut flat = vec![crate::data::synthetic::Vocab::PAD; b * sp];
        let mut starts = vec![0i32; b];
        for (i, p) in prompts.iter().enumerate() {
            let l = p.len();
            if l == 0 || l > sp {
                bail!("prefill_mixed row {i}: prompt must be 1..={sp} tokens, got {l}");
            }
            if l < sp {
                m.require_padded_prompts()?;
            }
            let pad = sp - l;
            flat[i * sp + pad..(i + 1) * sp].copy_from_slice(p);
            starts[i] = pad as i32;
        }
        self.prefill_rows(flat, starts, traffic)
    }

    /// Shared tail of both batch-prefill entry points: `flat` is the
    /// left-padded `[b, prompt_len]` token matrix and `starts[i]` row i's
    /// valid start (0 = exact length). Artifacts with the `padded_prompts`
    /// capability take the starts vector as an input; older artifacts are
    /// only reachable with all-zero starts and keep their original input
    /// list.
    fn prefill_rows(
        &mut self,
        flat: Vec<i32>,
        starts: Vec<i32>,
        traffic: TrafficClass,
    ) -> Result<SampleOut> {
        if traffic == TrafficClass::DeviceCategorical {
            bail!(
                "batch generation does not drive the device-RNG backend — serve \
                 DeviceCategorical through the scheduler (prefill_slot/decode_slots), \
                 which carries the per-request seed and step inputs"
            );
        }
        let m = &self.arts.manifest;
        let (b, sp) = (m.batch, m.prompt_len);
        let padded_artifacts = m.padded_prompts;
        let kv_dims = KvCache::dims_for(m);
        self.enter(EngineMode::Inference);
        let t0 = Instant::now();
        self.stage_pos_bufs()?;

        // Prefill: params + prompt (+ starts) -> (sampling outputs...,
        // k_cache, v_cache). Everything stays on device; only the
        // backend's sampling view is fetched.
        let (prefill, n_out) = self.gen_artifact("prefill", traffic)?;
        let prompt_buf = self.engine.upload_i32(&flat, &[b, sp])?;
        let start_buf = if padded_artifacts {
            Some(self.engine.upload_i32(&starts, &[b])?)
        } else {
            None
        };
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&prompt_buf);
        if let Some(sb) = &start_buf {
            inputs.push(sb);
        }
        let name = prefill.name.clone();
        let mut out = prefill.call_to_buffers(&inputs, n_out)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();

        let batch = self.arts.manifest.batch;
        self.install_kv(KvCache::arena(kc, vc, kv_dims, batch));
        let pads: Vec<usize> = starts.iter().map(|&s| s as usize).collect();
        let valids: Vec<usize> = pads.iter().map(|&p| sp - p).collect();
        self.kv.as_mut().unwrap().alloc_all(&valids, &pads)?;
        let sample = self.fetch_sample(&name, traffic, &out)?;
        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(sample)
    }

    /// One shared-position decode step over the live cache: feed the token
    /// sampled at generation step `step` for every row and fetch the next
    /// step's sampling view. K/V are passed and received as device buffers
    /// — zero host bytes (the donated artifacts may even update them in
    /// place); per-step host traffic is `b` ints up plus the traffic
    /// class's fetch (logits row / ids / top-k candidates) down.
    pub fn decode_step(
        &mut self,
        toks: &[i32],
        step: usize,
        traffic: TrafficClass,
    ) -> Result<SampleOut> {
        if traffic == TrafficClass::DeviceCategorical {
            bail!(
                "batch generation does not drive the device-RNG backend — serve \
                 DeviceCategorical through the scheduler (prefill_slot/decode_slots), \
                 which carries the per-request seed and step inputs"
            );
        }
        let m = &self.arts.manifest;
        let (b, sg) = (m.batch, m.gen_len);
        if toks.len() != b {
            bail!("decode_step tokens must be [{b}], got {} elements", toks.len());
        }
        if step >= sg {
            bail!("decode_step step {step} out of range (gen_len {sg})");
        }
        // Shared-position decode is only sound when every slot sits at the
        // SAME cache depth (pad + valid) and that depth is exactly the
        // position being fed — the state a batch prefill + `step` decode
        // steps leaves (left-padding keeps mixed-length rows depth-aligned,
        // but mixed rows need the per-row valid starts of `decode_slots`;
        // this entry has no starts input and serves the exact-length
        // `generate` path only). A serving-mode cache (slots free or at
        // mixed depths) or a stale `step` must go through `decode_slots`
        // instead; feeding one shared position would scatter K/V at the
        // wrong rows and desync the occupancy ledger.
        let sp = m.prompt_len;
        let uniform_depth = self.kv.as_ref().and_then(|kv| {
            if kv.layout() != kv::KvLayout::Arena {
                return None; // a paged pool advances via decode_slots only
            }
            let d0 = kv.depth_of(0)?;
            if kv.pad_of(0) != Some(0) {
                return None; // left-padded rows need decode_slots' starts
            }
            (1..kv.n_slots())
                .all(|i| kv.depth_of(i) == Some(d0) && kv.pad_of(i) == Some(0))
                .then_some(d0)
        });
        let ready = self.mode == EngineMode::Inference
            && step < self.pos_bufs.len()
            && uniform_depth == Some(sp + step);
        if !ready {
            bail!(
                "decode_step at step {step} requires a batch prefill with all slots at depth \
                 {} (serving-mode caches advance via decode_slots)",
                sp + step
            );
        }
        let t0 = Instant::now();
        let (decode, n_out) = self.gen_artifact("decode_step", traffic)?;
        let name = decode.name.clone();
        let tok_buf = self.engine.upload_i32(toks, &[b])?;
        let kv = self.kv.as_ref().unwrap();
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&kv.k);
        inputs.push(&kv.v);
        inputs.push(&tok_buf);
        inputs.push(&self.pos_bufs[step]);
        let mut out = decode.call_to_buffers(&inputs, n_out)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        // The K/V inputs were donated to the call: the old handles are
        // dead, and the fresh output pair (possibly the same storage,
        // updated in place) becomes the live cache.
        let kv = self.kv.as_mut().unwrap();
        kv.update(kc, vc);
        kv.advance_all();
        let sample = self.fetch_sample(&name, traffic, &out)?;
        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(sample)
    }

    /// Generate `gen_len` tokens for a batch of prompts (row-major
    /// `[b, prompt_len]`). Returns full sequences `[b, seq_len]`.
    ///
    /// This is the paper's memory-bandwidth-bound phase, a thin wrapper
    /// over the resumable [`HybridEngine::prefill`] +
    /// [`HybridEngine::decode_step`] pair: one prefill call, then up to
    /// `gen_len - 1` decode calls, with the [`SamplingBackend`] finishing
    /// each step's output into token ids. Under a `HostFullRow` backend
    /// the call sequence and inputs are identical to the pre-refactor
    /// loop, so generation is bit-identical for a fixed sampler seed
    /// (pinned by the integration golden); a greedy `DeviceTopK` backend
    /// produces the same sequences while fetching only `[b]` ids per step.
    /// The serving scheduler drives the same engine through the per-slot
    /// entry points instead ([`HybridEngine::prefill_slot`] /
    /// [`HybridEngine::decode_slots`]).
    pub fn generate(
        &mut self,
        prompts: &[i32],
        backend: &mut dyn SamplingBackend,
    ) -> Result<Vec<i32>> {
        let m = &self.arts.manifest;
        let (b, sp, sg, s) = (m.batch, m.prompt_len, m.gen_len, m.seq_len);
        let traffic = backend.traffic();
        // Phase timing covers the WHOLE generation loop (sampling and
        // bookkeeping included), exactly as the pre-refactor monolith did:
        // rewind the engine-call seconds prefill/decode_step accumulate and
        // charge wall-clock instead, so gen_secs stays comparable across
        // PRs while standalone (serving) calls still self-account.
        let t0 = Instant::now();
        let secs0 = self.stats.gen_secs;
        // Batch-level latency histograms: the generate call is the submit
        // anchor (no queue in the fixed-batch path), so TTFT = prefill +
        // first sample pass and inter-token = per-step wall time.
        let t_gen_us = self.telemetry.now_us();
        let mut t_last_us = t_gen_us;
        let mut out = self.prefill(prompts, traffic)?;

        let mut seqs = vec![0i32; b * s];
        for i in 0..b {
            seqs[i * s..i * s + sp].copy_from_slice(&prompts[i * sp..(i + 1) * sp]);
        }
        let mut done = vec![false; b];
        // Hoisted token staging: the sampled-token vec is reused across
        // steps, so each decode step's host→device traffic is b ints.
        let mut toks = vec![crate::data::synthetic::Vocab::PAD; b];

        for step in 0..sg {
            // Sample token `sp + step` for every unfinished row, borrowing
            // the fetched rows in place (no per-step copy).
            let active = done.iter().filter(|d| !**d).count() as u64;
            for i in 0..b {
                if done[i] {
                    toks[i] = crate::data::synthetic::Vocab::PAD;
                    continue;
                }
                let hist = &seqs[i * s..i * s + sp + step];
                let t = backend.sample(out.row(i), hist)?;
                seqs[i * s + sp + step] = t;
                toks[i] = t;
                if t == crate::data::synthetic::Vocab::EOS {
                    done[i] = true;
                }
            }
            self.stats.gen_tokens += active;
            if self.telemetry.is_enabled() && active > 0 {
                let now = self.telemetry.now_us();
                if step == 0 {
                    self.telemetry.record(Hist::Ttft, now.saturating_sub(t_gen_us));
                } else {
                    self.telemetry
                        .record(Hist::InterToken, now.saturating_sub(t_last_us));
                }
                t_last_us = now;
            }
            if step + 1 == sg || done.iter().all(|d| *d) {
                break;
            }
            out = self.decode_step(&toks, step, traffic)?;
        }

        self.stats.gen_secs = secs0 + t0.elapsed().as_secs_f64();
        Ok(seqs)
    }

    /// Generate for a batch of VARIABLE-LENGTH prompts (each
    /// `1..=prompt_len` tokens): a left-padded batch prefill
    /// ([`HybridEngine::prefill_mixed`]) followed by per-slot decode steps
    /// ([`HybridEngine::decode_slots`]) carrying each row's valid start.
    /// Left-alignment at the prompt window's right edge keeps every row's
    /// cache depth at `prompt_len + step`, so the rows advance in lockstep
    /// exactly like [`HybridEngine::generate`] — this is the fixed-batch
    /// reference the mixed-length serving golden compares the scheduler
    /// against. Returns each row's TRUE sequence (prompt ++ generated, no
    /// padding); rows stop at EOS and stop being decoded (their slot stays
    /// claimed but inactive, like a retired scheduler slot).
    pub fn generate_mixed(
        &mut self,
        prompts: &[Vec<i32>],
        backend: &mut dyn SamplingBackend,
    ) -> Result<Vec<Vec<i32>>> {
        let m = &self.arts.manifest;
        let (b, sp, sg) = (m.batch, m.prompt_len, m.gen_len);
        if prompts.len() != b {
            bail!("generate_mixed wants exactly {b} prompts, got {}", prompts.len());
        }
        let traffic = backend.traffic();
        let t0 = Instant::now();
        let secs0 = self.stats.gen_secs;
        let starts: Vec<i32> = prompts.iter().map(|p| sp as i32 - p.len() as i32).collect();
        let mut out = self.prefill_mixed(prompts, traffic)?;

        let mut seqs: Vec<Vec<i32>> = prompts.to_vec();
        let mut done = vec![false; b];
        let mut toks = vec![crate::data::synthetic::Vocab::PAD; b];
        let mut pos = vec![0i32; b];
        let mut step_starts = vec![0i32; b];
        let mut active = vec![false; b];

        for step in 0..sg {
            let live = done.iter().filter(|d| !**d).count() as u64;
            for i in 0..b {
                if done[i] {
                    toks[i] = crate::data::synthetic::Vocab::PAD;
                    pos[i] = 0;
                    step_starts[i] = 0;
                    active[i] = false;
                    continue;
                }
                let t = backend.sample(out.row(i), &seqs[i])?;
                seqs[i].push(t);
                toks[i] = t;
                // Cache row of the just-sampled token: valid start + its
                // index in the true sequence == prompt_len + step for every
                // live row (left-alignment keeps the batch depth-uniform).
                pos[i] = starts[i] + (seqs[i].len() - 1) as i32;
                step_starts[i] = starts[i];
                if t == crate::data::synthetic::Vocab::EOS {
                    done[i] = true;
                    active[i] = false;
                } else {
                    active[i] = true;
                }
            }
            self.stats.gen_tokens += live;
            if step + 1 == sg || done.iter().all(|d| *d) {
                break;
            }
            out = self.decode_slots(&DecodeBatch {
                toks: &toks,
                pos: &pos,
                starts: &step_starts,
                active: &active,
                traffic,
                rng: None,
            })?;
        }

        self.stats.gen_secs = secs0 + t0.elapsed().as_secs_f64();
        Ok(seqs)
    }

    // ------------------------------------------------------------------
    // Inference mode: serving (iteration-level continuous batching)
    // ------------------------------------------------------------------

    /// Opt the NEXT serving session into (or out of) the block-paged KV
    /// pool. Requires the artifact set's `paged_kv` capability (the
    /// `*_paged` entries + pool geometry in the manifest); the default
    /// arena layout needs no opt-in, so every pre-paging caller and golden
    /// is unaffected.
    pub fn use_paged_serving(&mut self, on: bool) -> Result<()> {
        if on {
            self.arts.manifest.require_paged_kv()?;
        }
        self.paged_serving = on;
        Ok(())
    }

    /// Whether the live/next serving session uses the block-paged pool
    /// (the [`crate::serving::SlotEngine::paged`] capability bit).
    pub fn serving_is_paged(&self) -> bool {
        self.paged_serving
    }

    /// Run the live paged pool OVERSUBSCRIBED: cap the allocator at `n`
    /// pages (below `n_slots * blocks_per_slot`) while the device buffers
    /// keep their full physical extent. Requires the `lazy_kv` artifact
    /// capability — oversubscription only works when admissions draw
    /// prompt pages lazily and decode grows tables on demand — and an
    /// idle pool (call right after [`HybridEngine::begin_serving`]).
    pub fn limit_kv_pages(&mut self, n: usize) -> Result<()> {
        self.arts.manifest.require_lazy_kv()?;
        let Some(kv) = self.kv.as_mut() else {
            bail!("limit_kv_pages: no live KV cache (call begin_serving first)");
        };
        kv.ledger.limit_pages(n)
    }

    /// Whether a paged admission of `prompt` (with `prefix_len` declared
    /// shared) can draw its pages right now — free list plus evictable
    /// prefixes. The scheduler defers admissions this rejects instead of
    /// spending a prefill fault on them. Arena serving always admits.
    pub fn kv_can_admit(&self, prompt: &[i32], prefix_len: usize) -> bool {
        match &self.kv {
            Some(kv) => kv.can_admit(prompt, prefix_len),
            None => true,
        }
    }

    /// Grow `slot`'s block table to cover its next `n` decode writes
    /// (see [`kv::PageLedger::reserve_rows`]). `Ok(false)` = pool
    /// exhausted even after LRU eviction: preempt the slot.
    pub fn kv_reserve_rows(&mut self, slot: usize, n: usize) -> Result<bool> {
        let Some(kv) = self.kv.as_mut() else {
            bail!("kv_reserve_rows: no live KV cache");
        };
        kv.reserve_rows(slot, n)
    }

    /// Enter serving mode: flip to inference and install a zeroed KV cache
    /// with every slot free. The continuous-batching scheduler
    /// (`crate::serving`) then admits requests one slot at a time via
    /// [`HybridEngine::prefill_slot`] and advances all live slots per
    /// iteration via [`HybridEngine::decode_slots`].
    ///
    /// The cache is the per-slot arena by default, or the block-paged pool
    /// after [`HybridEngine::use_paged_serving`]. The zero upload happens
    /// once per serving session; after that the caches live on device
    /// until the next train-mode flip.
    pub fn begin_serving(&mut self) -> Result<()> {
        // Fail early (not at first admission) if the artifact set predates
        // the serving entry points.
        self.arts.get("prefill_slot").map_err(|e| {
            e.context("artifacts predate continuous batching — re-run `make artifacts`")
        })?;
        self.arts.get("decode_slots")?;
        if self.paged_serving {
            self.arts.manifest.require_paged_kv()?;
            let m = &self.arts.manifest;
            let dims = KvCache::dims_for_paged(m);
            let (batch, smax, ps, np) = (m.batch, m.seq_len, m.page_size, m.kv_pages);
            self.enter(EngineMode::Inference);
            let numel: usize = dims.iter().product();
            let zeros = vec![0.0f32; numel];
            let kc = self.engine.upload_f32(&zeros, &dims)?;
            let vc = self.engine.upload_f32(&zeros, &dims)?;
            self.install_kv(KvCache::paged(kc, vc, dims, batch, smax, ps, np));
            return Ok(());
        }
        let dims = KvCache::dims_for(&self.arts.manifest);
        let batch = self.arts.manifest.batch;
        self.enter(EngineMode::Inference);
        let numel: usize = dims.iter().product();
        let zeros = vec![0.0f32; numel];
        let kc = self.engine.upload_f32(&zeros, &dims)?;
        let vc = self.engine.upload_f32(&zeros, &dims)?;
        self.install_kv(KvCache::arena(kc, vc, dims, batch));
        Ok(())
    }

    /// Admit one request into one free batch slot: run its prompt through
    /// the `prefill_slot` family of artifacts, which write the slot's K/V
    /// storage in place (all other slots' storage passes through
    /// untouched, so concurrent sequences keep their state). Returns the
    /// slot's [`AdmitOutcome`]: a single-row pending view (logits row, id,
    /// or top-k candidates per the traffic class) plus the cache-reuse
    /// report.
    ///
    /// The prompt may be ANY length `1..=prompt_len`. On the arena layout
    /// a short prompt is LEFT-PADDED into the fixed artifact shape and
    /// admitted with valid start `prompt_len - len` (requires the
    /// `padded_prompts` capability; the slot's computation is
    /// bit-identical to the unpadded exact-length prompt). On the paged
    /// layout the prompt is FRONT-ALIGNED (right-padded; the causal mask
    /// hides the tail), block pages are drawn from the ledger — with the
    /// page-aligned part of [`Admission::prefix_len`] mapped from the
    /// shared-prefix registry on a hit — and a faulted artifact call frees
    /// the admission's pages before returning the error.
    pub fn prefill_slot(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
        let m = &self.arts.manifest;
        let (b, sp) = (m.batch, m.prompt_len);
        let padded_artifacts = m.padded_prompts;
        let paged = self.paged_serving;
        let prompt = adm.prompt;
        let traffic = adm.traffic;
        let l = prompt.len();
        if l == 0 || l > sp {
            bail!("prefill_slot prompt must be 1..={sp} tokens, got {l}");
        }
        if l < sp && !paged {
            m.require_padded_prompts()?;
        }
        if slot >= b {
            bail!("prefill_slot slot {slot} out of range (batch {b})");
        }
        if self.mode != EngineMode::Inference || self.kv.is_none() {
            bail!("prefill_slot requires serving mode (call begin_serving first)");
        }
        if let Some(held) = self.kv.as_ref().unwrap().len_of(slot) {
            bail!("prefill_slot: slot {slot} still holds a {held}-token sequence");
        }
        if paged {
            return self.prefill_slot_paged(slot, adm);
        }
        let pad = sp - l;
        let t0 = Instant::now();
        let (art, n_out) = self.gen_artifact("prefill_slot", traffic)?;
        let name = art.name.clone();
        let mut padded = vec![crate::data::synthetic::Vocab::PAD; sp];
        padded[pad..].copy_from_slice(prompt);
        let prompt_buf = self.engine.upload_i32(&padded, &[1, sp])?;
        let slot_buf = self.engine.upload_i32(&[slot as i32], &[1])?;
        // The `_rng` entries always take the start input; older plain /
        // `_sampled` entries only with the `padded_prompts` capability.
        let device_rng = traffic == TrafficClass::DeviceCategorical;
        let start_buf = if padded_artifacts || device_rng {
            Some(self.engine.upload_i32(&[pad as i32], &[1])?)
        } else {
            None
        };
        let rng_bufs = if device_rng {
            let Some(rng) = adm.rng else {
                bail!("prefill_slot: device-RNG admission carries no seed/params inputs");
            };
            Some((
                self.engine.upload_i32(&rng.seed, &[1, 2])?,
                self.engine.upload_i32(&[0], &[1])?, // prefill performs draw #0
                self.engine.upload_f32(&rng.sparams, &[3])?,
            ))
        } else {
            None
        };
        let kv = self.kv.as_ref().unwrap();
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&kv.k);
        inputs.push(&kv.v);
        inputs.push(&prompt_buf);
        inputs.push(&slot_buf);
        if let Some(sb) = &start_buf {
            inputs.push(sb);
        }
        if let Some((seeds, steps, sp_buf)) = &rng_bufs {
            inputs.push(seeds);
            inputs.push(steps);
            inputs.push(sp_buf);
        }
        let mut out = art.call_to_buffers(&inputs, n_out)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let kv = self.kv.as_mut().unwrap();
        kv.update(kc, vc);
        kv.alloc(slot, l, pad)?;
        let sample = self.fetch_sample(&name, traffic, &out)?;
        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(AdmitOutcome::cold(PendingRow::from_row(sample.row(0))))
    }

    /// Paged admission tail of [`HybridEngine::prefill_slot`]: draw the
    /// slot's block table from the ledger (shared-prefix pages mapped on a
    /// registry hit), run the front-aligned prompt through the
    /// `prefill_slot_paged` artifact family, and register the prefix for
    /// later admissions only AFTER the call succeeded. Unlike the arena
    /// path — where KV rows are claimed only after the artifact call — the
    /// pages are allocated up front (the artifact needs the block table),
    /// so a faulted call must free them here before the error propagates.
    fn prefill_slot_paged(&mut self, slot: usize, adm: &Admission) -> Result<AdmitOutcome> {
        let t0 = Instant::now();
        let plan = self
            .kv
            .as_mut()
            .unwrap()
            .alloc_shared(slot, adm.prompt, adm.prefix_len)?;
        match self.prefill_slot_paged_call(slot, adm) {
            Ok(sample) => {
                if !plan.prefix_hit {
                    self.kv
                        .as_mut()
                        .unwrap()
                        .register_prefix(slot, adm.prefix_len, adm.prompt)?;
                }
                self.stats.gen_secs += t0.elapsed().as_secs_f64();
                Ok(AdmitOutcome {
                    pending: PendingRow::from_row(sample.row(0)),
                    reused_tokens: plan.reused_tokens,
                    prefix_hit: plan.prefix_hit,
                })
            }
            Err(e) => {
                // The pages were drawn before the call (the artifact needs
                // the block table): hand them back so a faulted admission
                // leaks nothing.
                let _ = self.kv.as_mut().unwrap().free(slot);
                Err(e)
            }
        }
    }

    /// The fallible middle of [`HybridEngine::prefill_slot_paged`]: upload
    /// the front-aligned prompt + block table, run the artifact, adopt the
    /// returned cache pair, and fetch the slot's sampling row. Split out
    /// so its caller can free the admission's pages on ANY error here.
    fn prefill_slot_paged_call(&mut self, slot: usize, adm: &Admission) -> Result<SampleOut> {
        let sp = self.arts.manifest.prompt_len;
        let l = adm.prompt.len();
        let (art, n_out) = self.gen_artifact("prefill_slot_paged", adm.traffic)?;
        let name = art.name.clone();
        // Front-aligned: real tokens first, PAD tail (causally inert).
        let mut padded = vec![crate::data::synthetic::Vocab::PAD; sp];
        padded[..l].copy_from_slice(adm.prompt);
        let prompt_buf = self.engine.upload_i32(&padded, &[1, sp])?;
        let kv = self.kv.as_ref().unwrap();
        let table = kv.block_table(slot).expect("alloc_shared left no table");
        // The artifact compiles against the full [1, blocks_per_slot]
        // table; a lazy table (prompt pages only) is zero-padded, so the
        // PAD tail's scatter rows land on garbage page 0 — the same
        // storage dead decode rows write, masked out of every read.
        let mb = kv.ledger.blocks_per_slot();
        let mut bt = vec![0i32; mb];
        for (j, &p) in table.iter().enumerate() {
            bt[j] = p as i32;
        }
        let bt_buf = self.engine.upload_i32(&bt, &[1, mb])?;
        let last_buf = self.engine.upload_i32(&[l as i32 - 1], &[1])?;
        let rng_bufs = if adm.traffic == TrafficClass::DeviceCategorical {
            let Some(rng) = adm.rng else {
                bail!("prefill_slot_paged: device-RNG admission carries no seed/params inputs");
            };
            Some((
                self.engine.upload_i32(&rng.seed, &[1, 2])?,
                self.engine.upload_i32(&[0], &[1])?, // prefill performs draw #0
                self.engine.upload_f32(&rng.sparams, &[3])?,
            ))
        } else {
            None
        };
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&kv.k);
        inputs.push(&kv.v);
        inputs.push(&prompt_buf);
        inputs.push(&bt_buf);
        inputs.push(&last_buf);
        if let Some((seeds, steps, sp_buf)) = &rng_bufs {
            inputs.push(seeds);
            inputs.push(steps);
            inputs.push(sp_buf);
        }
        let mut out = art.call_to_buffers(&inputs, n_out)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        self.kv.as_mut().unwrap().update(kc, vc);
        self.fetch_sample(&name, adm.traffic, &out)
    }

    /// One continuous-batching decode step: advance every `active` slot by
    /// one token at its OWN position (`batch.pos[slot]` = logical cache
    /// row the fed token is written at, which must equal the slot's depth
    /// `pad + valid`). On the arena layout `batch.starts[slot]` is the
    /// slot's valid start (left-pad width; the artifact masks cache
    /// entries before it out of attention and embeds the token at logical
    /// position `pos - start`); on the paged layout starts must be all
    /// zero and the artifact takes each slot's block table instead —
    /// INACTIVE slots get the all-zero garbage-page row, never their old
    /// table, so a dead row's PAD write can only land in storage no live
    /// slot maps. Inactive slots are fed PAD at position 0. Returns the
    /// batch's sampling view; only the active rows are meaningful.
    pub fn decode_slots(&mut self, batch: &DecodeBatch) -> Result<SampleOut> {
        let m = &self.arts.manifest;
        let b = m.batch;
        let padded_artifacts = m.padded_prompts;
        let paged = self.paged_serving;
        let (toks, pos, starts, active) = (batch.toks, batch.pos, batch.starts, batch.active);
        let traffic = batch.traffic;
        if toks.len() != b || pos.len() != b || starts.len() != b || active.len() != b {
            bail!(
                "decode_slots wants [{b}] toks/pos/starts/active, got {}/{}/{}/{}",
                toks.len(),
                pos.len(),
                starts.len(),
                active.len()
            );
        }
        if paged {
            if starts.iter().any(|&s| s != 0) {
                bail!("decode_slots: paged serving is front-aligned — nonzero valid start");
            }
        } else if !padded_artifacts && starts.iter().any(|&s| s != 0) {
            m.require_padded_prompts()?;
        }
        if self.mode != EngineMode::Inference || self.kv.is_none() {
            bail!("decode_slots requires serving mode (call begin_serving first)");
        }
        let t0 = Instant::now();
        if paged {
            // Lazy growth: the artifact writes the fed token's K/V row
            // through the table as uploaded, so every active slot's table
            // must cover its write row BEFORE dispatch. The scheduler
            // reserves (and preempts on exhaustion) via reserve_decode;
            // for direct callers this draw is the growth path, and an
            // exhausted pool is a hard error here — there is no requeue
            // below the scheduler.
            let kv = self.kv.as_mut().unwrap();
            for slot in 0..b {
                if active[slot] && !kv.reserve_rows(slot, 1)? {
                    bail!(
                        "decode_slots: KV pool exhausted growing slot {slot} \
                         ({} free of {} usable pages) — preempt or retire a slot first",
                        kv.ledger.free_pages(),
                        kv.ledger.usable_pages()
                    );
                }
            }
        }
        let base = if paged { "decode_slots_paged" } else { "decode_slots" };
        let (art, n_out) = self.gen_artifact(base, traffic)?;
        let name = art.name.clone();
        let tok_buf = self.engine.upload_i32(toks, &[b])?;
        let pos_buf = self.engine.upload_i32(pos, &[b])?;
        let kv = self.kv.as_ref().unwrap();
        let extra_buf: Option<PjRtBuffer> = if paged {
            // Flat [b, blocks_per_slot] block tables: live slots map their
            // own pages; dead rows map the garbage page (page 0) so their
            // PAD write cannot corrupt any live slot's storage.
            let mb = kv.ledger.blocks_per_slot();
            let mut bt = vec![0i32; b * mb];
            for slot in 0..b {
                if !active[slot] {
                    continue;
                }
                let Some(row) = kv.block_table(slot) else {
                    bail!("decode_slots: active slot {slot} has no block table");
                };
                for (j, &p) in row.iter().enumerate() {
                    bt[slot * mb + j] = p as i32;
                }
            }
            Some(self.engine.upload_i32(&bt, &[b, mb])?)
        } else if padded_artifacts {
            Some(self.engine.upload_i32(starts, &[b])?)
        } else {
            // Pre-capability arena artifacts take no starts input.
            None
        };
        let rng_bufs = if traffic == TrafficClass::DeviceCategorical {
            let Some(rng) = batch.rng else {
                bail!("decode_slots: device-RNG batch carries no seed/step inputs");
            };
            if rng.seeds.len() != 2 * b || rng.steps.len() != b {
                bail!(
                    "decode_slots rng wants [{b}, 2] seeds + [{b}] steps, got {}/{}",
                    rng.seeds.len(),
                    rng.steps.len()
                );
            }
            Some((
                self.engine.upload_i32(rng.seeds, &[b, 2])?,
                self.engine.upload_i32(rng.steps, &[b])?,
                self.engine.upload_f32(&rng.sparams, &[3])?,
            ))
        } else {
            None
        };
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&kv.k);
        inputs.push(&kv.v);
        inputs.push(&tok_buf);
        inputs.push(&pos_buf);
        if let Some(eb) = &extra_buf {
            inputs.push(eb);
        }
        if let Some((seeds, steps, sp_buf)) = &rng_bufs {
            inputs.push(seeds);
            inputs.push(steps);
            inputs.push(sp_buf);
        }
        let mut out = art.call_to_buffers(&inputs, n_out)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        // Donated K/V inputs: consumed by the call, replaced by the fresh
        // output handles (see the runtime contract note).
        let kv = self.kv.as_mut().unwrap();
        kv.update(kc, vc);
        kv.advance(active, pos)?;
        let sample = self.fetch_sample(&name, traffic, &out)?;
        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(sample)
    }

    /// One fused N-token decode chunk over the block-paged pool: a single
    /// `decode_chunk{N}` artifact call advances every `active` slot by up
    /// to `N` tokens (scan over the paged per-slot decode + device-RNG
    /// sampling tail) and returns the `[N, b]` emitted ids row-major. A
    /// per-row latch inside the artifact freezes rows that emit EOS or
    /// exhaust their `quota` mid-chunk — frozen steps re-write the row's
    /// last K/V entry idempotently and consume no RNG draws, so the KV
    /// ledger advances by exactly [`crate::serving::chunk_consumed`] and a
    /// retired row's stream is unperturbed. Paged serving only; `n == 1`
    /// callers use the stepwise [`HybridEngine::decode_slots`].
    pub fn decode_slots_chunk(&mut self, batch: &ChunkBatch) -> Result<Vec<i32>> {
        let m = &self.arts.manifest;
        let b = m.batch;
        let n = batch.n;
        if n < 2 {
            bail!("decode_slots_chunk wants n >= 2 — n == 1 is the stepwise decode_slots path");
        }
        m.require_device_rng()?;
        m.require_decode_chunk(n)?;
        if !self.paged_serving {
            bail!(
                "fused decode chunks serve from the block-paged KV pool only — \
                 enable use_paged_serving(true) before decoding chunks"
            );
        }
        let (toks, pos, active, quota) = (batch.toks, batch.pos, batch.active, batch.quota);
        if toks.len() != b || pos.len() != b || active.len() != b || quota.len() != b {
            bail!(
                "decode_slots_chunk wants [{b}] toks/pos/active/quota, got {}/{}/{}/{}",
                toks.len(),
                pos.len(),
                active.len(),
                quota.len()
            );
        }
        let rng = &batch.rng;
        if rng.seeds.len() != 2 * b || rng.steps.len() != b {
            bail!(
                "decode_slots_chunk rng wants [{b}, 2] seeds + [{b}] steps, got {}/{}",
                rng.seeds.len(),
                rng.steps.len()
            );
        }
        if self.mode != EngineMode::Inference || self.kv.is_none() {
            bail!("decode_slots_chunk requires serving mode (call begin_serving first)");
        }
        let t0 = Instant::now();
        // Lazy growth: a chunk can write up to min(n, quota) fresh K/V
        // rows per live slot (the EOS/quota latch turns the rest into
        // idempotent re-writes of the last accepted row), and the artifact
        // scatters through the table as uploaded — so the worst case must
        // be reserved BEFORE dispatch. The scheduler preempts on
        // exhaustion via reserve_decode; for direct callers an exhausted
        // pool is a hard error here.
        {
            let kv = self.kv.as_mut().unwrap();
            for slot in 0..b {
                if !active[slot] {
                    continue;
                }
                let worst = n.min(quota[slot].max(0) as usize).max(1);
                if !kv.reserve_rows(slot, worst)? {
                    bail!(
                        "decode_slots_chunk: KV pool exhausted growing slot {slot} by \
                         {worst} rows ({} free of {} usable pages) — preempt or retire \
                         a slot first",
                        kv.ledger.free_pages(),
                        kv.ledger.usable_pages()
                    );
                }
            }
        }
        let art = self.arts.get(&format!("decode_chunk{n}"))?;
        let name = art.name.clone();
        let tok_buf = self.engine.upload_i32(toks, &[b])?;
        let pos_buf = self.engine.upload_i32(pos, &[b])?;
        let kv = self.kv.as_ref().unwrap();
        // Flat [b, blocks_per_slot] block tables, dead rows on the garbage
        // page — same contract as the stepwise paged decode. A lazy table
        // is zero-padded to the full width: blocks past a slot's
        // reservation alias garbage page 0, which the kernels' live-length
        // mask (`idx <= pos`) keeps out of every read.
        let mb = kv.ledger.blocks_per_slot();
        let mut bt = vec![0i32; b * mb];
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let Some(row) = kv.block_table(slot) else {
                bail!("decode_slots_chunk: active slot {slot} has no block table");
            };
            for (j, &p) in row.iter().enumerate() {
                bt[slot * mb + j] = p as i32;
            }
        }
        let bt_buf = self.engine.upload_i32(&bt, &[b, mb])?;
        let seeds_buf = self.engine.upload_i32(rng.seeds, &[b, 2])?;
        let steps_buf = self.engine.upload_i32(rng.steps, &[b])?;
        let quota_buf = self.engine.upload_i32(quota, &[b])?;
        // Dead rows enter the chunk pre-frozen: no draws, garbage-page
        // writes only.
        let frozen: Vec<i32> = active.iter().map(|&a| i32::from(!a)).collect();
        let frozen_buf = self.engine.upload_i32(&frozen, &[b])?;
        let eos_buf = self
            .engine
            .upload_i32(&[crate::data::synthetic::Vocab::EOS], &[1])?;
        let sparams_buf = self.engine.upload_f32(&rng.sparams, &[3])?;
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.push(&kv.k);
        inputs.push(&kv.v);
        inputs.push(&tok_buf);
        inputs.push(&pos_buf);
        inputs.push(&bt_buf);
        inputs.push(&seeds_buf);
        inputs.push(&steps_buf);
        inputs.push(&quota_buf);
        inputs.push(&frozen_buf);
        inputs.push(&eos_buf);
        inputs.push(&sparams_buf);
        let mut out = art.call_to_buffers(&inputs, 3)?;
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let kv = self.kv.as_mut().unwrap();
        kv.update(kc, vc);
        let ids = match self.engine.fetch(&name, &out[0])? {
            HostTensor::I32(ids, _) => ids,
            other => bail!("{name}: chunk-ids fetch returned f32 {:?}", other.shape()),
        };
        if ids.len() != n * b {
            bail!("{name}: chunk ids must be [{n}, {b}], got {} elements", ids.len());
        }
        // Ledger advance mirrors the scheduler's token walk exactly: each
        // live slot's depth grows by the tokens it actually consumed (the
        // latch makes post-boundary K/V writes idempotent re-writes).
        let kv = self.kv.as_mut().unwrap();
        for slot in 0..b {
            if !active[slot] {
                continue;
            }
            let consumed = crate::serving::chunk_consumed(
                &ids,
                b,
                slot,
                n,
                quota[slot].max(0) as usize,
            );
            kv.advance_chunk(slot, pos[slot], consumed)?;
        }
        self.stats.gen_secs += t0.elapsed().as_secs_f64();
        Ok(ids)
    }

    /// Retire a finished sequence: on the arena layout its K/V rows become
    /// dead; on the paged layout its pages drop one reference each and
    /// return to the free list unless a registered prefix (or another
    /// slot sharing them) still holds them. The slot is immediately
    /// reusable by the next admission.
    pub fn release_slot(&mut self, slot: usize) -> Result<()> {
        let Some(kv) = self.kv.as_mut() else {
            bail!("release_slot: no live KV cache");
        };
        kv.free(slot)
    }

    /// Free slots currently available for admission (serving mode).
    pub fn free_slots(&self) -> usize {
        match &self.kv {
            Some(kv) => kv.n_slots() - kv.n_active(),
            None => 0,
        }
    }

    // ------------------------------------------------------------------
    // Forward passes over full sequences (experience scoring)
    // ------------------------------------------------------------------

    /// Full-sequence forward with pre-uploaded extra inputs (shared device
    /// buffers). Outputs are consumed entirely on host, so the literal
    /// path is the cheapest correct one here.
    fn forward_with_bufs(
        &self,
        artifact: &str,
        params: &ParamStore,
        extra: &[&PjRtBuffer],
    ) -> Result<Vec<HostTensor>> {
        let art = self.arts.get(artifact)?;
        let mut inputs: Vec<&PjRtBuffer> = params.buffers.iter().collect();
        inputs.extend_from_slice(extra);
        let out = art.call_buffers(&inputs)?;
        out.iter().map(HostTensor::from_literal).collect()
    }

    fn forward_with(
        &self,
        artifact: &str,
        params: &ParamStore,
        extra: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let extra_bufs: Vec<PjRtBuffer> = extra
            .iter()
            .map(|t| self.engine.upload(t))
            .collect::<Result<_>>()?;
        let refs: Vec<&PjRtBuffer> = extra_bufs.iter().collect();
        self.forward_with_bufs(artifact, params, &refs)
    }

    fn batch_tensor(&self, tokens: &[i32]) -> HostTensor {
        let m = &self.arts.manifest;
        HostTensor::I32(tokens.to_vec(), vec![m.batch, m.seq_len])
    }

    /// Score a generated batch with all four models — actor log-probs,
    /// frozen-reference log-probs, critic values, frozen-RM rewards at the
    /// `lens` positions — uploading the `[b, seq_len]` token batch ONCE and
    /// sharing the device buffer across the four forwards (the per-method
    /// path below uploads the identical batch every call).
    pub fn score_experience(&self, tokens: &[i32], lens: &[i32]) -> Result<ExperienceScores> {
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        if tokens.len() != b * s {
            bail!("tokens must be [{b}, {s}], got {} elements", tokens.len());
        }
        if lens.len() != b {
            bail!("lens must be [{b}], got {} elements", lens.len());
        }
        let tok_buf = self.engine.upload_i32(tokens, &[b, s])?;
        let lens_buf = self.engine.upload_i32(lens, &[b])?;
        let old_logp = self.forward_with_bufs("logprobs_forward", &self.actor, &[&tok_buf])?;
        let ref_logp =
            self.forward_with_bufs("logprobs_forward", &self.ref_actor, &[&tok_buf])?;
        let values = self.forward_with_bufs("critic_forward", &self.critic, &[&tok_buf])?;
        let rm = self.forward_with_bufs("rm_forward", &self.rm, &[&tok_buf, &lens_buf])?;
        Ok(ExperienceScores {
            old_logp: old_logp[0].as_f32()?.to_vec(),
            ref_logp: ref_logp[0].as_f32()?.to_vec(),
            values: values[0].as_f32()?.to_vec(),
            rm_scores: rm[0].as_f32()?.to_vec(),
        })
    }

    /// Current-policy log-probs `[b, s-1]`.
    pub fn actor_logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.forward_with("logprobs_forward", &self.actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Frozen-reference log-probs `[b, s-1]` (the KL anchor).
    pub fn ref_logprobs(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out =
            self.forward_with("logprobs_forward", &self.ref_actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Full per-position logits `[b, s, vocab]` flattened — the naive
    /// no-KV-cache generation baseline's forward (ablation for Figure 5).
    pub fn full_logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out =
            self.forward_with("logits_forward", &self.actor, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Critic values `[b, s]`.
    pub fn critic_values(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let out = self.forward_with("critic_forward", &self.critic, &[self.batch_tensor(tokens)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Frozen reward-model scores `[b]` at `lens` positions.
    pub fn rm_rewards(&self, tokens: &[i32], lens: &[i32]) -> Result<Vec<f32>> {
        let m = &self.arts.manifest;
        let out = self.forward_with(
            "rm_forward",
            &self.rm,
            &[self.batch_tensor(tokens), HostTensor::I32(lens.to_vec(), vec![m.batch])],
        )?;
        Ok(out[0].as_f32()?.to_vec())
    }

    // ------------------------------------------------------------------
    // Training mode: the train-step artifacts
    // ------------------------------------------------------------------

    /// One SFT step; returns the loss. The updated parameters and optimizer
    /// state come back as device buffers and are adopted in place — only
    /// the scalar loss is fetched.
    pub fn sft_step(&mut self, batch: &TokenBatch, lr: f32) -> Result<f32> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let art = self.arts.get("sft_step")?;
        let np = self.actor.len();
        let no = self.actor_opt.len();
        let extra_bufs = [
            self.engine.upload_i32(&batch.tokens, &[batch.b, batch.s])?,
            self.engine.upload_f32(&batch.loss_mask, &[batch.b, batch.s - 1])?,
            self.engine.upload_f32(&[lr], &[])?,
        ];
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.extend(self.actor_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_to_buffers(&inputs, np + no + 1)?;
        let (params, opt, scalars) = split_outputs(out, np, no, 1, "sft_step")?;
        self.actor.replace_buffers(params)?;
        self.actor_opt.replace_buffers(opt)?;
        let loss = self.engine.fetch("sft_step", &scalars[0])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (batch.b * batch.s) as u64;
        Ok(loss)
    }

    /// SFT eval loss (no update).
    pub fn sft_eval(&self, batch: &TokenBatch) -> Result<f32> {
        let out = self.forward_with(
            "sft_eval",
            &self.actor,
            &[
                HostTensor::I32(batch.tokens.clone(), vec![batch.b, batch.s]),
                HostTensor::F32(batch.loss_mask.clone(), vec![batch.b, batch.s - 1]),
            ],
        )?;
        out[0].item_f32()
    }

    /// One reward-model step; returns (loss, pairwise accuracy).
    pub fn rm_step(&mut self, pb: &PairBatch, lr: f32) -> Result<(f32, f32)> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let art = self.arts.get("rm_step")?;
        let np = self.critic.len();
        let no = self.critic_opt.len();
        let extra_bufs = [
            self.engine.upload_i32(&pb.chosen, &[pb.b, pb.s])?,
            self.engine.upload_i32(&pb.rejected, &[pb.b, pb.s])?,
            self.engine.upload_i32(&pb.lens_chosen, &[pb.b])?,
            self.engine.upload_i32(&pb.lens_rejected, &[pb.b])?,
            self.engine.upload_f32(&[lr], &[])?,
        ];
        let mut inputs: Vec<&PjRtBuffer> = self.critic.buffers.iter().collect();
        inputs.extend(self.critic_opt.buffers.iter());
        inputs.extend(extra_bufs.iter());
        let out = art.call_to_buffers(&inputs, np + no + 2)?;
        let (params, opt, scalars) = split_outputs(out, np, no, 2, "rm_step")?;
        self.critic.replace_buffers(params)?;
        self.critic_opt.replace_buffers(opt)?;
        let loss = self.engine.fetch("rm_step", &scalars[0])?.item_f32()?;
        let acc = self.engine.fetch("rm_step", &scalars[1])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (2 * pb.b * pb.s) as u64;
        Ok((loss, acc))
    }

    /// RM eval (loss, accuracy) without update.
    pub fn rm_eval(&self, pb: &PairBatch) -> Result<(f32, f32)> {
        let out = self.forward_with(
            "rm_eval",
            &self.critic,
            &[
                HostTensor::I32(pb.chosen.clone(), vec![pb.b, pb.s]),
                HostTensor::I32(pb.rejected.clone(), vec![pb.b, pb.s]),
                HostTensor::I32(pb.lens_chosen.clone(), vec![pb.b]),
                HostTensor::I32(pb.lens_rejected.clone(), vec![pb.b]),
            ],
        )?;
        Ok((out[0].item_f32()?, out[1].item_f32()?))
    }

    /// Stage one experience batch's epoch-constant tensors on device. PPO
    /// runs `ppo_epochs` actor+critic updates over the SAME experience
    /// batch; staging once and re-feeding the buffers turns the per-epoch
    /// upload cost from 6 tensors into just the fresh ptx batch and the
    /// scalar hyperparameters (mirrors what `score_experience` already
    /// does for the scoring forwards).
    pub fn stage_experience(
        &self,
        tokens: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        returns: &[f32],
        old_values: &[f32],
        mask: &[f32],
    ) -> Result<StagedExperience> {
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let w = b * (s - 1);
        if tokens.len() != b * s {
            bail!("stage_experience tokens must be [{b}, {s}], got {}", tokens.len());
        }
        for (what, len) in [
            ("old_logp", old_logp.len()),
            ("adv", adv.len()),
            ("returns", returns.len()),
            ("old_values", old_values.len()),
            ("mask", mask.len()),
        ] {
            if len != w {
                bail!("stage_experience {what} must be [{b}, {}], got {len}", s - 1);
            }
        }
        Ok(StagedExperience {
            tokens: self.engine.upload_i32(tokens, &[b, s])?,
            old_logp: self.engine.upload_f32(old_logp, &[b, s - 1])?,
            adv: self.engine.upload_f32(adv, &[b, s - 1])?,
            returns: self.engine.upload_f32(returns, &[b, s - 1])?,
            old_values: self.engine.upload_f32(old_values, &[b, s - 1])?,
            mask: self.engine.upload_f32(mask, &[b, s - 1])?,
        })
    }

    /// Shared tail of both actor-step entry points: inputs already on
    /// device, outputs adopted in place, scalars fetched.
    #[allow(clippy::too_many_arguments)]
    fn ppo_actor_exec(
        &mut self,
        tokens: &PjRtBuffer,
        old_logp: &PjRtBuffer,
        adv: &PjRtBuffer,
        mask: &PjRtBuffer,
        ptx: &PjRtBuffer,
        clip_eps: f32,
        ptx_coef: f32,
        lr: f32,
    ) -> Result<ActorStepOut> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let art = self.arts.get("ppo_actor_step")?;
        let np = self.actor.len();
        let no = self.actor_opt.len();
        let hyper_buf = self.engine.upload_f32(&[clip_eps, ptx_coef, 0.0, 0.0], &[4])?;
        let lr_buf = self.engine.upload_f32(&[lr], &[])?;
        let mut inputs: Vec<&PjRtBuffer> = self.actor.buffers.iter().collect();
        inputs.extend(self.actor_opt.buffers.iter());
        inputs.extend([tokens, old_logp, adv, mask, ptx, &hyper_buf, &lr_buf]);
        let out = art.call_to_buffers(&inputs, np + no + 3)?;
        let (params, opt, scalars) = split_outputs(out, np, no, 3, "ppo_actor_step")?;
        self.actor.replace_buffers(params)?;
        self.actor_opt.replace_buffers(opt)?;
        let loss = self.engine.fetch("ppo_actor_step", &scalars[0])?.item_f32()?;
        let kl = self.engine.fetch("ppo_actor_step", &scalars[1])?.item_f32()?;
        let clipfrac = self.engine.fetch("ppo_actor_step", &scalars[2])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (b * s) as u64;
        Ok(ActorStepOut { loss, approx_kl: kl, clipfrac })
    }

    /// One PPO actor update over a full experience batch (one-shot path:
    /// uploads every tensor; epoch loops should stage once and use
    /// [`HybridEngine::ppo_actor_step_staged`]).
    #[allow(clippy::too_many_arguments)]
    pub fn ppo_actor_step(
        &mut self,
        tokens: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        mask: &[f32],
        ptx_tokens: &[i32],
        clip_eps: f32,
        ptx_coef: f32,
        lr: f32,
    ) -> Result<ActorStepOut> {
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let tok_buf = self.engine.upload_i32(tokens, &[b, s])?;
        let logp_buf = self.engine.upload_f32(old_logp, &[b, s - 1])?;
        let adv_buf = self.engine.upload_f32(adv, &[b, s - 1])?;
        let mask_buf = self.engine.upload_f32(mask, &[b, s - 1])?;
        let ptx_buf = self.engine.upload_i32(ptx_tokens, &[b, s])?;
        self.ppo_actor_exec(
            &tok_buf, &logp_buf, &adv_buf, &mask_buf, &ptx_buf, clip_eps, ptx_coef, lr,
        )
    }

    /// One PPO actor update re-feeding a staged experience batch — only
    /// the ptx batch and scalars cross the host boundary.
    pub fn ppo_actor_step_staged(
        &mut self,
        staged: &StagedExperience,
        ptx_tokens: &[i32],
        clip_eps: f32,
        ptx_coef: f32,
        lr: f32,
    ) -> Result<ActorStepOut> {
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let ptx_buf = self.engine.upload_i32(ptx_tokens, &[b, s])?;
        self.ppo_actor_exec(
            &staged.tokens,
            &staged.old_logp,
            &staged.adv,
            &staged.mask,
            &ptx_buf,
            clip_eps,
            ptx_coef,
            lr,
        )
    }

    /// Shared tail of both critic-step entry points.
    fn ppo_critic_exec(
        &mut self,
        tokens: &PjRtBuffer,
        returns: &PjRtBuffer,
        old_values: &PjRtBuffer,
        mask: &PjRtBuffer,
        clip_eps: f32,
        lr: f32,
    ) -> Result<f32> {
        self.enter(EngineMode::Train);
        let t0 = Instant::now();
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let art = self.arts.get("ppo_critic_step")?;
        let np = self.critic.len();
        let no = self.critic_opt.len();
        let hyper_buf = self.engine.upload_f32(&[clip_eps, 0.0, 0.0, 0.0], &[4])?;
        let lr_buf = self.engine.upload_f32(&[lr], &[])?;
        let mut inputs: Vec<&PjRtBuffer> = self.critic.buffers.iter().collect();
        inputs.extend(self.critic_opt.buffers.iter());
        inputs.extend([tokens, returns, old_values, mask, &hyper_buf, &lr_buf]);
        let out = art.call_to_buffers(&inputs, np + no + 1)?;
        let (params, opt, scalars) = split_outputs(out, np, no, 1, "ppo_critic_step")?;
        self.critic.replace_buffers(params)?;
        self.critic_opt.replace_buffers(opt)?;
        let loss = self.engine.fetch("ppo_critic_step", &scalars[0])?.item_f32()?;
        self.stats.train_secs += t0.elapsed().as_secs_f64();
        self.stats.train_tokens += (b * s) as u64;
        Ok(loss)
    }

    /// One PPO critic update (one-shot path; see
    /// [`HybridEngine::ppo_critic_step_staged`] for epoch loops).
    pub fn ppo_critic_step(
        &mut self,
        tokens: &[i32],
        returns: &[f32],
        old_values: &[f32],
        mask: &[f32],
        clip_eps: f32,
        lr: f32,
    ) -> Result<f32> {
        let m = &self.arts.manifest;
        let (b, s) = (m.batch, m.seq_len);
        let tok_buf = self.engine.upload_i32(tokens, &[b, s])?;
        let ret_buf = self.engine.upload_f32(returns, &[b, s - 1])?;
        let val_buf = self.engine.upload_f32(old_values, &[b, s - 1])?;
        let mask_buf = self.engine.upload_f32(mask, &[b, s - 1])?;
        self.ppo_critic_exec(&tok_buf, &ret_buf, &val_buf, &mask_buf, clip_eps, lr)
    }

    /// One PPO critic update re-feeding a staged experience batch — only
    /// the scalars cross the host boundary.
    pub fn ppo_critic_step_staged(
        &mut self,
        staged: &StagedExperience,
        clip_eps: f32,
        lr: f32,
    ) -> Result<f32> {
        self.ppo_critic_exec(
            &staged.tokens,
            &staged.returns,
            &staged.old_values,
            &staged.mask,
            clip_eps,
            lr,
        )
    }

    /// EMA shadow update (no-op if EMA disabled). The new shadow stays on
    /// device end to end.
    pub fn ema_update(&mut self, decay: f32) -> Result<()> {
        let Some(ema) = &mut self.ema else { return Ok(()) };
        let n_ema = ema.len();
        let art = self.arts.get("ema_update")?;
        let decay_buf = self.engine.upload_f32(&[decay], &[])?;
        let mut inputs: Vec<&PjRtBuffer> = ema.buffers.iter().collect();
        inputs.extend(self.actor.buffers.iter());
        inputs.push(&decay_buf);
        let out = art.call_to_buffers(&inputs, n_ema)?;
        ema.replace_buffers(out)?;
        Ok(())
    }

    /// Swap the EMA shadow in as the serving actor (final checkpoint choice).
    pub fn promote_ema(&mut self) -> Result<()> {
        let Some(ema) = &self.ema else {
            bail!("EMA is disabled");
        };
        let host = ema.to_host()?;
        let lits: Vec<Literal> = host.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        self.actor.replace(&self.engine, &lits)?;
        Ok(())
    }
}
