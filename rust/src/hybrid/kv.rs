//! KV-cache state for the inference phase: the "light-weight memory
//! management system" of paper §4. The caches are device-resident buffers
//! whose lifetime is bounded by the inference phase — installed straight
//! from the prefill artifact's output buffers, swapped (never copied) for
//! the decode artifact's output buffers each step, released at the
//! train-mode flip. K/V bytes never transit host memory between prefill
//! and the flip; per-decode-step host traffic is the logits row only.
//!
//! Device bytes live in [`KvCache`]; every host-side decision about them —
//! which slot owns which storage, where the next token writes, what can be
//! reused — lives in the buffer-free [`PageLedger`], which comes in two
//! layouts:
//!
//! * **Arena** (`[n_layers, b*h, smax, d_head]`): each batch slot owns a
//!   contiguous row group. A variable-length prompt arrives LEFT-PADDED
//!   (`pad` dead entries at the front, masked out of attention by the
//!   artifacts' valid-start inputs), so a slot's state is `(valid, pad)`
//!   with the next write at row `pad + valid`.
//! * **Paged** (`[n_layers, n_heads, n_pages * page_size, d_head]`): the
//!   vLLM-style block-paged pool, an OVERSUBSCRIBED allocator. Slots own
//!   no storage; each holds a *block table* mapping its logical blocks
//!   onto refcounted physical pages drawn from a free list — and draws
//!   them LAZILY: admission takes only `ceil(valid / page_size)` pages
//!   (the prompt's coverage), and decode grows the table one page at a
//!   time as the sequence's depth crosses page boundaries
//!   ([`PageLedger::reserve_rows`], called BEFORE each dispatch because
//!   the artifacts write the fed token's K/V rows through the table as
//!   uploaded). The artifacts compile against a max-size
//!   (`blocks_per_slot`) block table; a lazy table is uploaded zero-padded,
//!   so its dead tail points at garbage page 0 exactly like dead decode
//!   rows do — the kernels' live-length mask (`idx <= pos`) keeps those
//!   rows' contribution at exactly zero, which is what makes a short table
//!   bit-exact against a full one (the `lazy_kv` artifact capability).
//!   Prompts are FRONT-ALIGNED (`pad == 0`), so the next write is at
//!   logical row `valid`. Page 0 is reserved as the garbage page: it never
//!   enters the free list and never appears in a table. Pages holding a
//!   **shared prompt prefix** are mapped into several tables at once:
//!   admission hashes the page-aligned prefix, a registry hit maps the
//!   registered pages (refcount up) instead of allocating, and retirement
//!   only returns a page to the free list when its last reference drops.
//!   When the free list runs short, registered prefixes are evicted in
//!   **LRU order**: every entry carries a monotone touch stamp (bumped on
//!   registration and on every admission hit), and the least-recently
//!   touched entry is stolen first — deterministic because the clock never
//!   ties. If eviction cannot cover a mid-decode page draw the pool is
//!   genuinely full of live sequences: [`PageLedger::reserve_rows`]
//!   reports it (`Ok(false)`) and the scheduler PREEMPTS the slot — the
//!   request retires as `FinishReason::Preempted` through the fault-policy
//!   requeue path and replays later, bit-identically (greedy decode and
//!   the counter-keyed device RNG are both pure functions of the request).
//!
//! The continuous-batching scheduler admits a new request by prefilling
//! straight into a retired slot (`prefill_slot` / `prefill_slot_paged`
//! artifacts) while the other slots keep decoding — the ledger here is
//! what keeps admissions, per-row positions, block tables, and the device
//! cache honest about which rows are live, which are padding, and which
//! pages are shared.

use std::collections::BTreeMap;

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::runtime::Manifest;

/// Which geometry the ledger (and the device buffers) use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Per-slot contiguous row groups, left-padded prompts.
    Arena,
    /// Block-paged pool behind per-slot block tables, front-aligned
    /// prompts, shared-prefix reuse, lazy page growth.
    Paged { page_size: usize, n_pages: usize },
}

/// One occupied slot: `valid` real tokens preceded by `pad` left-padding
/// entries (paged slots always have `pad == 0`). The next token writes at
/// logical row `pad + valid`. Paged slots also carry their block table,
/// which under lazy growth covers at least the written rows and at most
/// the full window: `ceil(depth / page_size) <= pages.len() <=
/// blocks_per_slot`.
#[derive(Debug, Clone, PartialEq, Eq)]
struct SlotState {
    valid: usize,
    pad: usize,
    /// Physical page of each logical block (empty under [`KvLayout::Arena`]).
    pages: Vec<u32>,
}

impl SlotState {
    fn depth(&self) -> usize {
        self.pad + self.valid
    }
}

/// A registered shareable prefix: the page-aligned token run plus the
/// pages holding it (each holding one registry refcount until eviction)
/// and its LRU stamp.
#[derive(Debug, Clone)]
struct PrefixEntry {
    /// The exact tokens, for equality verification on lookup — the hash
    /// routes, the tokens decide (collisions degrade to a miss, never to
    /// serving another request's cache).
    tokens: Vec<i32>,
    pages: Vec<u32>,
    /// Monotone LRU stamp: set at registration, refreshed on every
    /// admission hit (and on re-registration of the same tokens). The
    /// clock never repeats a value, so eviction order is total and
    /// deterministic: least-recently-touched first.
    touch: u64,
}

/// The outcome of a shared-prefix admission ([`PageLedger::alloc_shared`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitPlan {
    /// Cached tokens this admission mapped instead of recomputing-from-
    /// nothing: the page-aligned shared-prefix length on a registry hit,
    /// 0 on a miss. (The fixed-shape prefill still runs over the full
    /// window either way — this is the ledger-level reuse figure the serve
    /// bench reports as computed-vs-admitted savings.)
    pub reused_tokens: usize,
    /// Whether the prefix registry served this admission.
    pub prefix_hit: bool,
}

/// FNV-1a over a token run — the prefix registry key. Deterministic across
/// runs (reproducibility contract) and cheap enough for per-admission use.
fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Host-side occupancy/allocation state for a KV cache — everything except
/// the device buffers, so allocator invariants are unit-testable without a
/// device (see `rust/tests/failure_injection.rs`).
#[derive(Debug, Clone)]
pub struct PageLedger {
    layout: KvLayout,
    /// Logical window per slot (`seq_len` of the artifacts).
    smax: usize,
    slots: Vec<Option<SlotState>>,
    /// Allocatable pages (paged only; never contains page 0, never a page
    /// above `usable`).
    free: Vec<u32>,
    /// Per-page reference count: tables holding it + registry entries
    /// holding it (paged only; `refcount[0]` stays 0 — the garbage page is
    /// pointed at by *dead* rows only, which the ledger never records).
    refcount: Vec<u32>,
    /// Registered shareable prefixes by token hash.
    prefixes: BTreeMap<u64, PrefixEntry>,
    /// Highest allocatable page index: the allocator only ever hands out
    /// pages `1..=usable`. Defaults to `n_pages - 1` (the whole physical
    /// pool minus the garbage page); [`PageLedger::limit_pages`] lowers it
    /// to run the pool oversubscribed against the same device buffers.
    usable: usize,
    /// Monotone LRU clock (see [`PrefixEntry::touch`]).
    touch_clock: u64,
    /// Prefix-registry entries stolen (evicted) under pool pressure.
    evictions: u64,
    /// Pages actually reclaimed (refcount dropped to 0) by those steals.
    pages_stolen: u64,
    /// Registration attempts dropped because a DIFFERENT token run already
    /// owns the hash bucket (FNV collision). The colliding prefix simply
    /// never registers — admissions degrade to misses, never to another
    /// request's pages.
    collisions: u64,
    /// High-water mark of pages in use (drawn off the free list).
    peak_used: usize,
    /// Test-only hash override so a forced collision is constructible
    /// (real FNV collisions are impractical to find in a unit test).
    #[cfg(test)]
    hash_hook: Option<fn(&[i32]) -> u64>,
}

impl PageLedger {
    pub fn arena(n_slots: usize, smax: usize) -> PageLedger {
        PageLedger {
            layout: KvLayout::Arena,
            smax,
            slots: vec![None; n_slots],
            free: Vec::new(),
            refcount: Vec::new(),
            prefixes: BTreeMap::new(),
            usable: 0,
            touch_clock: 0,
            evictions: 0,
            pages_stolen: 0,
            collisions: 0,
            peak_used: 0,
            #[cfg(test)]
            hash_hook: None,
        }
    }

    pub fn paged(n_slots: usize, smax: usize, page_size: usize, n_pages: usize) -> PageLedger {
        assert!(page_size > 0 && smax % page_size == 0, "{smax} % {page_size}");
        // Free list starts as pages 1..n_pages (0 is the garbage page);
        // popped from the back, so allocation order is descending — any
        // order works, this one makes "first alloc gets the last page"
        // tests unambiguous.
        PageLedger {
            layout: KvLayout::Paged { page_size, n_pages },
            smax,
            slots: vec![None; n_slots],
            free: (1..n_pages as u32).collect(),
            refcount: vec![0; n_pages],
            prefixes: BTreeMap::new(),
            usable: n_pages - 1,
            touch_clock: 0,
            evictions: 0,
            pages_stolen: 0,
            collisions: 0,
            peak_used: 0,
            #[cfg(test)]
            hash_hook: None,
        }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    /// Logical blocks spanning one slot's full `[0, smax)` window — the
    /// block-table width the artifacts compile against. Lazy tables are
    /// shorter; uploads zero-pad to this width.
    pub fn blocks_per_slot(&self) -> usize {
        match self.layout {
            KvLayout::Arena => 0,
            KvLayout::Paged { page_size, .. } => self.smax / page_size,
        }
    }

    /// Pages needed to cover `rows` logical rows.
    fn pages_for(&self, rows: usize) -> usize {
        match self.layout {
            KvLayout::Arena => 0,
            KvLayout::Paged { page_size, .. } => rows.div_ceil(page_size),
        }
    }

    fn hash_of(&self, tokens: &[i32]) -> u64 {
        #[cfg(test)]
        if let Some(hook) = self.hash_hook {
            return hook(tokens);
        }
        prefix_hash(tokens)
    }

    /// Advance the LRU clock. Strictly monotone, so two entries never tie.
    fn tick(&mut self) -> u64 {
        self.touch_clock += 1;
        self.touch_clock
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// VALID (non-padding) tokens held by a slot (`None` if free).
    pub fn len_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.valid)
    }

    /// Left-padding entries preceding a slot's valid tokens (always 0 for
    /// paged slots).
    pub fn pad_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.pad)
    }

    /// Logical cache row the slot's next token writes at (`pad + valid`).
    pub fn depth_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.depth())
    }

    /// Valid tokens held across all occupied slots (padding never counted).
    pub fn valid_tokens(&self) -> usize {
        self.slots.iter().flatten().map(|o| o.valid).sum()
    }

    /// Lowest-numbered free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Pages currently allocatable (paged only; arena reports 0).
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages the allocator may hand out in total (`n_pages - 1` unless
    /// lowered by [`PageLedger::limit_pages`]).
    pub fn usable_pages(&self) -> usize {
        self.usable
    }

    /// Pages currently drawn off the free list (live tables + registry).
    pub fn used_pages(&self) -> usize {
        self.usable - self.free.len()
    }

    /// High-water mark of [`PageLedger::used_pages`].
    pub fn peak_used_pages(&self) -> usize {
        self.peak_used
    }

    /// Prefix-registry entries evicted (stolen) under pool pressure.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Pages reclaimed by those evictions.
    pub fn pages_stolen(&self) -> u64 {
        self.pages_stolen
    }

    /// Prefix registrations dropped on an FNV hash collision.
    pub fn collisions(&self) -> u64 {
        self.collisions
    }

    /// Registered shareable prefixes currently held.
    pub fn n_prefixes(&self) -> usize {
        self.prefixes.len()
    }

    /// A slot's block table row (paged slots only).
    pub fn block_table(&self, slot: usize) -> Option<&[u32]> {
        self.slots
            .get(slot)
            .and_then(|s| s.as_ref())
            .filter(|o| !o.pages.is_empty())
            .map(|o| o.pages.as_slice())
    }

    /// Cap the allocator at `n` pages (indices `1..=n`) so the pool runs
    /// OVERSUBSCRIBED: the device buffers keep their full physical extent
    /// (block tables stay valid indices), but admissions and page growth
    /// compete for fewer pages than `n_slots * blocks_per_slot`. Only
    /// legal on an idle pool (no live slots, no registered prefixes,
    /// nothing drawn) and `n` must still fit one full window — a single
    /// slot must always be able to run to `smax`.
    pub fn limit_pages(&mut self, n: usize) -> Result<()> {
        let KvLayout::Paged { n_pages, .. } = self.layout else {
            bail!("kv limit_pages: arena layout has no page pool");
        };
        if self.n_active() != 0 || !self.prefixes.is_empty() || self.free.len() != self.usable {
            bail!(
                "kv limit_pages: pool not idle ({} live slots, {} prefixes, {} of {} free)",
                self.n_active(),
                self.prefixes.len(),
                self.free.len(),
                self.usable
            );
        }
        if n < self.blocks_per_slot() || n > n_pages - 1 {
            bail!(
                "kv limit_pages: {n} pages outside [{}, {}] (one full window .. physical pool)",
                self.blocks_per_slot(),
                n_pages - 1
            );
        }
        self.usable = n;
        self.free = (1..=n as u32).collect();
        self.peak_used = 0;
        Ok(())
    }

    fn check_slot(&self, op: &str, slot: usize, valid: usize, pad: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv {op}: slot {slot} out of range ({} slots)", self.slots.len());
        }
        if let Some(held) = &self.slots[slot] {
            bail!("kv {op}: slot {slot} already holds {} tokens", held.valid);
        }
        if valid == 0 {
            bail!("kv {op}: slot {slot} allocated with zero valid tokens");
        }
        if valid + pad > self.smax {
            bail!("kv {op}: slot {slot} wants {valid}+{pad} entries, smax {}", self.smax);
        }
        Ok(())
    }

    /// Allocate one slot for a freshly prefilled sequence of `valid` real
    /// tokens preceded by `pad` left-padding entries. Arena slots only own
    /// their fixed row group; paged slots draw LAZILY — just the
    /// `ceil(valid / page_size)` pages the prompt writes (`pad` must be 0 —
    /// paged prompts are front-aligned); decode grows the table via
    /// [`PageLedger::reserve_rows`]. For shared-prefix admission use
    /// [`PageLedger::alloc_shared`].
    pub fn alloc(&mut self, slot: usize, valid: usize, pad: usize) -> Result<()> {
        self.check_slot("alloc", slot, valid, pad)?;
        let pages = match self.layout {
            KvLayout::Arena => Vec::new(),
            KvLayout::Paged { .. } => {
                if pad != 0 {
                    bail!("kv alloc: paged slots are front-aligned (pad {pad} != 0)");
                }
                self.take_pages(self.pages_for(valid))?
            }
        };
        self.slots[slot] = Some(SlotState { valid, pad, pages });
        Ok(())
    }

    /// Allocate every slot at once (the batch-generate path: one
    /// full-batch prefill fills all rows; `pads[i]` is row i's
    /// left-padding — all zeros for the exact-length path).
    pub fn alloc_all(&mut self, valids: &[usize], pads: &[usize]) -> Result<()> {
        assert_eq!(valids.len(), self.slots.len());
        assert_eq!(pads.len(), self.slots.len());
        for slot in 0..self.slots.len() {
            self.alloc(slot, valids[slot], pads[slot])?;
        }
        Ok(())
    }

    /// Paged shared-prefix admission: look the prompt's declared prefix up
    /// in the registry and map its pages instead of allocating them. The
    /// shared region is the PAGE-ALIGNED part of `prefix_len` (a prefix
    /// shorter than one page shares nothing); on a hit the registered
    /// tokens are compared for equality — the hash never decides alone —
    /// and the entry's LRU stamp is refreshed. Fresh pages cover the rest
    /// of the PROMPT (not the window: growth is lazy). Front-aligned, so
    /// decode writes land at logical rows `>= valid > shared region` and
    /// never touch a shared page; the full-window prefill re-writes shared
    /// pages with bit-identical values (same tokens, same logical
    /// positions), which is what makes the mapping copy-on-write-safe.
    pub fn alloc_shared(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prefix_len: usize,
    ) -> Result<AdmitPlan> {
        let KvLayout::Paged { page_size, .. } = self.layout else {
            bail!("kv alloc_shared: arena layout has no page sharing");
        };
        let valid = tokens.len();
        self.check_slot("alloc_shared", slot, valid, 0)?;
        let aligned = (prefix_len.min(valid) / page_size) * page_size;
        let mut shared: Vec<u32> = Vec::new();
        if aligned > 0 {
            let key = self.hash_of(&tokens[..aligned]);
            let stamp = self.tick();
            if let Some(entry) = self.prefixes.get_mut(&key) {
                if entry.tokens == tokens[..aligned] {
                    entry.touch = stamp;
                    shared = entry.pages.clone();
                }
            }
        }
        let hit = !shared.is_empty();
        // Pin the shared pages BEFORE drawing fresh ones: drawing may
        // evict registry entries (including the one we just matched), and
        // the pin keeps its pages off the free list while we hold them.
        for &p in &shared {
            self.refcount[p as usize] += 1;
        }
        let fresh = match self.take_pages(self.pages_for(valid) - shared.len()) {
            Ok(f) => f,
            Err(e) => {
                for &p in &shared {
                    self.unref_page(p);
                }
                return Err(e);
            }
        };
        let mut pages = shared;
        pages.extend(fresh);
        self.slots[slot] = Some(SlotState { valid, pad: 0, pages });
        Ok(AdmitPlan { reused_tokens: if hit { aligned } else { 0 }, prefix_hit: hit })
    }

    /// Whether a paged admission of `tokens` (with `prefix_len` declared
    /// shared) can draw its prompt pages right now, counting both the free
    /// list and every prefix the allocator could steal. Arena admissions
    /// always fit (fixed row groups). The scheduler asks this BEFORE
    /// prefilling so a full pool defers the admission instead of burning a
    /// prefill fault (and a quarantine strike) on it.
    pub fn can_admit(&self, tokens: &[i32], prefix_len: usize) -> bool {
        let KvLayout::Paged { n_pages, page_size } = self.layout else {
            return true;
        };
        let valid = tokens.len();
        let aligned = (prefix_len.min(valid) / page_size) * page_size;
        let mut shared_pages: &[u32] = &[];
        if aligned > 0 {
            let key = self.hash_of(&tokens[..aligned]);
            if let Some(entry) = self.prefixes.get(&key) {
                if entry.tokens == tokens[..aligned] {
                    shared_pages = &entry.pages;
                }
            }
        }
        let needed = self.pages_for(valid).saturating_sub(shared_pages.len());
        if needed <= self.free.len() {
            return true;
        }
        // Count pages eviction could reclaim: pages whose every reference
        // is a registry entry's. Pages of the prefix we would map are
        // excluded — alloc_shared pins them first, so evicting that entry
        // frees nothing.
        let mut table_refs = vec![0u32; n_pages];
        for s in self.slots.iter().flatten() {
            for &p in &s.pages {
                table_refs[p as usize] += 1;
            }
        }
        for &p in shared_pages {
            table_refs[p as usize] += 1;
        }
        let evictable = (1..n_pages)
            .filter(|&p| self.refcount[p] > 0 && table_refs[p] == 0)
            .count();
        needed <= self.free.len() + evictable
    }

    /// Grow `slot`'s block table to cover its next `n` written rows
    /// (clamped to the window) — call BEFORE dispatching a decode that
    /// writes those rows, because the artifact scatters K/V through the
    /// table as uploaded. `Ok(true)`: covered (possibly without drawing —
    /// the depth may sit mid-page). `Ok(false)`: the pool is exhausted
    /// even after LRU eviction — the caller must preempt (retire + requeue)
    /// the slot rather than dispatch. `Err`: the slot is free or out of
    /// range (a scheduling bug, not a capacity condition).
    pub fn reserve_rows(&mut self, slot: usize, n: usize) -> Result<bool> {
        if !matches!(self.layout, KvLayout::Paged { .. }) {
            return Ok(true);
        }
        let Some(occ) = self.slots.get(slot).and_then(|s| s.as_ref()) else {
            bail!("kv reserve_rows: slot {slot} is free or out of range");
        };
        let target = (occ.depth() + n).min(self.smax);
        let need = self.pages_for(target);
        let have = occ.pages.len();
        if need <= have {
            return Ok(true);
        }
        match self.try_take_pages(need - have) {
            Some(fresh) => {
                self.slots[slot].as_mut().unwrap().pages.extend(fresh);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Register a successfully prefilled slot's page-aligned prefix for
    /// reuse by later admissions. Call AFTER the prefill artifact
    /// succeeded — registering first would hand pages holding garbage to
    /// the next request on a prefill fault. No-op when the aligned prefix
    /// is empty; re-registering the SAME tokens just refreshes the LRU
    /// stamp; a hash bucket held by DIFFERENT tokens is an FNV collision —
    /// counted, and the new prefix stays unregistered (its admissions
    /// degrade to registry misses).
    pub fn register_prefix(&mut self, slot: usize, prefix_len: usize, tokens: &[i32]) -> Result<()> {
        let KvLayout::Paged { page_size, .. } = self.layout else {
            bail!("kv register_prefix: arena layout has no page sharing");
        };
        let Some(state) = self.slots.get(slot).and_then(|s| s.as_ref()) else {
            bail!("kv register_prefix: slot {slot} is free");
        };
        let aligned = (prefix_len.min(state.valid).min(tokens.len()) / page_size) * page_size;
        if aligned == 0 {
            return Ok(());
        }
        let pages: Vec<u32> = state.pages[..aligned / page_size].to_vec();
        let key = self.hash_of(&tokens[..aligned]);
        let stamp = self.tick();
        if let Some(entry) = self.prefixes.get_mut(&key) {
            if entry.tokens == tokens[..aligned] {
                entry.touch = stamp;
            } else {
                self.collisions += 1;
            }
            return Ok(());
        }
        for &p in &pages {
            self.refcount[p as usize] += 1;
        }
        self.prefixes.insert(
            key,
            PrefixEntry { tokens: tokens[..aligned].to_vec(), pages, touch: stamp },
        );
        Ok(())
    }

    /// Pop `n` pages off the free list (each handed out with refcount 1),
    /// evicting registered prefixes in LRU order if the list runs short.
    /// `None` when the pool is exhausted even with the registry drained —
    /// the capacity signal [`PageLedger::reserve_rows`] turns into a
    /// preemption. Evictions performed before hitting the wall stick
    /// (they were legitimate steals; the freed pages serve the next draw).
    fn try_take_pages(&mut self, n: usize) -> Option<Vec<u32>> {
        while self.free.len() < n {
            if !self.evict_lru() {
                return None;
            }
        }
        let taken = self.free.split_off(self.free.len() - n);
        for &p in &taken {
            debug_assert_eq!(self.refcount[p as usize], 0, "free page {p} had references");
            self.refcount[p as usize] = 1;
        }
        self.peak_used = self.peak_used.max(self.used_pages());
        Some(taken)
    }

    /// [`PageLedger::try_take_pages`] for admission paths, where running
    /// out is an error. The diagnostic distinguishes a POOL FULLY LIVE
    /// condition (every drawn page is accounted for by live block tables
    /// or the registry — retire or preempt something) from a genuine
    /// refcount leak (references and refcounts disagree — an allocator
    /// bug).
    fn take_pages(&mut self, n: usize) -> Result<Vec<u32>> {
        if let Some(taken) = self.try_take_pages(n) {
            return Ok(taken);
        }
        let KvLayout::Paged { n_pages, .. } = self.layout else { unreachable!() };
        let mut want = vec![0u32; n_pages];
        let mut table_pages = 0usize;
        for s in self.slots.iter().flatten() {
            for &p in &s.pages {
                if want[p as usize] == 0 {
                    table_pages += 1;
                }
                want[p as usize] += 1;
            }
        }
        let mut registry_pages = 0usize;
        for e in self.prefixes.values() {
            for &p in &e.pages {
                if want[p as usize] == 0 {
                    registry_pages += 1;
                }
                want[p as usize] += 1;
            }
        }
        if want == self.refcount {
            bail!(
                "kv alloc: need {n} pages but only {} free — pool fully live \
                 ({table_pages} pages in live block tables, {registry_pages} registry-only, \
                 {} allocatable); retire or preempt a slot",
                self.free.len(),
                self.usable
            );
        }
        bail!(
            "kv alloc: need {n} pages, {} free, and refcounts disagree with live references \
             (page leak?): refcount {:?} != references {:?}",
            self.free.len(),
            self.refcount,
            want
        );
    }

    /// Evict the least-recently-touched registry entry. Returns false when
    /// the registry is empty.
    fn evict_lru(&mut self) -> bool {
        let Some(key) = self
            .prefixes
            .iter()
            .min_by_key(|(_, e)| e.touch)
            .map(|(&k, _)| k)
        else {
            return false;
        };
        let reclaimed = self.evict_prefix(key);
        self.evictions += 1;
        self.pages_stolen += reclaimed as u64;
        true
    }

    /// Drop a registry entry, returning how many of its pages actually
    /// came free (pages still mapped by live tables stay allocated).
    fn evict_prefix(&mut self, key: u64) -> usize {
        let Some(entry) = self.prefixes.remove(&key) else {
            return 0;
        };
        let before = self.free.len();
        for &p in &entry.pages {
            self.unref_page(p);
        }
        self.free.len() - before
    }

    fn unref_page(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "unref of page {page} with refcount 0");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    /// Record one decoded token appended to every slot where `active`.
    /// `fed_pos[slot]` is the logical cache row the token was written to;
    /// it must equal the slot's current depth `pad + valid` (the scheduler
    /// and the device cache advancing in lockstep is the core serving
    /// invariant), and under lazy growth the slot's block table must
    /// already cover that row ([`PageLedger::reserve_rows`] runs before
    /// dispatch; writing through an unreserved row went to another slot's
    /// page or the garbage page).
    pub fn advance(&mut self, active: &[bool], fed_pos: &[i32]) -> Result<()> {
        if active.len() != self.slots.len() || fed_pos.len() != self.slots.len() {
            bail!(
                "kv advance: active/pos length {}/{} != {} slots",
                active.len(),
                fed_pos.len(),
                self.slots.len()
            );
        }
        for slot in 0..self.slots.len() {
            if !active[slot] {
                continue;
            }
            let paged = matches!(self.layout, KvLayout::Paged { .. });
            let covered = self.pages_for(self.depth_of(slot).unwrap_or(0) + 1);
            let Some(occ) = self.slots[slot].as_mut() else {
                bail!("kv advance: slot {slot} is free but marked active");
            };
            if fed_pos[slot] as usize != occ.depth() {
                bail!(
                    "kv advance: slot {slot} fed at pos {} but its depth is {} \
                     ({} valid + {} pad)",
                    fed_pos[slot],
                    occ.depth(),
                    occ.valid,
                    occ.pad
                );
            }
            if occ.depth() + 1 > self.smax {
                bail!("kv advance: slot {slot} overflows smax {}", self.smax);
            }
            if paged && occ.pages.len() < covered {
                bail!(
                    "kv advance: slot {slot} wrote row {} with only {} pages reserved \
                     (reserve_rows must run before dispatch)",
                    occ.depth(),
                    occ.pages.len()
                );
            }
            occ.valid += 1;
        }
        Ok(())
    }

    /// Record `n` tokens appended to ONE slot by a fused decode chunk:
    /// the first was written at `fed_pos` (which must equal the slot's
    /// depth, exactly as in [`PageLedger::advance`]) and the rest at the
    /// following rows. Equivalent to `n` single-token advances — the
    /// chunk artifact writes every accepted token's K/V row in its
    /// unrolled loop, so the ledger catches up in one call. `n == 0` is a
    /// no-op (a zero-quota or instantly-latched row wrote nothing). The
    /// slot's table must already cover all `n` rows (reserved before
    /// dispatch).
    pub fn advance_chunk(&mut self, slot: usize, fed_pos: i32, n: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv advance_chunk: slot {slot} out of range ({} slots)", self.slots.len());
        }
        if n == 0 {
            return Ok(());
        }
        let paged = matches!(self.layout, KvLayout::Paged { .. });
        let covered = self.pages_for(self.depth_of(slot).unwrap_or(0) + n);
        let Some(occ) = self.slots[slot].as_mut() else {
            bail!("kv advance_chunk: slot {slot} is free");
        };
        if fed_pos as usize != occ.depth() {
            bail!(
                "kv advance_chunk: slot {slot} fed at pos {fed_pos} but its depth is {} \
                 ({} valid + {} pad)",
                occ.depth(),
                occ.valid,
                occ.pad
            );
        }
        if occ.depth() + n > self.smax {
            bail!(
                "kv advance_chunk: slot {slot} advancing {n} tokens overflows smax {}",
                self.smax
            );
        }
        if paged && occ.pages.len() < covered {
            bail!(
                "kv advance_chunk: slot {slot} wrote rows {}..{} with only {} pages reserved \
                 (reserve_rows must run before dispatch)",
                occ.depth(),
                occ.depth() + n,
                occ.pages.len()
            );
        }
        occ.valid += n;
        Ok(())
    }

    /// Record one decoded token appended to every slot (the ARENA batch-
    /// generate path only: fixed row groups, no pages to grow — paged
    /// serving advances via [`PageLedger::advance`] / `advance_chunk`).
    pub fn advance_all(&mut self) {
        debug_assert!(
            matches!(self.layout, KvLayout::Arena),
            "advance_all is the arena generate path; paged slots advance per-slot"
        );
        for s in self.slots.iter_mut().flatten() {
            s.valid += 1;
        }
    }

    /// Retire a sequence: arena rows become dead; paged pages drop one
    /// reference each, returning to the free list unless a registered
    /// prefix (or another slot's table) still holds them.
    pub fn free(&mut self, slot: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv free: slot {slot} out of range ({} slots)", self.slots.len());
        }
        let Some(state) = self.slots[slot].take() else {
            bail!("kv free: slot {slot} is already free");
        };
        for &p in &state.pages {
            self.unref_page(p);
        }
        Ok(())
    }

    /// Allocator consistency check, for tests and debug assertions:
    /// every page's refcount equals the number of tables + registry
    /// entries holding it, the free list is exactly the refcount-0 pages
    /// within the usable range (minus the garbage page), no page is listed
    /// twice, nothing above the usable cap is ever referenced, and every
    /// live paged slot's table covers its written rows without exceeding
    /// the window.
    pub fn check_invariants(&self) -> Result<()> {
        let KvLayout::Paged { n_pages, .. } = self.layout else {
            return Ok(());
        };
        let mut want = vec![0u32; n_pages];
        for (i, s) in self.slots.iter().enumerate() {
            let Some(s) = s else { continue };
            for &p in &s.pages {
                want[p as usize] += 1;
            }
            if s.pages.len() < self.pages_for(s.depth()) {
                bail!(
                    "kv invariant: slot {i} holds {} rows on {} pages",
                    s.depth(),
                    s.pages.len()
                );
            }
            if s.pages.len() > self.blocks_per_slot() {
                bail!(
                    "kv invariant: slot {i} table has {} blocks, window holds {}",
                    s.pages.len(),
                    self.blocks_per_slot()
                );
            }
        }
        for e in self.prefixes.values() {
            for &p in &e.pages {
                want[p as usize] += 1;
            }
        }
        if want[0] != 0 {
            bail!("kv invariant: garbage page 0 is referenced {} times", want[0]);
        }
        if self.refcount != want {
            bail!("kv invariant: refcounts {:?} != references {:?}", self.refcount, want);
        }
        for p in self.usable + 1..n_pages {
            if self.refcount[p] != 0 {
                bail!("kv invariant: page {p} above the usable cap {} is referenced", self.usable);
            }
        }
        let mut seen = vec![false; n_pages];
        for &p in &self.free {
            if p == 0 {
                bail!("kv invariant: garbage page 0 on the free list");
            }
            if p as usize > self.usable {
                bail!("kv invariant: page {p} above the usable cap {} is free-listed", self.usable);
            }
            if seen[p as usize] {
                bail!("kv invariant: page {p} on the free list twice");
            }
            seen[p as usize] = true;
            if self.refcount[p as usize] != 0 {
                bail!("kv invariant: free page {p} has refcount {}", self.refcount[p as usize]);
            }
        }
        let free_should = (1..=self.usable).filter(|&p| self.refcount[p] == 0).count();
        if self.free.len() != free_should {
            bail!(
                "kv invariant: {} pages free but {} have refcount 0",
                self.free.len(),
                free_should
            );
        }
        Ok(())
    }
}

/// The device buffers plus their [`PageLedger`].
pub struct KvCache {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
    /// Arena: `[n_layers, b*h, smax, d_head]`;
    /// paged: `[n_layers, n_heads, n_pages * page_size, d_head]`.
    pub dims: Vec<usize>,
    pub ledger: PageLedger,
}

impl KvCache {
    /// The arena cache shape the AOT artifacts compile against
    /// (`python/compile/aot.py`: `(n_layers, batch*n_heads, seq_len, d_head)`).
    pub fn dims_for(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.batch * m.actor.n_heads,
            m.seq_len,
            m.actor.d_head(),
        ]
    }

    /// The block-paged pool shape of the `*_paged` artifacts
    /// (`(n_layers, n_heads, kv_pages * page_size, d_head)`).
    pub fn dims_for_paged(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.actor.n_heads,
            m.kv_pages * m.page_size,
            m.actor.d_head(),
        ]
    }

    /// Arena-cache bytes for a manifest's shape (usable before a cache
    /// exists; [`KvCache::bytes`] reports the live figure either way).
    pub fn bytes_for(m: &Manifest) -> usize {
        2 * Self::dims_for(m).iter().product::<usize>() * 4
    }

    /// Adopt freshly produced device buffers as the live ARENA cache, with
    /// all `n_slots` batch slots initially free.
    pub fn arena(k: PjRtBuffer, v: PjRtBuffer, dims: Vec<usize>, n_slots: usize) -> KvCache {
        let smax = dims[2];
        KvCache { k, v, dims, ledger: PageLedger::arena(n_slots, smax) }
    }

    /// Adopt freshly produced device buffers as the live BLOCK-PAGED pool
    /// (`smax` is the logical per-slot window, NOT the pool length).
    pub fn paged(
        k: PjRtBuffer,
        v: PjRtBuffer,
        dims: Vec<usize>,
        n_slots: usize,
        smax: usize,
        page_size: usize,
        n_pages: usize,
    ) -> KvCache {
        KvCache { k, v, dims, ledger: PageLedger::paged(n_slots, smax, page_size, n_pages) }
    }

    /// Swap in the decode step's output buffers (zero-copy: the previous
    /// generation's buffers are dropped, freeing their device memory).
    pub fn update(&mut self, k: PjRtBuffer, v: PjRtBuffer) {
        self.k = k;
        self.v = v;
    }

    /// Bytes held by both caches (f32).
    pub fn bytes(&self) -> usize {
        2 * self.dims.iter().product::<usize>() * 4
    }

    // ------------------------------------------------------------------
    // Ledger forwards (serving / continuous batching)
    // ------------------------------------------------------------------

    pub fn layout(&self) -> KvLayout {
        self.ledger.layout()
    }

    pub fn n_slots(&self) -> usize {
        self.ledger.n_slots()
    }

    pub fn n_active(&self) -> usize {
        self.ledger.n_active()
    }

    pub fn len_of(&self, slot: usize) -> Option<usize> {
        self.ledger.len_of(slot)
    }

    pub fn pad_of(&self, slot: usize) -> Option<usize> {
        self.ledger.pad_of(slot)
    }

    pub fn depth_of(&self, slot: usize) -> Option<usize> {
        self.ledger.depth_of(slot)
    }

    pub fn valid_tokens(&self) -> usize {
        self.ledger.valid_tokens()
    }

    pub fn first_free(&self) -> Option<usize> {
        self.ledger.first_free()
    }

    pub fn block_table(&self, slot: usize) -> Option<&[u32]> {
        self.ledger.block_table(slot)
    }

    pub fn alloc(&mut self, slot: usize, valid: usize, pad: usize) -> Result<()> {
        self.ledger.alloc(slot, valid, pad)
    }

    pub fn alloc_all(&mut self, valids: &[usize], pads: &[usize]) -> Result<()> {
        self.ledger.alloc_all(valids, pads)
    }

    pub fn alloc_shared(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prefix_len: usize,
    ) -> Result<AdmitPlan> {
        self.ledger.alloc_shared(slot, tokens, prefix_len)
    }

    pub fn can_admit(&self, tokens: &[i32], prefix_len: usize) -> bool {
        self.ledger.can_admit(tokens, prefix_len)
    }

    pub fn reserve_rows(&mut self, slot: usize, n: usize) -> Result<bool> {
        self.ledger.reserve_rows(slot, n)
    }

    pub fn register_prefix(&mut self, slot: usize, prefix_len: usize, tokens: &[i32]) -> Result<()> {
        self.ledger.register_prefix(slot, prefix_len, tokens)
    }

    pub fn advance(&mut self, active: &[bool], fed_pos: &[i32]) -> Result<()> {
        self.ledger.advance(active, fed_pos)
    }

    pub fn advance_chunk(&mut self, slot: usize, fed_pos: i32, n: usize) -> Result<()> {
        self.ledger.advance_chunk(slot, fed_pos, n)
    }

    pub fn advance_all(&mut self) {
        self.ledger.advance_all()
    }

    pub fn free(&mut self, slot: usize) -> Result<()> {
        self.ledger.free(slot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMAX: usize = 16;
    const PS: usize = 4;
    const MB: usize = SMAX / PS; // 4 blocks per slot
    const SLOTS: usize = 2;
    const PAGES: usize = (SLOTS + 1) * MB + 1; // 13: both slots + spare + garbage

    fn ledger() -> PageLedger {
        PageLedger::paged(SLOTS, SMAX, PS, PAGES)
    }

    #[test]
    fn arena_ledger_matches_legacy_occupancy_semantics() {
        let mut l = PageLedger::arena(2, SMAX);
        assert_eq!(l.first_free(), Some(0));
        l.alloc(0, 5, 3).unwrap();
        assert_eq!(l.len_of(0), Some(5));
        assert_eq!(l.pad_of(0), Some(3));
        assert_eq!(l.depth_of(0), Some(8));
        assert_eq!(l.first_free(), Some(1));
        assert!(l.alloc(0, 1, 0).is_err(), "double alloc");
        assert!(l.alloc(1, 0, 0).is_err(), "zero valid");
        assert!(l.alloc(1, SMAX, 1).is_err(), "overflow");
        l.advance(&[true, false], &[8, 0]).unwrap();
        assert_eq!(l.depth_of(0), Some(9));
        assert!(l.advance(&[true, false], &[8, 0]).is_err(), "stale pos");
        assert!(l.advance(&[false, true], &[0, 0]).is_err(), "free but active");
        assert_eq!(l.valid_tokens(), 6);
        l.free(0).unwrap();
        assert!(l.free(0).is_err(), "double free");
        assert_eq!(l.n_active(), 0);
    }

    #[test]
    fn chunk_advance_equals_repeated_single_advances() {
        let mut chunked = ledger();
        let mut stepped = ledger();
        for l in [&mut chunked, &mut stepped] {
            l.alloc_shared(0, &[1, 2, 3], 0).unwrap();
        }
        // Lazy growth: the 3-token prompt drew one page; the chunk's 4
        // writes reach row 6, so the table must be grown BEFORE advancing.
        assert!(chunked.reserve_rows(0, 4).unwrap());
        chunked.advance_chunk(0, 3, 4).unwrap();
        for d in 0..4 {
            assert!(stepped.reserve_rows(0, 1).unwrap());
            stepped.advance(&[true, false], &[3 + d, 0]).unwrap();
        }
        assert_eq!(chunked.depth_of(0), stepped.depth_of(0));
        assert_eq!(chunked.depth_of(0), Some(7));
        assert_eq!(
            chunked.block_table(0).unwrap().len(),
            stepped.block_table(0).unwrap().len(),
            "chunked and stepwise growth draw the same page count"
        );
        chunked.check_invariants().unwrap();
        stepped.check_invariants().unwrap();
        // Same failure contracts as the stepwise path: stale fed position,
        // smax overflow, free slot, and advancing past the reservation.
        assert!(chunked.advance_chunk(0, 3, 1).is_err(), "stale pos");
        assert!(chunked.advance_chunk(0, 7, SMAX).is_err(), "overflow");
        assert!(chunked.advance_chunk(1, 0, 1).is_err(), "free slot");
        assert!(chunked.reserve_rows(0, SMAX - 7).unwrap());
        chunked.advance_chunk(0, 7, SMAX - 7).unwrap();
        assert_eq!(chunked.depth_of(0), Some(SMAX));
        // advance_chunk(n=0) is a no-op (a zero-quota chunk row).
        let depth = stepped.depth_of(0);
        stepped.advance_chunk(0, 99, 0).unwrap();
        assert_eq!(stepped.depth_of(0), depth, "n == 0 advances nothing");
    }

    #[test]
    fn paged_alloc_draws_prompt_pages_lazily() {
        let mut l = ledger();
        assert_eq!(l.free_pages(), PAGES - 1, "page 0 reserved");
        assert_eq!(l.usable_pages(), PAGES - 1);
        // 6 tokens cover 2 pages — not the full MB-page window.
        l.alloc(0, 6, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 2);
        assert_eq!(l.used_pages(), 2);
        let table: Vec<u32> = l.block_table(0).unwrap().to_vec();
        assert_eq!(table.len(), 2, "lazy: ceil(6/4) pages, not blocks_per_slot");
        assert!(!table.contains(&0), "garbage page never allocated");
        assert!(l.alloc(1, 4, 2).is_err(), "paged slots are front-aligned");
        l.alloc(1, 4, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 3);
        assert_eq!(l.peak_used_pages(), 3);
        l.free(0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 1, "slot 0's pages returned");
        // The freed pages are allocatable again.
        l.alloc(0, 2, 0).unwrap();
        l.check_invariants().unwrap();
        for &p in l.block_table(0).unwrap() {
            assert!(table.contains(&p), "reused the returned pages");
        }
        assert_eq!(l.peak_used_pages(), 3, "peak is a high-water mark");
    }

    #[test]
    fn reserve_rows_grows_across_page_boundaries_only() {
        let mut l = ledger();
        l.alloc(0, 6, 0).unwrap(); // 2 pages cover rows 0..8
        assert_eq!(l.block_table(0).unwrap().len(), 2);
        // Rows 6 and 7 sit inside the reservation: no draw.
        assert!(l.reserve_rows(0, 1).unwrap());
        assert_eq!(l.block_table(0).unwrap().len(), 2);
        l.advance(&[true, false], &[6, 0]).unwrap();
        assert!(l.reserve_rows(0, 1).unwrap());
        l.advance(&[true, false], &[7, 0]).unwrap();
        // Row 8 crosses into page 3.
        assert!(l.reserve_rows(0, 1).unwrap());
        assert_eq!(l.block_table(0).unwrap().len(), 3);
        l.check_invariants().unwrap();
        l.advance(&[true, false], &[8, 0]).unwrap();
        // A chunk reservation clamps at the window and never overshoots.
        assert!(l.reserve_rows(0, SMAX).unwrap());
        assert_eq!(l.block_table(0).unwrap().len(), MB);
        l.check_invariants().unwrap();
        assert!(l.reserve_rows(1, 1).is_err(), "free slot is a bug, not capacity");
    }

    #[test]
    fn advance_without_reservation_is_rejected() {
        let mut l = ledger();
        l.alloc(0, 4, 0).unwrap(); // exactly one page: rows 0..4
        let err = l.advance(&[true, false], &[4, 0]).unwrap_err().to_string();
        assert!(err.contains("reserve_rows"), "{err}");
        assert_eq!(l.depth_of(0), Some(4), "failed advance must not move depth");
        let err = l.advance_chunk(0, 4, 2).unwrap_err().to_string();
        assert!(err.contains("reserve_rows"), "{err}");
        assert!(l.reserve_rows(0, 2).unwrap());
        l.advance_chunk(0, 4, 2).unwrap();
        l.check_invariants().unwrap();
    }

    #[test]
    fn exhausted_reserve_signals_preemption_not_error() {
        // Pool of 2 allocatable pages on a 1-page-per-prompt workload:
        // both slots admit, then the first slot to cross a page boundary
        // takes the... nothing — there is no third page. reserve_rows says
        // Ok(false): preempt, don't crash. Freeing the other slot makes
        // the same reservation succeed.
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, 3);
        l.alloc(0, 4, 0).unwrap();
        l.alloc(1, 4, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), 0);
        assert!(!l.reserve_rows(0, 1).unwrap(), "pool exhausted: preempt");
        l.check_invariants().unwrap();
        l.free(1).unwrap();
        assert!(l.reserve_rows(0, 1).unwrap(), "freed pages serve the retry");
        l.check_invariants().unwrap();
    }

    #[test]
    fn shared_prefix_hit_maps_registered_pages() {
        let mut l = ledger();
        // 6-token prompt with a declared 5-token prefix: page-aligned
        // shared region is one page (4 tokens).
        let prompt: Vec<i32> = (10..16).collect();
        let plan = l.alloc_shared(0, &prompt, 5).unwrap();
        assert_eq!(plan, AdmitPlan { reused_tokens: 0, prefix_hit: false }, "cold registry");
        l.register_prefix(0, 5, &prompt).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.n_prefixes(), 1);
        let prefix_page = l.block_table(0).unwrap()[0];

        // Same prefix, different tail: the aligned page is mapped shared.
        let mut other = prompt.clone();
        other[5] = 99;
        let plan = l.alloc_shared(1, &other, 5).unwrap();
        assert_eq!(plan, AdmitPlan { reused_tokens: PS, prefix_hit: true });
        l.check_invariants().unwrap();
        assert_eq!(l.block_table(1).unwrap()[0], prefix_page, "page shared");
        // Two 6-token prompts cover 2 pages each, one of them shared:
        // only 3 distinct pages drawn.
        assert_eq!(l.free_pages(), PAGES - 1 - 3);

        // DIFFERENT prefix tokens miss even at the same declared length.
        l.free(1).unwrap();
        let unrelated: Vec<i32> = (50..56).collect();
        let plan = l.alloc_shared(1, &unrelated, 5).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }

    #[test]
    fn shared_pages_survive_owner_retirement() {
        let mut l = ledger();
        let prompt: Vec<i32> = (0..8).collect();
        l.alloc_shared(0, &prompt, 8).unwrap();
        l.register_prefix(0, 8, &prompt).unwrap();
        let shared: Vec<u32> = l.block_table(0).unwrap()[..2].to_vec();
        // Owner retires; the registered prefix keeps its 2 pages warm.
        l.free(0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 2);
        // A later admission still hits.
        let plan = l.alloc_shared(1, &prompt, 8).unwrap();
        assert_eq!(plan.reused_tokens, 8);
        assert_eq!(&l.block_table(1).unwrap()[..2], &shared[..]);
        l.check_invariants().unwrap();
    }

    #[test]
    fn prefix_shorter_than_a_page_shares_nothing() {
        let mut l = ledger();
        let prompt: Vec<i32> = (0..8).collect();
        l.alloc_shared(0, &prompt, PS - 1).unwrap();
        l.register_prefix(0, PS - 1, &prompt).unwrap();
        assert_eq!(l.n_prefixes(), 0, "sub-page prefix not registrable");
        let plan = l.alloc_shared(1, &prompt, PS - 1).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }

    #[test]
    fn eviction_reclaims_orphan_prefix_pages_under_pool_pressure() {
        // Tight pool: 2*MB allocatable pages. A full-window orphan prefix
        // (owner retired) then makes a second full-window admission
        // impossible without eviction.
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, 2 * MB + 1);
        let prompt: Vec<i32> = (0..SMAX as i32).collect();
        l.alloc_shared(0, &prompt, SMAX).unwrap();
        l.register_prefix(0, SMAX, &prompt).unwrap();
        l.free(0).unwrap(); // orphan: MB pages held by the registry alone
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), MB);
        assert_eq!(l.n_prefixes(), 1);

        let full: Vec<i32> = (100..100 + SMAX as i32).collect();
        l.alloc_shared(0, &full, 0).unwrap(); // takes the whole free list
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), 0);
        assert_eq!(l.n_prefixes(), 1, "orphan still warm while pages last");

        // Second admission finds the free list empty: the allocator must
        // evict the orphan prefix, reclaim its pages, and succeed.
        l.alloc(1, 4, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.n_prefixes(), 0, "orphan evicted under pool pressure");
        assert_eq!(l.evictions(), 1);
        assert_eq!(l.pages_stolen(), MB as u64, "all orphan pages reclaimed");
        assert_eq!(l.free_pages(), MB - 1, "stolen pages minus the one drawn");
    }

    #[test]
    fn lru_evicts_least_recently_touched_prefix_first() {
        // Three one-page orphan prefixes on a 3-page pool, registered in
        // order A, B, C — then A is touched by an admission hit, making B
        // the LRU entry. Pool pressure must steal B first, keep A and C.
        let mk = || {
            let mut l = PageLedger::paged(SLOTS, SMAX, PS, 4);
            for i in 0..3i32 {
                let toks: Vec<i32> = (i * 100..i * 100 + PS as i32).collect();
                l.alloc(0, PS, 0).unwrap();
                // alloc() registers nothing; re-admit via register path.
                l.register_prefix(0, PS, &toks).unwrap();
                l.free(0).unwrap();
            }
            l.check_invariants().unwrap();
            assert_eq!(l.n_prefixes(), 3);
            assert_eq!(l.free_pages(), 0);
            // Touch A: an admission hit refreshes its LRU stamp.
            let a: Vec<i32> = (0..PS as i32).collect();
            let plan = l.alloc_shared(0, &a, PS).unwrap();
            assert!(plan.prefix_hit);
            l.free(0).unwrap();
            l
        };
        let mut l = mk();
        // One fresh page forces exactly one eviction: B (least recent).
        let fresh: Vec<i32> = (900..900 + PS as i32).collect();
        l.alloc_shared(0, &fresh, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.n_prefixes(), 2);
        assert_eq!(l.evictions(), 1);
        let b: Vec<i32> = (100..100 + PS as i32).collect();
        let c: Vec<i32> = (200..200 + PS as i32).collect();
        l.free(0).unwrap();
        assert!(!l.alloc_shared(0, &b, PS).unwrap().prefix_hit, "B was the LRU victim");
        l.free(0).unwrap();
        assert!(l.alloc_shared(0, &c, PS).unwrap().prefix_hit, "C survived");
        l.free(0).unwrap();
        let a: Vec<i32> = (0..PS as i32).collect();
        assert!(l.alloc_shared(0, &a, PS).unwrap().prefix_hit, "A survived (touched)");

        // Determinism: the same op sequence on a fresh ledger evicts the
        // same victim and leaves identical allocator state.
        let mut m = mk();
        m.alloc_shared(0, &fresh, 0).unwrap();
        m.free(0).unwrap();
        assert!(!m.alloc_shared(0, &b, PS).unwrap().prefix_hit, "same victim both runs");
        m.free(0).unwrap();
        assert!(m.alloc_shared(0, &c, PS).unwrap().prefix_hit, "same survivors both runs");
    }

    #[test]
    fn exhausted_pool_distinguishes_fully_live_from_leak() {
        // Pool holds one full window only: a second full-window admission
        // has no free pages and nothing to evict. That is NOT a leak —
        // every page is pinned by a live block table — and the diagnostic
        // must say so (the leak wording is reserved for refcount
        // disagreement, an actual allocator bug).
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, MB + 1);
        l.alloc(0, SMAX, 0).unwrap();
        let err = l.alloc(1, 4, 0).unwrap_err().to_string();
        assert!(err.contains("pool fully live"), "{err}");
        assert!(err.contains("live block tables"), "{err}");
        assert!(!err.contains("leak"), "live-pool exhaustion is not a leak: {err}");
        // The failed alloc must not have touched slot state.
        assert_eq!(l.len_of(1), None);
        l.check_invariants().unwrap();
    }

    #[test]
    fn limit_pages_caps_the_allocator_not_the_buffers() {
        let mut l = ledger();
        // Not idle -> refused.
        l.alloc(0, 4, 0).unwrap();
        assert!(l.limit_pages(MB).is_err(), "live slot blocks the cap");
        l.free(0).unwrap();
        // Below one window or above the physical pool -> refused.
        assert!(l.limit_pages(MB - 1).is_err());
        assert!(l.limit_pages(PAGES).is_err());
        // 6 pages on a 2-slot, 4-blocks-per-slot engine: oversubscribed
        // (full reservation would need 8).
        l.limit_pages(6).unwrap();
        assert_eq!(l.usable_pages(), 6);
        assert_eq!(l.free_pages(), 6);
        l.alloc(0, 8, 0).unwrap(); // 2 pages
        l.alloc(1, 8, 0).unwrap(); // 2 pages
        l.check_invariants().unwrap();
        assert_eq!(l.used_pages(), 4);
        // Both slots can still grow one page each...
        assert!(l.reserve_rows(0, PS + 1).unwrap());
        assert!(l.reserve_rows(1, PS + 1).unwrap());
        l.check_invariants().unwrap();
        assert_eq!(l.used_pages(), 6);
        // ...but the next boundary crossing preempts.
        l.advance_chunk(0, 8, PS).unwrap();
        assert!(!l.reserve_rows(0, 1).unwrap(), "oversubscription bites");
        // No page above the cap was ever drawn.
        for s in 0..SLOTS {
            for &p in l.block_table(s).unwrap() {
                assert!(p as usize <= 6, "page {p} above the cap");
            }
        }
        l.check_invariants().unwrap();
    }

    #[test]
    fn can_admit_predicts_admission_capacity() {
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, MB + 2); // 5 usable
        let prompt: Vec<i32> = (0..SMAX as i32).collect();
        assert!(l.can_admit(&prompt, 0));
        l.alloc_shared(0, &prompt, SMAX).unwrap(); // 4 pages
        l.register_prefix(0, SMAX, &prompt).unwrap();
        // 1 page free; a fresh 2-page prompt does NOT fit (the registered
        // prefix's pages are pinned by the live owner — not evictable).
        let two_pages: Vec<i32> = (100..100 + 2 * PS as i32).collect();
        assert!(!l.can_admit(&two_pages, 0));
        assert!(l.can_admit(&two_pages[..PS], 0), "1-page prompt still fits");
        // The same prompt AS A PREFIX HIT fits: all 4 pages map shared.
        assert!(l.can_admit(&prompt, SMAX));
        // Owner retires -> the orphan's pages become evictable capacity.
        l.free(0).unwrap();
        assert!(l.can_admit(&two_pages, 0), "evictable orphan counts");
        // But a hit on the orphan must NOT count its own pages twice:
        // mapping it pins the pages, so only the free page remains for
        // growth — still admissible (no fresh pages needed).
        assert!(l.can_admit(&prompt, SMAX));
        // And the prediction matches reality.
        l.alloc_shared(1, &two_pages, 0).unwrap();
        l.check_invariants().unwrap();
    }

    #[test]
    fn depth_and_advance_are_front_aligned_for_paged_slots() {
        let mut l = ledger();
        l.alloc(0, 6, 0).unwrap();
        assert_eq!(l.depth_of(0), Some(6), "paged depth = valid (no pad)");
        l.advance(&[true, false], &[6, 0]).unwrap();
        assert_eq!(l.depth_of(0), Some(7));
        assert!(l.advance(&[true, false], &[6, 0]).is_err(), "stale pos");
    }

    #[test]
    fn collision_is_verified_by_tokens_not_hash() {
        // Force the registry to hold a prefix, then look up a DIFFERENT
        // token run: even if an adversarial hash collided, the token
        // equality check must turn it into a miss. (The insert-side twin
        // of this test, with a FORCED collision, is below.)
        let mut l = ledger();
        let a: Vec<i32> = vec![1; 8];
        let b: Vec<i32> = vec![2; 8];
        l.alloc_shared(0, &a, 8).unwrap();
        l.register_prefix(0, 8, &a).unwrap();
        let plan = l.alloc_shared(1, &b, 8).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }

    #[test]
    fn forced_collision_never_registers_different_tokens() {
        // Every token run hashes to the same bucket: the first prefix
        // registers, the second (different tokens) must be REJECTED and
        // counted — pre-fix, it was silently treated as already-registered
        // and its admissions could never hit, while the bucket owner's
        // pages stayed pinned forever.
        let mut l = ledger();
        l.hash_hook = Some(|_| 0xDEAD);
        let a: Vec<i32> = vec![1; 8];
        let b: Vec<i32> = vec![2; 8];
        l.alloc_shared(0, &a, 8).unwrap();
        l.register_prefix(0, 8, &a).unwrap();
        assert_eq!(l.n_prefixes(), 1);
        assert_eq!(l.collisions(), 0);

        l.alloc_shared(1, &b, 8).unwrap();
        l.register_prefix(1, 8, &b).unwrap();
        assert_eq!(l.n_prefixes(), 1, "collider must not displace the owner");
        assert_eq!(l.collisions(), 1, "collision counted");
        l.check_invariants().unwrap();

        // The owner still hits; the collider degrades to a miss (correct,
        // if unlucky) — never to the owner's pages.
        l.free(0).unwrap();
        l.free(1).unwrap();
        assert!(l.alloc_shared(0, &a, 8).unwrap().prefix_hit);
        let plan = l.alloc_shared(1, &b, 8).unwrap();
        assert!(!plan.prefix_hit);
        assert_ne!(
            l.block_table(0).unwrap()[..2],
            l.block_table(1).unwrap()[..2],
            "collider never maps the owner's pages"
        );
        l.check_invariants().unwrap();

        // Re-registering the SAME tokens refreshes, doesn't count.
        l.register_prefix(0, 8, &a).unwrap();
        assert_eq!(l.collisions(), 1);
        assert_eq!(l.n_prefixes(), 1);
    }
}
