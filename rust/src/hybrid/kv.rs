//! KV-cache state for the inference phase: the "light-weight memory
//! management system" of paper §4. The caches are device-resident buffers
//! whose lifetime is bounded by the inference phase — installed straight
//! from the prefill artifact's output buffers, swapped (never copied) for
//! the decode artifact's output buffers each step, released at the
//! train-mode flip. K/V bytes never transit host memory between prefill
//! and the flip; per-decode-step host traffic is the logits row only.
//!
//! Device bytes live in [`KvCache`]; every host-side decision about them —
//! which slot owns which storage, where the next token writes, what can be
//! reused — lives in the buffer-free [`PageLedger`], which comes in two
//! layouts:
//!
//! * **Arena** (`[n_layers, b*h, smax, d_head]`): each batch slot owns a
//!   contiguous row group. A variable-length prompt arrives LEFT-PADDED
//!   (`pad` dead entries at the front, masked out of attention by the
//!   artifacts' valid-start inputs), so a slot's state is `(valid, pad)`
//!   with the next write at row `pad + valid`.
//! * **Paged** (`[n_layers, n_heads, n_pages * page_size, d_head]`): the
//!   vLLM-style block-paged pool. Slots own no storage; each holds a
//!   *block table* mapping its logical blocks onto refcounted physical
//!   pages drawn from a free list. Prompts are FRONT-ALIGNED (`pad == 0`;
//!   the artifacts' causal mask keeps the right-padded tail inert), so the
//!   next write is at logical row `valid`. Page 0 is reserved as the
//!   garbage page dead decode rows point at — it never enters the free
//!   list and never appears in a table. Pages holding a **shared prompt
//!   prefix** are mapped into several tables at once: admission hashes the
//!   page-aligned prefix, a registry hit maps the registered pages
//!   (refcount up) instead of allocating, and retirement only returns a
//!   page to the free list when its last reference drops. Registered
//!   prefixes without a live owner are evicted (deterministically, in
//!   hash order) when the free list runs short.
//!
//! The continuous-batching scheduler admits a new request by prefilling
//! straight into a retired slot (`prefill_slot` / `prefill_slot_paged`
//! artifacts) while the other slots keep decoding — the ledger here is
//! what keeps admissions, per-row positions, block tables, and the device
//! cache honest about which rows are live, which are padding, and which
//! pages are shared.

use std::collections::BTreeMap;

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::runtime::Manifest;

/// Which geometry the ledger (and the device buffers) use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Per-slot contiguous row groups, left-padded prompts.
    Arena,
    /// Block-paged pool behind per-slot block tables, front-aligned
    /// prompts, shared-prefix reuse.
    Paged { page_size: usize, n_pages: usize },
}

/// One occupied slot: `valid` real tokens preceded by `pad` left-padding
/// entries (paged slots always have `pad == 0`). The next token writes at
/// logical row `pad + valid`. Paged slots also carry their block table.
#[derive(Debug, Clone, PartialEq, Eq)]
struct SlotState {
    valid: usize,
    pad: usize,
    /// Physical page of each logical block (empty under [`KvLayout::Arena`]).
    pages: Vec<u32>,
}

impl SlotState {
    fn depth(&self) -> usize {
        self.pad + self.valid
    }
}

/// A registered shareable prefix: the page-aligned token run plus the
/// pages holding it (each holding one registry refcount until eviction).
#[derive(Debug, Clone)]
struct PrefixEntry {
    /// The exact tokens, for equality verification on lookup — the hash
    /// routes, the tokens decide (collisions degrade to a miss, never to
    /// serving another request's cache).
    tokens: Vec<i32>,
    pages: Vec<u32>,
}

/// The outcome of a shared-prefix admission ([`PageLedger::alloc_shared`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmitPlan {
    /// Cached tokens this admission mapped instead of recomputing-from-
    /// nothing: the page-aligned shared-prefix length on a registry hit,
    /// 0 on a miss. (The fixed-shape prefill still runs over the full
    /// window either way — this is the ledger-level reuse figure the serve
    /// bench reports as computed-vs-admitted savings.)
    pub reused_tokens: usize,
    /// Whether the prefix registry served this admission.
    pub prefix_hit: bool,
}

/// FNV-1a over a token run — the prefix registry key. Deterministic across
/// runs (reproducibility contract) and cheap enough for per-admission use.
fn prefix_hash(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Host-side occupancy/allocation state for a KV cache — everything except
/// the device buffers, so allocator invariants are unit-testable without a
/// device (see `rust/tests/failure_injection.rs`).
#[derive(Debug, Clone)]
pub struct PageLedger {
    layout: KvLayout,
    /// Logical window per slot (`seq_len` of the artifacts).
    smax: usize,
    slots: Vec<Option<SlotState>>,
    /// Allocatable pages (paged only; never contains page 0).
    free: Vec<u32>,
    /// Per-page reference count: tables holding it + registry entries
    /// holding it (paged only; `refcount[0]` stays 0 — the garbage page is
    /// pointed at by *dead* rows only, which the ledger never records).
    refcount: Vec<u32>,
    /// Registered shareable prefixes by token hash. BTreeMap so eviction
    /// order is deterministic.
    prefixes: BTreeMap<u64, PrefixEntry>,
}

impl PageLedger {
    pub fn arena(n_slots: usize, smax: usize) -> PageLedger {
        PageLedger {
            layout: KvLayout::Arena,
            smax,
            slots: vec![None; n_slots],
            free: Vec::new(),
            refcount: Vec::new(),
            prefixes: BTreeMap::new(),
        }
    }

    pub fn paged(n_slots: usize, smax: usize, page_size: usize, n_pages: usize) -> PageLedger {
        assert!(page_size > 0 && smax % page_size == 0, "{smax} % {page_size}");
        // Free list starts as pages 1..n_pages (0 is the garbage page);
        // popped from the back, so allocation order is descending — any
        // order works, this one makes "first alloc gets the last page"
        // tests unambiguous.
        PageLedger {
            layout: KvLayout::Paged { page_size, n_pages },
            smax,
            slots: vec![None; n_slots],
            free: (1..n_pages as u32).collect(),
            refcount: vec![0; n_pages],
            prefixes: BTreeMap::new(),
        }
    }

    pub fn layout(&self) -> KvLayout {
        self.layout
    }

    /// Logical blocks spanning one slot's full `[0, smax)` window.
    pub fn blocks_per_slot(&self) -> usize {
        match self.layout {
            KvLayout::Arena => 0,
            KvLayout::Paged { page_size, .. } => self.smax / page_size,
        }
    }

    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    pub fn n_active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// VALID (non-padding) tokens held by a slot (`None` if free).
    pub fn len_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.valid)
    }

    /// Left-padding entries preceding a slot's valid tokens (always 0 for
    /// paged slots).
    pub fn pad_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.pad)
    }

    /// Logical cache row the slot's next token writes at (`pad + valid`).
    pub fn depth_of(&self, slot: usize) -> Option<usize> {
        self.slots.get(slot).and_then(|s| s.as_ref()).map(|o| o.depth())
    }

    /// Valid tokens held across all occupied slots (padding never counted).
    pub fn valid_tokens(&self) -> usize {
        self.slots.iter().flatten().map(|o| o.valid).sum()
    }

    /// Lowest-numbered free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Pages currently allocatable (paged only; arena reports 0).
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Registered shareable prefixes currently held.
    pub fn n_prefixes(&self) -> usize {
        self.prefixes.len()
    }

    /// A slot's block table row (paged slots only).
    pub fn block_table(&self, slot: usize) -> Option<&[u32]> {
        self.slots
            .get(slot)
            .and_then(|s| s.as_ref())
            .filter(|o| !o.pages.is_empty())
            .map(|o| o.pages.as_slice())
    }

    fn check_slot(&self, op: &str, slot: usize, valid: usize, pad: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv {op}: slot {slot} out of range ({} slots)", self.slots.len());
        }
        if let Some(held) = &self.slots[slot] {
            bail!("kv {op}: slot {slot} already holds {} tokens", held.valid);
        }
        if valid == 0 {
            bail!("kv {op}: slot {slot} allocated with zero valid tokens");
        }
        if valid + pad > self.smax {
            bail!("kv {op}: slot {slot} wants {valid}+{pad} entries, smax {}", self.smax);
        }
        Ok(())
    }

    /// Allocate one slot for a freshly prefilled sequence of `valid` real
    /// tokens preceded by `pad` left-padding entries. Arena slots only own
    /// their fixed row group; paged slots draw a full window's worth of
    /// pages from the free list (`pad` must be 0 — paged prompts are
    /// front-aligned). For shared-prefix admission use
    /// [`PageLedger::alloc_shared`].
    pub fn alloc(&mut self, slot: usize, valid: usize, pad: usize) -> Result<()> {
        self.check_slot("alloc", slot, valid, pad)?;
        let pages = match self.layout {
            KvLayout::Arena => Vec::new(),
            KvLayout::Paged { .. } => {
                if pad != 0 {
                    bail!("kv alloc: paged slots are front-aligned (pad {pad} != 0)");
                }
                self.take_pages(self.blocks_per_slot())?
            }
        };
        self.slots[slot] = Some(SlotState { valid, pad, pages });
        Ok(())
    }

    /// Allocate every slot at once (the batch-generate path: one
    /// full-batch prefill fills all rows; `pads[i]` is row i's
    /// left-padding — all zeros for the exact-length path).
    pub fn alloc_all(&mut self, valids: &[usize], pads: &[usize]) -> Result<()> {
        assert_eq!(valids.len(), self.slots.len());
        assert_eq!(pads.len(), self.slots.len());
        for slot in 0..self.slots.len() {
            self.alloc(slot, valids[slot], pads[slot])?;
        }
        Ok(())
    }

    /// Paged shared-prefix admission: look the prompt's declared prefix up
    /// in the registry and map its pages instead of allocating them. The
    /// shared region is the PAGE-ALIGNED part of `prefix_len` (a prefix
    /// shorter than one page shares nothing); on a hit the registered
    /// tokens are compared for equality — the hash never decides alone.
    /// Fresh pages cover the rest of the window. Front-aligned, so decode
    /// writes land at logical rows `>= valid > shared region` and never
    /// touch a shared page; the full-window prefill re-writes shared pages
    /// with bit-identical values (same tokens, same logical positions),
    /// which is what makes the mapping copy-on-write-safe.
    pub fn alloc_shared(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prefix_len: usize,
    ) -> Result<AdmitPlan> {
        let KvLayout::Paged { page_size, .. } = self.layout else {
            bail!("kv alloc_shared: arena layout has no page sharing");
        };
        let valid = tokens.len();
        self.check_slot("alloc_shared", slot, valid, 0)?;
        let aligned = (prefix_len.min(valid) / page_size) * page_size;
        let mut shared: Vec<u32> = Vec::new();
        if aligned > 0 {
            let key = prefix_hash(&tokens[..aligned]);
            if let Some(entry) = self.prefixes.get(&key) {
                if entry.tokens == tokens[..aligned] {
                    shared = entry.pages.clone();
                }
            }
        }
        let hit = !shared.is_empty();
        // Pin the shared pages BEFORE drawing fresh ones: drawing may
        // evict registry entries (including the one we just matched), and
        // the pin keeps its pages off the free list while we hold them.
        for &p in &shared {
            self.refcount[p as usize] += 1;
        }
        let fresh = match self.take_pages(self.blocks_per_slot() - shared.len()) {
            Ok(f) => f,
            Err(e) => {
                for &p in &shared {
                    self.unref_page(p);
                }
                return Err(e);
            }
        };
        let mut pages = shared;
        pages.extend(fresh);
        self.slots[slot] = Some(SlotState { valid, pad: 0, pages });
        Ok(AdmitPlan { reused_tokens: if hit { aligned } else { 0 }, prefix_hit: hit })
    }

    /// Register a successfully prefilled slot's page-aligned prefix for
    /// reuse by later admissions. Call AFTER the prefill artifact
    /// succeeded — registering first would hand pages holding garbage to
    /// the next request on a prefill fault. No-op when the aligned prefix
    /// is empty or the hash is already registered.
    pub fn register_prefix(&mut self, slot: usize, prefix_len: usize, tokens: &[i32]) -> Result<()> {
        let KvLayout::Paged { page_size, .. } = self.layout else {
            bail!("kv register_prefix: arena layout has no page sharing");
        };
        let Some(state) = self.slots.get(slot).and_then(|s| s.as_ref()) else {
            bail!("kv register_prefix: slot {slot} is free");
        };
        let aligned = (prefix_len.min(state.valid).min(tokens.len()) / page_size) * page_size;
        if aligned == 0 {
            return Ok(());
        }
        let key = prefix_hash(&tokens[..aligned]);
        if self.prefixes.contains_key(&key) {
            return Ok(());
        }
        let pages: Vec<u32> = state.pages[..aligned / page_size].to_vec();
        for &p in &pages {
            self.refcount[p as usize] += 1;
        }
        self.prefixes.insert(key, PrefixEntry { tokens: tokens[..aligned].to_vec(), pages });
        Ok(())
    }

    /// Pop `n` pages off the free list (each handed out with refcount 1),
    /// evicting registered prefixes (in deterministic hash order) if the
    /// list runs short.
    fn take_pages(&mut self, n: usize) -> Result<Vec<u32>> {
        while self.free.len() < n {
            let Some((&key, _)) = self.prefixes.iter().next() else {
                bail!(
                    "kv alloc: need {n} pages but only {} free and no prefix left to evict \
                     (page leak?)",
                    self.free.len()
                );
            };
            self.evict_prefix(key);
        }
        let taken = self.free.split_off(self.free.len() - n);
        for &p in &taken {
            debug_assert_eq!(self.refcount[p as usize], 0, "free page {p} had references");
            self.refcount[p as usize] = 1;
        }
        Ok(taken)
    }

    fn evict_prefix(&mut self, key: u64) {
        let Some(entry) = self.prefixes.remove(&key) else {
            return;
        };
        for &p in &entry.pages {
            self.unref_page(p);
        }
    }

    fn unref_page(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        debug_assert!(*rc > 0, "unref of page {page} with refcount 0");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
    }

    /// Record one decoded token appended to every slot where `active`.
    /// `fed_pos[slot]` is the logical cache row the token was written to;
    /// it must equal the slot's current depth `pad + valid` (the scheduler
    /// and the device cache advancing in lockstep is the core serving
    /// invariant).
    pub fn advance(&mut self, active: &[bool], fed_pos: &[i32]) -> Result<()> {
        if active.len() != self.slots.len() || fed_pos.len() != self.slots.len() {
            bail!(
                "kv advance: active/pos length {}/{} != {} slots",
                active.len(),
                fed_pos.len(),
                self.slots.len()
            );
        }
        for slot in 0..self.slots.len() {
            if !active[slot] {
                continue;
            }
            let Some(occ) = self.slots[slot].as_mut() else {
                bail!("kv advance: slot {slot} is free but marked active");
            };
            if fed_pos[slot] as usize != occ.depth() {
                bail!(
                    "kv advance: slot {slot} fed at pos {} but its depth is {} \
                     ({} valid + {} pad)",
                    fed_pos[slot],
                    occ.depth(),
                    occ.valid,
                    occ.pad
                );
            }
            if occ.depth() + 1 > self.smax {
                bail!("kv advance: slot {slot} overflows smax {}", self.smax);
            }
            occ.valid += 1;
        }
        Ok(())
    }

    /// Record `n` tokens appended to ONE slot by a fused decode chunk:
    /// the first was written at `fed_pos` (which must equal the slot's
    /// depth, exactly as in [`PageLedger::advance`]) and the rest at the
    /// following rows. Equivalent to `n` single-token advances — the
    /// chunk artifact writes every accepted token's K/V row in its
    /// unrolled loop, so the ledger catches up in one call.
    pub fn advance_chunk(&mut self, slot: usize, fed_pos: i32, n: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv advance_chunk: slot {slot} out of range ({} slots)", self.slots.len());
        }
        let Some(occ) = self.slots[slot].as_mut() else {
            bail!("kv advance_chunk: slot {slot} is free");
        };
        if fed_pos as usize != occ.depth() {
            bail!(
                "kv advance_chunk: slot {slot} fed at pos {fed_pos} but its depth is {} \
                 ({} valid + {} pad)",
                occ.depth(),
                occ.valid,
                occ.pad
            );
        }
        if occ.depth() + n > self.smax {
            bail!(
                "kv advance_chunk: slot {slot} advancing {n} tokens overflows smax {}",
                self.smax
            );
        }
        occ.valid += n;
        Ok(())
    }

    /// Record one decoded token appended to every slot (batch generate).
    pub fn advance_all(&mut self) {
        for s in self.slots.iter_mut().flatten() {
            s.valid += 1;
        }
    }

    /// Retire a sequence: arena rows become dead; paged pages drop one
    /// reference each, returning to the free list unless a registered
    /// prefix (or another slot's table) still holds them.
    pub fn free(&mut self, slot: usize) -> Result<()> {
        if slot >= self.slots.len() {
            bail!("kv free: slot {slot} out of range ({} slots)", self.slots.len());
        }
        let Some(state) = self.slots[slot].take() else {
            bail!("kv free: slot {slot} is already free");
        };
        for &p in &state.pages {
            self.unref_page(p);
        }
        Ok(())
    }

    /// Allocator consistency check, for tests and debug assertions:
    /// every page's refcount equals the number of tables + registry
    /// entries holding it, the free list is exactly the refcount-0 pages
    /// (minus the garbage page), and no page is listed twice.
    pub fn check_invariants(&self) -> Result<()> {
        let KvLayout::Paged { n_pages, .. } = self.layout else {
            return Ok(());
        };
        let mut want = vec![0u32; n_pages];
        for s in self.slots.iter().flatten() {
            for &p in &s.pages {
                want[p as usize] += 1;
            }
        }
        for e in self.prefixes.values() {
            for &p in &e.pages {
                want[p as usize] += 1;
            }
        }
        if want[0] != 0 {
            bail!("kv invariant: garbage page 0 is referenced {} times", want[0]);
        }
        if self.refcount != want {
            bail!("kv invariant: refcounts {:?} != references {:?}", self.refcount, want);
        }
        let mut seen = vec![false; n_pages];
        for &p in &self.free {
            if p == 0 {
                bail!("kv invariant: garbage page 0 on the free list");
            }
            if seen[p as usize] {
                bail!("kv invariant: page {p} on the free list twice");
            }
            seen[p as usize] = true;
            if self.refcount[p as usize] != 0 {
                bail!("kv invariant: free page {p} has refcount {}", self.refcount[p as usize]);
            }
        }
        let free_should = (1..n_pages).filter(|&p| self.refcount[p] == 0).count();
        if self.free.len() != free_should {
            bail!(
                "kv invariant: {} pages free but {} have refcount 0",
                self.free.len(),
                free_should
            );
        }
        Ok(())
    }
}

/// The device buffers plus their [`PageLedger`].
pub struct KvCache {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
    /// Arena: `[n_layers, b*h, smax, d_head]`;
    /// paged: `[n_layers, n_heads, n_pages * page_size, d_head]`.
    pub dims: Vec<usize>,
    pub ledger: PageLedger,
}

impl KvCache {
    /// The arena cache shape the AOT artifacts compile against
    /// (`python/compile/aot.py`: `(n_layers, batch*n_heads, seq_len, d_head)`).
    pub fn dims_for(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.batch * m.actor.n_heads,
            m.seq_len,
            m.actor.d_head(),
        ]
    }

    /// The block-paged pool shape of the `*_paged` artifacts
    /// (`(n_layers, n_heads, kv_pages * page_size, d_head)`).
    pub fn dims_for_paged(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.actor.n_heads,
            m.kv_pages * m.page_size,
            m.actor.d_head(),
        ]
    }

    /// Arena-cache bytes for a manifest's shape (usable before a cache
    /// exists; [`KvCache::bytes`] reports the live figure either way).
    pub fn bytes_for(m: &Manifest) -> usize {
        2 * Self::dims_for(m).iter().product::<usize>() * 4
    }

    /// Adopt freshly produced device buffers as the live ARENA cache, with
    /// all `n_slots` batch slots initially free.
    pub fn arena(k: PjRtBuffer, v: PjRtBuffer, dims: Vec<usize>, n_slots: usize) -> KvCache {
        let smax = dims[2];
        KvCache { k, v, dims, ledger: PageLedger::arena(n_slots, smax) }
    }

    /// Adopt freshly produced device buffers as the live BLOCK-PAGED pool
    /// (`smax` is the logical per-slot window, NOT the pool length).
    pub fn paged(
        k: PjRtBuffer,
        v: PjRtBuffer,
        dims: Vec<usize>,
        n_slots: usize,
        smax: usize,
        page_size: usize,
        n_pages: usize,
    ) -> KvCache {
        KvCache { k, v, dims, ledger: PageLedger::paged(n_slots, smax, page_size, n_pages) }
    }

    /// Swap in the decode step's output buffers (zero-copy: the previous
    /// generation's buffers are dropped, freeing their device memory).
    pub fn update(&mut self, k: PjRtBuffer, v: PjRtBuffer) {
        self.k = k;
        self.v = v;
    }

    /// Bytes held by both caches (f32).
    pub fn bytes(&self) -> usize {
        2 * self.dims.iter().product::<usize>() * 4
    }

    // ------------------------------------------------------------------
    // Ledger forwards (serving / continuous batching)
    // ------------------------------------------------------------------

    pub fn layout(&self) -> KvLayout {
        self.ledger.layout()
    }

    pub fn n_slots(&self) -> usize {
        self.ledger.n_slots()
    }

    pub fn n_active(&self) -> usize {
        self.ledger.n_active()
    }

    pub fn len_of(&self, slot: usize) -> Option<usize> {
        self.ledger.len_of(slot)
    }

    pub fn pad_of(&self, slot: usize) -> Option<usize> {
        self.ledger.pad_of(slot)
    }

    pub fn depth_of(&self, slot: usize) -> Option<usize> {
        self.ledger.depth_of(slot)
    }

    pub fn valid_tokens(&self) -> usize {
        self.ledger.valid_tokens()
    }

    pub fn first_free(&self) -> Option<usize> {
        self.ledger.first_free()
    }

    pub fn block_table(&self, slot: usize) -> Option<&[u32]> {
        self.ledger.block_table(slot)
    }

    pub fn alloc(&mut self, slot: usize, valid: usize, pad: usize) -> Result<()> {
        self.ledger.alloc(slot, valid, pad)
    }

    pub fn alloc_all(&mut self, valids: &[usize], pads: &[usize]) -> Result<()> {
        self.ledger.alloc_all(valids, pads)
    }

    pub fn alloc_shared(
        &mut self,
        slot: usize,
        tokens: &[i32],
        prefix_len: usize,
    ) -> Result<AdmitPlan> {
        self.ledger.alloc_shared(slot, tokens, prefix_len)
    }

    pub fn register_prefix(&mut self, slot: usize, prefix_len: usize, tokens: &[i32]) -> Result<()> {
        self.ledger.register_prefix(slot, prefix_len, tokens)
    }

    pub fn advance(&mut self, active: &[bool], fed_pos: &[i32]) -> Result<()> {
        self.ledger.advance(active, fed_pos)
    }

    pub fn advance_chunk(&mut self, slot: usize, fed_pos: i32, n: usize) -> Result<()> {
        self.ledger.advance_chunk(slot, fed_pos, n)
    }

    pub fn advance_all(&mut self) {
        self.ledger.advance_all()
    }

    pub fn free(&mut self, slot: usize) -> Result<()> {
        self.ledger.free(slot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SMAX: usize = 16;
    const PS: usize = 4;
    const MB: usize = SMAX / PS; // 4 blocks per slot
    const SLOTS: usize = 2;
    const PAGES: usize = (SLOTS + 1) * MB + 1; // 13: both slots + spare + garbage

    fn ledger() -> PageLedger {
        PageLedger::paged(SLOTS, SMAX, PS, PAGES)
    }

    #[test]
    fn arena_ledger_matches_legacy_occupancy_semantics() {
        let mut l = PageLedger::arena(2, SMAX);
        assert_eq!(l.first_free(), Some(0));
        l.alloc(0, 5, 3).unwrap();
        assert_eq!(l.len_of(0), Some(5));
        assert_eq!(l.pad_of(0), Some(3));
        assert_eq!(l.depth_of(0), Some(8));
        assert_eq!(l.first_free(), Some(1));
        assert!(l.alloc(0, 1, 0).is_err(), "double alloc");
        assert!(l.alloc(1, 0, 0).is_err(), "zero valid");
        assert!(l.alloc(1, SMAX, 1).is_err(), "overflow");
        l.advance(&[true, false], &[8, 0]).unwrap();
        assert_eq!(l.depth_of(0), Some(9));
        assert!(l.advance(&[true, false], &[8, 0]).is_err(), "stale pos");
        assert!(l.advance(&[false, true], &[0, 0]).is_err(), "free but active");
        assert_eq!(l.valid_tokens(), 6);
        l.free(0).unwrap();
        assert!(l.free(0).is_err(), "double free");
        assert_eq!(l.n_active(), 0);
    }

    #[test]
    fn chunk_advance_equals_repeated_single_advances() {
        let mut chunked = ledger();
        let mut stepped = ledger();
        for l in [&mut chunked, &mut stepped] {
            l.alloc_shared(0, &[1, 2, 3], 0).unwrap();
        }
        chunked.advance_chunk(0, 3, 4).unwrap();
        for d in 0..4 {
            stepped.advance(&[true, false], &[3 + d, 0]).unwrap();
        }
        assert_eq!(chunked.depth_of(0), stepped.depth_of(0));
        assert_eq!(chunked.depth_of(0), Some(7));
        // Same failure contracts as the stepwise path: stale fed position,
        // smax overflow, free slot.
        assert!(chunked.advance_chunk(0, 3, 1).is_err(), "stale pos");
        assert!(chunked.advance_chunk(0, 7, SMAX).is_err(), "overflow");
        assert!(chunked.advance_chunk(1, 0, 1).is_err(), "free slot");
        chunked.advance_chunk(0, 7, SMAX - 7).unwrap();
        assert_eq!(chunked.depth_of(0), Some(SMAX));
    }

    #[test]
    fn paged_alloc_draws_and_free_returns_pages() {
        let mut l = ledger();
        assert_eq!(l.free_pages(), PAGES - 1, "page 0 reserved");
        l.alloc(0, 6, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - MB);
        let table: Vec<u32> = l.block_table(0).unwrap().to_vec();
        assert_eq!(table.len(), MB);
        assert!(!table.contains(&0), "garbage page never allocated");
        assert!(l.alloc(1, 4, 2).is_err(), "paged slots are front-aligned");
        l.alloc(1, 4, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 2 * MB);
        l.free(0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - MB, "slot 0's pages returned");
        // The freed pages are allocatable again.
        l.alloc(0, 2, 0).unwrap();
        l.check_invariants().unwrap();
        for &p in l.block_table(0).unwrap() {
            assert!(table.contains(&p), "reused the returned pages");
        }
    }

    #[test]
    fn shared_prefix_hit_maps_registered_pages() {
        let mut l = ledger();
        // 6-token prompt with a declared 5-token prefix: page-aligned
        // shared region is one page (4 tokens).
        let prompt: Vec<i32> = (10..16).collect();
        let plan = l.alloc_shared(0, &prompt, 5).unwrap();
        assert_eq!(plan, AdmitPlan { reused_tokens: 0, prefix_hit: false }, "cold registry");
        l.register_prefix(0, 5, &prompt).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.n_prefixes(), 1);
        let prefix_page = l.block_table(0).unwrap()[0];

        // Same prefix, different tail: the aligned page is mapped shared.
        let mut other = prompt.clone();
        other[5] = 99;
        let plan = l.alloc_shared(1, &other, 5).unwrap();
        assert_eq!(plan, AdmitPlan { reused_tokens: PS, prefix_hit: true });
        l.check_invariants().unwrap();
        assert_eq!(l.block_table(1).unwrap()[0], prefix_page, "page shared");
        // Shared page consumed no free-list page: two tables, 2*MB blocks,
        // but only 2*MB - 1 pages drawn.
        assert_eq!(l.free_pages(), PAGES - 1 - (2 * MB - 1));

        // DIFFERENT prefix tokens miss even at the same declared length.
        l.free(1).unwrap();
        let unrelated: Vec<i32> = (50..56).collect();
        let plan = l.alloc_shared(1, &unrelated, 5).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }

    #[test]
    fn shared_pages_survive_owner_retirement() {
        let mut l = ledger();
        let prompt: Vec<i32> = (0..8).collect();
        l.alloc_shared(0, &prompt, 8).unwrap();
        l.register_prefix(0, 8, &prompt).unwrap();
        let shared: Vec<u32> = l.block_table(0).unwrap()[..2].to_vec();
        // Owner retires; the registered prefix keeps its 2 pages warm.
        l.free(0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), PAGES - 1 - 2);
        // A later admission still hits.
        let plan = l.alloc_shared(1, &prompt, 8).unwrap();
        assert_eq!(plan.reused_tokens, 8);
        assert_eq!(&l.block_table(1).unwrap()[..2], &shared[..]);
        l.check_invariants().unwrap();
    }

    #[test]
    fn prefix_shorter_than_a_page_shares_nothing() {
        let mut l = ledger();
        let prompt: Vec<i32> = (0..8).collect();
        l.alloc_shared(0, &prompt, PS - 1).unwrap();
        l.register_prefix(0, PS - 1, &prompt).unwrap();
        assert_eq!(l.n_prefixes(), 0, "sub-page prefix not registrable");
        let plan = l.alloc_shared(1, &prompt, PS - 1).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }

    #[test]
    fn eviction_reclaims_orphan_prefix_pages_under_pool_pressure() {
        // Tight pool: exactly both slots' blocks + garbage page, no spare.
        // An orphan prefix (owner retired) then makes a second full
        // admission impossible without eviction.
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, 2 * MB + 1);
        let prompt: Vec<i32> = (0..SMAX as i32).collect();
        l.alloc_shared(0, &prompt, SMAX).unwrap();
        l.register_prefix(0, SMAX, &prompt).unwrap();
        l.free(0).unwrap(); // orphan: MB pages held by the registry alone
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), MB);
        assert_eq!(l.n_prefixes(), 1);

        l.alloc(0, 4, 0).unwrap(); // takes the whole free list
        l.check_invariants().unwrap();
        assert_eq!(l.free_pages(), 0);
        assert_eq!(l.n_prefixes(), 1, "orphan still warm while pages last");

        // Second admission finds the free list empty: the allocator must
        // evict the orphan prefix, reclaim its pages, and succeed.
        l.alloc(1, 4, 0).unwrap();
        l.check_invariants().unwrap();
        assert_eq!(l.n_prefixes(), 0, "orphan evicted under pool pressure");
        assert_eq!(l.free_pages(), 0);
    }

    #[test]
    fn exhausted_pool_with_nothing_to_evict_errors() {
        // Pool holds one slot's blocks only: the second admission has no
        // free pages and no registered prefix to evict — a hard error
        // (pool geometry bug / page leak), not a silent corruption.
        let mut l = PageLedger::paged(SLOTS, SMAX, PS, MB + 1);
        l.alloc(0, 4, 0).unwrap();
        let err = l.alloc(1, 4, 0).unwrap_err().to_string();
        assert!(err.contains("page leak"), "{err}");
        // The failed alloc must not have touched slot state.
        assert_eq!(l.len_of(1), None);
        l.check_invariants().unwrap();
    }

    #[test]
    fn depth_and_advance_are_front_aligned_for_paged_slots() {
        let mut l = ledger();
        l.alloc(0, 6, 0).unwrap();
        assert_eq!(l.depth_of(0), Some(6), "paged depth = valid (no pad)");
        l.advance(&[true, false], &[6, 0]).unwrap();
        assert_eq!(l.depth_of(0), Some(7));
        assert!(l.advance(&[true, false], &[6, 0]).is_err(), "stale pos");
    }

    #[test]
    fn collision_is_verified_by_tokens_not_hash() {
        // Force the registry to hold a prefix, then look up a DIFFERENT
        // token run: even if an adversarial hash collided, the token
        // equality check must turn it into a miss. (We can't force a real
        // FNV collision cheaply; this pins the code path where tokens
        // differ — the equality check, not the hash, decides.)
        let mut l = ledger();
        let a: Vec<i32> = vec![1; 8];
        let b: Vec<i32> = vec![2; 8];
        l.alloc_shared(0, &a, 8).unwrap();
        l.register_prefix(0, 8, &a).unwrap();
        let plan = l.alloc_shared(1, &b, 8).unwrap();
        assert!(!plan.prefix_hit);
        l.check_invariants().unwrap();
    }
}
