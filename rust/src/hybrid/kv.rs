//! KV-cache state for the inference phase: the "light-weight memory
//! management system" of paper §4. The caches are device-resident buffers
//! whose lifetime is bounded by the inference phase — allocated at prefill,
//! updated in place each decode step, released at the train-mode flip.

use anyhow::Result;
use xla::{Literal, PjRtBuffer};

use crate::runtime::{Engine, HostTensor};

pub struct KvCache {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
    /// [n_layers, b*h, smax, d_head]
    pub dims: Vec<usize>,
}

impl KvCache {
    pub fn from_literals(engine: &Engine, k: &Literal, v: &Literal) -> Result<KvCache> {
        let kt = HostTensor::from_literal(k)?;
        let dims = kt.shape().to_vec();
        let kb = engine.upload(&kt)?;
        let vb = engine.upload(&HostTensor::from_literal(v)?)?;
        Ok(KvCache { k: kb, v: vb, dims })
    }

    /// Replace both caches with the decode step's outputs.
    pub fn update(&mut self, engine: &Engine, k: &Literal, v: &Literal) -> Result<()> {
        self.k = engine.upload(&HostTensor::from_literal(k)?)?;
        self.v = engine.upload(&HostTensor::from_literal(v)?)?;
        Ok(())
    }

    /// Bytes held by both caches (f32).
    pub fn bytes(&self) -> usize {
        2 * self.dims.iter().product::<usize>() * 4
    }
}
