//! KV-cache state for the inference phase: the "light-weight memory
//! management system" of paper §4. The caches are device-resident buffers
//! whose lifetime is bounded by the inference phase — installed straight
//! from the prefill artifact's output buffers, swapped (never copied) for
//! the decode artifact's output buffers each step, released at the
//! train-mode flip. K/V bytes never transit host memory between prefill
//! and the flip; per-decode-step host traffic is the logits row only.
//!
//! For the serving path the cache additionally tracks **per-slot
//! occupancy**: each batch slot (a `[n_heads, smax, d_head]` row group of
//! both caches) is either free or holds a live sequence. Occupancy counts
//! **valid tokens only**: a variable-length prompt arrives LEFT-PADDED
//! into the fixed `prompt_len` window (`pad` dead entries at the front of
//! the slot, written by the padded prefill and masked out of attention by
//! the artifact's valid-start inputs), so a slot's state is `(valid, pad)`
//! with the next cache write landing at row `pad + valid`. The
//! continuous-batching scheduler admits a new request by prefilling
//! straight into a retired slot's rows (`prefill_slot` artifact) while the
//! other slots keep decoding — the ledger here is what keeps admissions,
//! per-row positions, and the device cache honest about which rows are
//! live and which are padding.

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::runtime::Manifest;

/// One occupied slot: `valid` real tokens preceded by `pad` left-padding
/// entries (0 for exact-length prompts). The next token writes at cache
/// row `pad + valid`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotOcc {
    pub valid: usize,
    pub pad: usize,
}

impl SlotOcc {
    /// Artifact cache row the slot's NEXT token will be written at.
    pub fn depth(&self) -> usize {
        self.pad + self.valid
    }
}

pub struct KvCache {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
    /// [n_layers, b*h, smax, d_head]
    pub dims: Vec<usize>,
    /// Per-slot occupancy; `None` = free.
    occupancy: Vec<Option<SlotOcc>>,
}

impl KvCache {
    /// The cache shape the AOT artifacts compile against
    /// (`python/compile/aot.py`: `(n_layers, batch*n_heads, seq_len, d_head)`).
    pub fn dims_for(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.batch * m.actor.n_heads,
            m.seq_len,
            m.actor.d_head(),
        ]
    }

    /// Cache bytes for a manifest's shape (usable before a cache exists;
    /// [`KvCache::bytes`] reports the same figure for a live cache).
    pub fn bytes_for(m: &Manifest) -> usize {
        2 * Self::dims_for(m).iter().product::<usize>() * 4
    }

    /// Adopt freshly produced device buffers as the live cache, with all
    /// `n_slots` batch slots initially free.
    pub fn from_buffers(k: PjRtBuffer, v: PjRtBuffer, dims: Vec<usize>, n_slots: usize) -> KvCache {
        KvCache { k, v, dims, occupancy: vec![None; n_slots] }
    }

    /// Swap in the decode step's output buffers (zero-copy: the previous
    /// generation's buffers are dropped, freeing their device memory).
    pub fn update(&mut self, k: PjRtBuffer, v: PjRtBuffer) {
        self.k = k;
        self.v = v;
    }

    /// Bytes held by both caches (f32).
    pub fn bytes(&self) -> usize {
        2 * self.dims.iter().product::<usize>() * 4
    }

    // ------------------------------------------------------------------
    // Per-slot occupancy (serving / continuous batching)
    // ------------------------------------------------------------------

    pub fn n_slots(&self) -> usize {
        self.occupancy.len()
    }

    pub fn n_active(&self) -> usize {
        self.occupancy.iter().filter(|s| s.is_some()).count()
    }

    /// VALID (non-padding) tokens held by a slot (`None` if free).
    pub fn len_of(&self, slot: usize) -> Option<usize> {
        self.occupancy.get(slot).copied().flatten().map(|o| o.valid)
    }

    /// Left-padding entries preceding a slot's valid tokens.
    pub fn pad_of(&self, slot: usize) -> Option<usize> {
        self.occupancy.get(slot).copied().flatten().map(|o| o.pad)
    }

    /// Artifact cache row the slot's next token writes at (`pad + valid`).
    pub fn depth_of(&self, slot: usize) -> Option<usize> {
        self.occupancy.get(slot).copied().flatten().map(|o| o.depth())
    }

    /// Valid tokens held across all occupied slots (the occupancy figure —
    /// padding entries are dead rows and never counted).
    pub fn valid_tokens(&self) -> usize {
        self.occupancy.iter().flatten().map(|o| o.valid).sum()
    }

    /// Lowest-numbered free slot, if any.
    pub fn first_free(&self) -> Option<usize> {
        self.occupancy.iter().position(|s| s.is_none())
    }

    /// Claim one slot for a freshly prefilled sequence of `valid` real
    /// tokens preceded by `pad` left-padding entries (0 for an
    /// exact-length prompt).
    pub fn claim(&mut self, slot: usize, valid: usize, pad: usize) -> Result<()> {
        if slot >= self.occupancy.len() {
            bail!("kv claim: slot {slot} out of range ({} slots)", self.occupancy.len());
        }
        if let Some(held) = self.occupancy[slot] {
            bail!("kv claim: slot {slot} already holds {} tokens", held.valid);
        }
        if valid == 0 {
            bail!("kv claim: slot {slot} claimed with zero valid tokens");
        }
        if valid + pad > self.dims[2] {
            bail!(
                "kv claim: slot {slot} wants {valid}+{pad} entries, smax {}",
                self.dims[2]
            );
        }
        self.occupancy[slot] = Some(SlotOcc { valid, pad });
        Ok(())
    }

    /// Claim every slot at once (the batch-generate path: one full-batch
    /// prefill fills all rows; `pads[i]` is row i's left-padding — all
    /// zeros for the exact-length path).
    pub fn claim_all(&mut self, valids: &[usize], pads: &[usize]) {
        assert_eq!(valids.len(), self.occupancy.len());
        assert_eq!(pads.len(), self.occupancy.len());
        for (slot, s) in self.occupancy.iter_mut().enumerate() {
            *s = Some(SlotOcc { valid: valids[slot], pad: pads[slot] });
        }
    }

    /// Record one decoded token appended to every slot where `active`.
    /// `fed_pos[slot]` is the cache row the token was written to; it must
    /// equal the slot's current depth `pad + valid` (the scheduler and the
    /// device cache advancing in lockstep is the core serving invariant).
    pub fn advance_where(&mut self, active: &[bool], fed_pos: &[i32]) -> Result<()> {
        if active.len() != self.occupancy.len() || fed_pos.len() != self.occupancy.len() {
            bail!(
                "kv advance: active/pos length {}/{} != {} slots",
                active.len(),
                fed_pos.len(),
                self.occupancy.len()
            );
        }
        for slot in 0..self.occupancy.len() {
            if !active[slot] {
                continue;
            }
            let Some(occ) = self.occupancy[slot] else {
                bail!("kv advance: slot {slot} is free but marked active");
            };
            if fed_pos[slot] as usize != occ.depth() {
                bail!(
                    "kv advance: slot {slot} fed at pos {} but its depth is {} \
                     ({} valid + {} pad)",
                    fed_pos[slot],
                    occ.depth(),
                    occ.valid,
                    occ.pad
                );
            }
            if occ.depth() + 1 > self.dims[2] {
                bail!("kv advance: slot {slot} overflows smax {}", self.dims[2]);
            }
            self.occupancy[slot] = Some(SlotOcc { valid: occ.valid + 1, pad: occ.pad });
        }
        Ok(())
    }

    /// Record one decoded token appended to every slot (batch generate).
    pub fn advance_all(&mut self) {
        for s in self.occupancy.iter_mut() {
            if let Some(occ) = s {
                occ.valid += 1;
            }
        }
    }

    /// Retire a sequence: its rows become dead and the slot reusable.
    pub fn release(&mut self, slot: usize) -> Result<()> {
        if slot >= self.occupancy.len() {
            bail!("kv release: slot {slot} out of range ({} slots)", self.occupancy.len());
        }
        if self.occupancy[slot].is_none() {
            bail!("kv release: slot {slot} is already free");
        }
        self.occupancy[slot] = None;
        Ok(())
    }
}
