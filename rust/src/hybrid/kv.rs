//! KV-cache state for the inference phase: the "light-weight memory
//! management system" of paper §4. The caches are device-resident buffers
//! whose lifetime is bounded by the inference phase — installed straight
//! from the prefill artifact's output buffers, swapped (never copied) for
//! the decode artifact's output buffers each step, released at the
//! train-mode flip. K/V bytes never transit host memory between prefill
//! and the flip; per-decode-step host traffic is the logits row only.

use crate::runtime::Manifest;
use xla::PjRtBuffer;

pub struct KvCache {
    pub k: PjRtBuffer,
    pub v: PjRtBuffer,
    /// [n_layers, b*h, smax, d_head]
    pub dims: Vec<usize>,
}

impl KvCache {
    /// The cache shape the AOT artifacts compile against
    /// (`python/compile/aot.py`: `(n_layers, batch*n_heads, seq_len, d_head)`).
    pub fn dims_for(m: &Manifest) -> Vec<usize> {
        vec![
            m.actor.n_layers,
            m.batch * m.actor.n_heads,
            m.seq_len,
            m.actor.d_head(),
        ]
    }

    /// Cache bytes for a manifest's shape (usable before a cache exists;
    /// [`KvCache::bytes`] reports the same figure for a live cache).
    pub fn bytes_for(m: &Manifest) -> usize {
        2 * Self::dims_for(m).iter().product::<usize>() * 4
    }

    /// Adopt the prefill artifact's output buffers as the live cache.
    pub fn from_buffers(k: PjRtBuffer, v: PjRtBuffer, dims: Vec<usize>) -> KvCache {
        KvCache { k, v, dims }
    }

    /// Swap in the decode step's output buffers (zero-copy: the previous
    /// generation's buffers are dropped, freeing their device memory).
    pub fn update(&mut self, k: PjRtBuffer, v: PjRtBuffer) {
        self.k = k;
        self.v = v;
    }

    /// Bytes held by both caches (f32).
    pub fn bytes(&self) -> usize {
        2 * self.dims.iter().product::<usize>() * 4
    }
}
